"""Distribution: sharding rules + a real (8 fake devices) lower/compile in a
subprocess, so the main test process keeps its single-device jax config."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import functools
import jax, jax.numpy as jnp
from repro.configs.base import get_arch, reduce_for_smoke
from repro.distributed import ctx, hlo_analysis
from repro.distributed.sharding import (make_axis_env, params_shardings,
                                        batch_pspec, cache_shardings)
from repro.launch.mesh import make_test_mesh
from repro.models import lm
from repro.training.optimizer import init_opt_state
from repro.training.train_step import TrainConfig, make_train_step

cfg = reduce_for_smoke(get_arch("{arch}"))
mesh = make_test_mesh(data=2, model=4)
env = make_axis_env(mesh)
key = jax.random.PRNGKey(0)
p_shapes = jax.eval_shape(functools.partial(lm.init_params, cfg=cfg), key)
p_sh = params_shardings(cfg, p_shapes, env)
params = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                         sharding=sh),
                      p_shapes, p_sh)
o_shapes = jax.eval_shape(init_opt_state, p_shapes)
o_sh = {{"m": p_sh, "v": p_sh,
        "count": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}}
opt = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                      sharding=sh),
                   o_shapes, o_sh)
B, S = 8, 64
tok_sh = jax.sharding.NamedSharding(mesh, batch_pspec(B, env))
shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, S)
tokens = jax.ShapeDtypeStruct(shape, jnp.int32, sharding=tok_sh)
step = make_train_step(cfg, TrainConfig(microbatches=2, q_chunk=32,
                                        xent_chunk=32))
with ctx.use_env(env):
    lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params, opt, tokens,
                                                         tokens)
compiled = lowered.compile()
an = hlo_analysis.analyze(compiled.as_text())
print(json.dumps({{"flops": an["dot_flops"],
                  "coll": hlo_analysis.total_collective_bytes(an["collectives"]),
                  "ok": True}}))
"""


def _run(arch):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SUBPROC.format(arch=arch)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["stablelm-3b", "moonshot-v1-16b-a3b",
                                  "zamba2-2.7b"])
def test_small_mesh_train_compiles_with_collectives(arch):
    res = _run(arch)
    assert res["ok"]
    assert res["flops"] > 0
    assert res["coll"] > 0          # sharded training must communicate


def test_param_pspec_rules_cover_all_archs():
    """Pure-function check: every leaf of every arch gets a valid spec."""
    import functools
    import jax
    from repro.configs.base import get_arch, list_archs, reduce_for_smoke
    from repro.core.descriptor import flatten_with_names
    from repro.distributed.sharding import param_pspec
    from repro.models import lm

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    class FakeEnv:
        mesh = FakeMesh()
        fsdp = ("data",)
        dp = ("data",)
        model = "model"
        msize = 16
        fsize = 16
        dpsize = 16
        attn_policy = "v1"
        moe_impl = "gspmd"

    for arch in list_archs():
        if arch.startswith(("micro", "train-")):
            continue
        cfg = get_arch(arch)
        sc = reduce_for_smoke(cfg)
        shapes = jax.eval_shape(
            functools.partial(lm.init_params, cfg=sc), jax.random.PRNGKey(0))
        names, paths, leaves = flatten_with_names(shapes)
        for n, l in zip(names, leaves):
            spec = param_pspec(n, l.shape, cfg, FakeEnv())
            assert len(spec) <= len(l.shape), (arch, n, spec, l.shape)
