"""repro.analysis: determinism linter, SimSan sanitizer, race detector."""
import json

import numpy as np
import pytest

from repro.analysis import Sanitizer, SanitizerError, enabled, simsan
from repro.analysis.lint import (Finding, collect_set_attrs, is_sim_critical,
                                 lint_paths, lint_source)
from repro.analysis.lint import main as lint_main
from repro.analysis.races import (compare_runs, detect, first_log_divergence,
                                  semantic_summary)
from repro.net.network import Network
from repro.sim import ForkOnDemand, ReplayEngine, SimFunction, spike_660323
from repro.sim.engine import build_cluster
from repro.sim.events import EventLoop
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def findings(src, **kw):
    return [f for f in lint_source(src, **kw) if not f.suppressed]


def rules(src, **kw):
    return [f.rule for f in findings(src, **kw)]


# ---------------------------------------------------------------------------
# linter: one positive + one suppressed case per rule
# ---------------------------------------------------------------------------

def test_lint_wall_clock_call_and_reference():
    assert rules("import time\nt = time.monotonic()\n") == ["wall-clock"]
    # stored as a default (never called here) is still a finding
    assert rules("import time\ndef f(clock=time.monotonic): pass\n") \
        == ["wall-clock"]
    assert rules("from time import perf_counter\nx = perf_counter()\n") \
        == ["wall-clock"]


def test_lint_wall_clock_suppressed_inline_and_above():
    src = ("import time\n"
           "t = time.monotonic()  # sim-ok: wall-clock -- host only\n")
    all_f = lint_source(src)
    assert [f.suppressed for f in all_f] == [True]
    src = ("import time\n"
           "# sim-ok: wall-clock -- reason spanning\n"
           "# a second comment line\n"
           "t = time.monotonic()\n")
    assert findings(src) == []
    # a trailing comment must NOT bleed onto the next statement
    src = ("import time\n"
           "a = 1  # sim-ok: wall-clock\n"
           "t = time.monotonic()\n")
    assert rules(src) == ["wall-clock"]


def test_lint_datetime_now():
    src = "import datetime\nts = datetime.datetime.now()\n"
    assert rules(src) == ["wall-clock"]
    # explicit tz argument is allowed (still wall clock, but the rule
    # targets the argless idiom that litters timestamps)
    src = "import datetime\nts = datetime.datetime.now(tz)\n"
    assert rules(src) == []


def test_lint_unseeded_random():
    assert rules("import random\nx = random.random()\n") \
        == ["unseeded-random"]
    assert rules("import random\nx = random.Random()\n") \
        == ["unseeded-random"]
    assert rules("import random\nx = random.Random(7)\n") == []
    assert rules("import numpy as np\nx = np.random.rand(3)\n") \
        == ["unseeded-random"]
    assert rules("import numpy as np\nr = np.random.default_rng(0)\n") == []
    assert rules("import secrets\nk = secrets.token_bytes(8)\n") \
        == ["unseeded-random"]
    assert rules("import random\nx = random.SystemRandom()\n") \
        == ["unseeded-random"]


def test_lint_set_iter():
    assert rules("for x in {1, 2}:\n    pass\n") == ["set-iter"]
    assert rules("s = set()\nfor x in s:\n    pass\n") == ["set-iter"]
    assert rules("s = {1}\nys = [x for x in s]\n") == ["set-iter"]
    assert rules("s = set()\nfor x in sorted(s):\n    pass\n") == []
    # set-typed attribute known from a cross-file annotation
    src = "for u in conn.users:\n    pass\n"
    assert rules(src) == []
    assert rules(src, extra_set_attrs={"users"}) == ["set-iter"]


def test_lint_cross_file_set_attrs():
    types_src = ("class C:\n"
                 "    def __init__(self):\n"
                 "        self.users: Set[str] = set()\n")
    attrs = collect_set_attrs([("types.py", types_src)])
    assert "users" in attrs
    assert rules("for u in c.users:\n    pass\n", extra_set_attrs=attrs) \
        == ["set-iter"]


def test_lint_float_sum():
    assert rules("s = {1.0}\nt = sum(s)\n") == ["float-sum"]
    # a genexp over a set is BOTH an unordered reduction and a set
    # iteration — the two rules are suppressed independently
    assert rules("s = {1.0}\nt = sum(x * 2 for x in s)\n") \
        == ["float-sum", "set-iter"]
    assert rules("t = sum([1.0, 2.0])\n") == []


def test_lint_dict_iter_is_strict_only():
    src = "d = {}\nfor k, v in d.items():\n    pass\n"
    assert rules(src) == []
    assert rules(src, strict=True) == ["dict-iter"]


def test_lint_finding_shape():
    f = findings("import time\nt = time.monotonic()\n")[0]
    assert isinstance(f, Finding)
    assert (f.line, f.rule) == (2, "wall-clock")
    assert f.to_dict()["rule"] == "wall-clock"
    assert "wall-clock" in f.format()


def test_lint_sim_critical_scoping():
    assert is_sim_critical(REPO / "src/repro/net/transport.py")
    assert is_sim_critical(REPO / "src/repro/sim/events.py")
    assert not is_sim_critical(REPO / "src/repro/core/instance.py")
    assert not is_sim_critical(REPO / "benchmarks/fig20_spikes.py")


def test_lint_repo_tree_is_clean():
    """The gating check CI runs: zero active findings over src/repro."""
    found, checked = lint_paths([str(REPO / "src/repro")])
    active = [f for f in found if not f.suppressed]
    assert active == [], "\n".join(f.format() for f in active)
    assert checked > 10
    # the waivers written for this PR are present and inventoried
    assert sum(1 for f in found if f.suppressed) >= 5


def test_lint_cli_json(tmp_path, capsys):
    bad = tmp_path / "repro" / "sim" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.monotonic()\n")
    rc = lint_main(["--json", str(bad)])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert out["active"] == 1
    assert out["findings"][0]["rule"] == "wall-clock"
    bad.write_text("x = 1\n")
    assert lint_main([str(bad)]) == 0


# ---------------------------------------------------------------------------
# SimSan: enablement + typed violations at every hook family
# ---------------------------------------------------------------------------

def sanitized_net():
    net, nodes = build_cluster(2, page_elems=128, sanitize=True)
    return net, nodes


def test_simsan_env_switch(monkeypatch):
    monkeypatch.delenv(simsan._ENV, raising=False)
    assert not enabled()
    assert Network(sanitize=None).sanitizer is None
    monkeypatch.setenv(simsan._ENV, "1")
    assert enabled()
    assert Network(sanitize=None).sanitizer is not None
    # explicit False beats the environment
    assert Network(sanitize=False).sanitizer is None


def test_simsan_error_carries_context():
    err = SanitizerError("meter-drift", "dct read n0->n1", meter_bytes=4,
                         expected=8)
    assert isinstance(err, AssertionError)
    assert err.check == "meter-drift"
    assert err.op == "dct read n0->n1"
    assert err.context == {"meter_bytes": 4, "expected": 8}
    assert "[simsan:meter-drift]" in str(err)
    assert "expected=8" in str(err)


def test_simsan_lane_overlap():
    net, _ = sanitized_net()
    san = net.sanitizer
    net.occupy_link("n0", 10.0)     # n0 has node_links lanes; fill them all
    for _ in range(max(1, net.model.node_links) - 1):
        net.occupy_link("n0", 10.0)
    with pytest.raises(SanitizerError) as ei:
        san.link_hold("n0", 5.0, 6.0, "test transfer n0->n1")
    assert ei.value.check == "lane-overlap"
    assert "test transfer" in str(ei.value)
    with pytest.raises(SanitizerError) as ei:
        san.link_hold("n0", 20.0, 19.0, "backwards hold")
    assert ei.value.check == "negative-hold"


def test_simsan_channel_monotonicity():
    net, _ = sanitized_net()
    san = net.sanitizer
    net.set_channel_busy("n0", "n1", 10.0)
    with pytest.raises(SanitizerError) as ei:
        san.channel_hold("n0", "n1", 4.0, 12.0, "overlapping read")
    assert ei.value.check == "channel-overlap"
    with pytest.raises(SanitizerError) as ei:
        san.channel_hold("n0", "n1", 10.0, 9.0, "rewinding read")
    assert ei.value.check in ("channel-backward", "negative-hold")


def test_simsan_meter_drift_names_op():
    """Corrupting the byte meter between charges is caught at the next
    charge, and the error names the charging op."""
    net, _ = sanitized_net()
    t = net.transport_obj("dct")
    t._charge("read", "n0", "n1", 1024, 1e-6)
    net.meter["dct.bytes"] += 17        # out-of-band corruption
    with pytest.raises(SanitizerError) as ei:
        t._charge("read", "n0", "n1", 1024, 1e-6)
    assert ei.value.check == "meter-drift"
    assert "dct read n0->n1" in str(ei.value)
    assert ei.value.context["meter_bytes"] == pytest.approx(
        ei.value.context["expected"] + 17)


def test_simsan_meter_reset_clears_shadow():
    net, _ = sanitized_net()
    t = net.transport_obj("dct")
    t._charge("read", "n0", "n1", 512, 1e-6)
    net.reset_meter()
    t._charge("read", "n0", "n1", 256, 1e-6)    # must not raise
    assert net.meter["dct.bytes"] == 256


def test_simsan_retry_payload_conservation():
    net, _ = sanitized_net()
    san = net.sanitizer
    net.meter["dct.bytes"] = 100
    san.retry_conserved("dct", 100, "dct read retry n0->n1")
    net.meter["dct.bytes"] = 164        # a faulted attempt moved bytes
    with pytest.raises(SanitizerError) as ei:
        san.retry_conserved("dct", 100, "dct read retry n0->n1")
    assert ei.value.check == "retry-payload"


def test_simsan_payload_conservation():
    net, _ = sanitized_net()
    san = net.sanitizer
    wire = np.zeros((4, 128), np.float32)
    san.tag_payload(wire, "dct", rows=4, nbytes=4 * 128 * 4)
    with pytest.raises(SanitizerError) as ei:
        san.adopt_payload(wire, rows=3, row_bytes=128 * 4, op="adopt w@n0")
    assert ei.value.check == "payload-conservation"
    assert ei.value.context["wire_rows"] == 4
    # untagged arrays (cache hits, RPC replies) pass through untouched
    san.adopt_payload(np.zeros((2, 128), np.float32), rows=2,
                      row_bytes=128 * 4, op="adopt cachehit")
    # a correctly adopted tag is consumed
    san.tag_payload(wire, "dct", rows=4, nbytes=4 * 128 * 4)
    san.adopt_payload(wire, rows=4, row_bytes=128 * 4, op="adopt w@n0")
    assert san.stats()["pending_payloads"] == 0


def test_simsan_evicted_conn_use():
    net, _ = sanitized_net()
    t = net.transport_obj("dct")
    net.conns.acquire(t, "n0", "n1", user="i0")
    conn = net.conns.conns[("dct", "dci", "n0")]
    net.conns.evict(conn)
    with pytest.raises(SanitizerError) as ei:
        net.conns._touch(conn, None)
    assert ei.value.check == "evicted-conn-use"


def test_simsan_refcount_corruption():
    net, _ = sanitized_net()
    t = net.transport_obj("dct")
    net.conns.acquire(t, "n0", "n1", user="i0")
    key = ("dct", "dci", "n0")
    # index says "ghost" holds a reference; the conn disagrees
    net.conns._user_index["ghost"] = {key}
    with pytest.raises(SanitizerError) as ei:
        net.sanitizer.check_conns(net.conns, "audit")
    assert ei.value.check == "refcount-dangling"


def test_simsan_conn_slot_corruption():
    net, _ = sanitized_net()
    t = net.transport_obj("dct")
    net.conns.acquire(t, "n0", "n1", user="i0")
    key = ("dct", "tgt", "n1")
    # rip the pool slot out from under a live connection
    net.conns.pools["n1"].remove(key)
    with pytest.raises(SanitizerError) as ei:
        net.sanitizer.check_conns(net.conns, "audit")
    assert ei.value.check == "conn-slot-missing"


def test_simsan_lease_edges():
    net, _ = sanitized_net()
    san = net.sanitizer
    san.lease_register("n0", 1)
    with pytest.raises(SanitizerError) as ei:
        san.lease_register("n0", 1)     # id reused while live
    assert ei.value.check == "lease-edge"
    san.lease_renew("n0", 1)
    san.lease_reclaim("n0", 1)
    with pytest.raises(SanitizerError) as ei:
        san.lease_renew("n0", 1)        # renewing a reclaimed lease
    assert ei.value.check == "lease-edge"
    assert ei.value.context["state"] == "reclaimed"
    with pytest.raises(SanitizerError):
        san.lease_revoke("n0", 2)       # never registered
    san.lease_register("n0", 1)         # reclaimed ids may be reused


def test_simsan_lease_crash_edge():
    net, _ = sanitized_net()
    san = net.sanitizer
    san.lease_register("n0", 1)
    san.node_crashed("n0")
    with pytest.raises(SanitizerError) as ei:
        san.lease_renew("n0", 1)
    assert ei.value.context["state"] == "reclaimed"


def test_simsan_parent_lost_exactly_once():
    net, _ = sanitized_net()
    san = net.sanitizer
    san.parent_lost("f", "n1")
    with pytest.raises(SanitizerError) as ei:
        san.parent_lost("f", "n1")
    assert ei.value.check == "parent-lost-twice"
    # a re-registered node is a fresh incarnation: counting again is legal
    san.node_registered("n1")
    san.parent_lost("f", "n1")


def _spike_engine(sanitize, tiebreak_seed=None):
    fn = SimFunction("spike", state_bytes=16 * 128 * 4, touch_frac=0.1,
                     exec_s=0.030, coldstart_s=0.167, hold_s=60.0)
    net, nodes = build_cluster(8, page_elems=128, sanitize=sanitize)
    return ReplayEngine(spike_660323(scale=1), ForkOnDemand(replicas=2),
                        [fn], network=net, nodes=nodes, seed=11,
                        page_elems=128, tiebreak_seed=tiebreak_seed)


def test_simsan_replay_is_digest_identical():
    """The sanitizer observes; it never perturbs clocks or meters."""
    plain = _spike_engine(sanitize=False).run().summary()
    eng = _spike_engine(sanitize=True)
    sanitized = eng.run().summary()
    assert sanitized == plain
    stats = eng.net.sanitizer.stats()
    assert stats["checks"] > 100        # the hooks actually ran
    assert stats["pending_payloads"] == 0


# ---------------------------------------------------------------------------
# event-loop priorities + seeded tiebreak shuffling
# ---------------------------------------------------------------------------

def test_eventloop_priority_orders_same_time_events():
    loop = EventLoop()
    out = []
    loop.at(1.0, out.append, "gc", priority=10)
    loop.at(1.0, out.append, "arrival", priority=0)
    loop.at(1.0, out.append, "sample", priority=20)
    loop.at(1.0, out.append, "arrival2", priority=0)
    loop.run()
    assert out == ["arrival", "arrival2", "gc", "sample"]


def test_eventloop_tiebreak_shuffles_within_class_only():
    def order(ts):
        loop = EventLoop(tiebreak_seed=ts)
        out = []
        for i in range(8):
            loop.at(1.0, out.append, f"a{i}", priority=0)
        loop.at(1.0, out.append, "z", priority=5)
        loop.run()
        return out
    base = order(None)
    assert base == [f"a{i}" for i in range(8)] + ["z"]
    shuffled = [order(s) for s in range(1, 6)]
    assert any(s != base for s in shuffled), \
        "five seeds never permuted an 8-way tie"
    for s in shuffled:
        assert s[-1] == "z"             # cross-priority order is pinned
        assert sorted(s[:-1]) == sorted(base[:-1])


# ---------------------------------------------------------------------------
# race detector
# ---------------------------------------------------------------------------

def _planted_race_run(tiebreak_seed, *, cascade=False):
    """Two same-(time, priority) handlers whose ORDER changes the result
    (last write wins); with ``cascade`` the winner also schedules extra
    work, so the event log itself diverges."""
    loop = EventLoop(tiebreak_seed=tiebreak_seed)
    state = {}

    def write(v):
        first = "winner" not in state
        state["winner"] = v
        # only a FIRST-running "b" spawns the follow-up, so the schedule
        # itself (not just the result) depends on dispatch order
        if cascade and first and v == "b":
            loop.after(1.0, lambda: None, label="b-followup")
    loop.at(1.0, write, "a", label="write-a")
    loop.at(1.0, write, "b", label="write-b")
    loop.run()
    return list(loop.log), {"winner": state["winner"],
                            "event_log_digest": "ignored"}


def test_race_detector_finds_planted_race():
    report = compare_runs(lambda ts: _planted_race_run(ts),
                          seeds=range(1, 6))
    assert report.racy
    assert report.changed_keys == ["winner"]
    assert report.racy_seed in range(1, 6)
    assert "RACE" in report.describe()
    # same dispatched-label multiset at t=1 -> the log view CANNOT see
    # this one; the semantic summary is what catches it
    assert report.first_divergence is None


def test_race_detector_pinpoints_log_divergence():
    report = compare_runs(
        lambda ts: _planted_race_run(ts, cascade=True), seeds=range(1, 6))
    assert report.racy
    d = report.first_divergence
    assert d is not None
    assert d["time"] == 2.0
    assert "b-followup" in d["baseline"] + d["shuffled"]


def test_race_detector_race_free_negative():
    def commutative(ts):
        loop = EventLoop(tiebreak_seed=ts)
        acc = []
        for i in range(6):
            loop.at(1.0, acc.append, i, label=f"add{i}")
        loop.run()
        return list(loop.log), {"total": sum(acc),
                                "event_log_digest": "ignored"}
    report = compare_runs(commutative, seeds=range(1, 6))
    assert not report.racy
    assert "race-free" in report.describe()
    assert report.to_dict()["racy"] is False


def test_first_log_divergence_groups_by_time():
    a = [(1.0, "x"), (1.0, "y"), (2.0, "z")]
    b = [(1.0, "y"), (1.0, "x"), (2.0, "z")]      # reorder within t=1: fine
    assert first_log_divergence(a, b) is None
    c = [(1.0, "x"), (1.0, "y"), (2.0, "w")]
    d = first_log_divergence(a, c)
    assert d == {"time": 2.0, "baseline": ["z"], "shuffled": ["w"]}
    # one log simply ends early
    d = first_log_divergence(a, a[:-1])
    assert d is not None and d["time"] == 2.0


def test_semantic_summary_strips_log_digest():
    s = {"invocations": 3, "event_log_digest": "abc"}
    assert semantic_summary(s) == {"invocations": 3}


def test_race_detector_on_replay_engine():
    """The real replay stack is race-free under tiebreak shuffling (the
    CI smoke runs the bigger fig20-style version of this)."""
    report = detect(lambda ts: _spike_engine(sanitize=False,
                                             tiebreak_seed=ts),
                    seeds=(1, 2))
    assert not report.racy, report.describe()
