"""Fault plane: plan determinism, transport retry/backoff, crash teardown,
the recovery chain (sibling -> re-seed -> typed failure), mid-fan-out parent
crashes, and exactly-once parent-loss accounting.

The seeded chaos property at the bottom needs hypothesis (skipped locally,
installed by the CI chaos job).
"""
import jax
import numpy as np
import pytest

from repro.core.instance import ModelInstance
from repro.net import (AuthError, Network, NodeDown, RecoveryFailed,
                       ReproError, RetriesExhausted, SeedGone, TransportError)
from repro.net.model import NetModel
from repro.fork import ForkPolicy
from repro.fork.tree import build_fork_tree
from repro.platform.coordinator import Coordinator, FunctionDef
from repro.platform.node import NodeRuntime
from repro.sim import (Crash, FaultInjector, FaultPlan, Flap, ForkOnDemand,
                       ReplayEngine, SimFunction, Trace, build_cluster)
from tests.conftest import FakeClock

ALWAYS = 1e9      # a flap window covering every sim time the tests reach


def _install(net, **plan_kw) -> FaultInjector:
    inj = FaultInjector(net, FaultPlan(**plan_kw))
    net.faults = inj
    return inj


def _fork_pair(net, nodes, cfg, params, lazy=True):
    """Parent instance + handle on nodes[0], lazy child on nodes[1]."""
    parent = ModelInstance.create(nodes[0], cfg.name, params, kind="weights")
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(lazy=lazy, prefetch=0))
    return parent, handle, child


# ---------------------------------------------------------------------------
# FaultPlan: pure data, seeded, validated
# ---------------------------------------------------------------------------

def test_fault_plan_random_is_deterministic():
    ids = [f"n{i}" for i in range(16)]
    kw = dict(crash_rate=0.25, flap_rate=0.5, degrade_rate=0.25,
              op_fail_rate=0.05)
    a = FaultPlan.random(7, ids, 600.0, **kw)
    b = FaultPlan.random(7, ids, 600.0, **kw)
    assert a == b and a.describe() == b.describe()
    assert a != FaultPlan.random(8, ids, 600.0, **kw)
    # events land inside the middle 80% of the run, on cluster nodes
    for c in a.crashes:
        assert 60.0 <= c.t <= 540.0 and c.node in ids
    # all-zero rates generate exactly the empty plan
    assert FaultPlan.random(7, ids, 600.0).empty()
    assert not a.empty()


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(op_fail_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(flaps=(Flap(5.0, 5.0, "n0"),))
    from repro.sim import Degrade
    with pytest.raises(ValueError):
        FaultPlan(degrades=(Degrade(0.0, 1.0, "n0", 0.0),))


def test_error_taxonomy_kinds():
    # every typed error carries a stable machine-readable kind and keeps
    # its pre-taxonomy builtin base, so old except clauses still catch it
    assert issubclass(NodeDown, TransportError)
    assert issubclass(TransportError, ConnectionError)
    assert issubclass(RetriesExhausted, TransportError)
    assert issubclass(AuthError, PermissionError)
    assert issubclass(SeedGone, KeyError)
    assert issubclass(RecoveryFailed, ReproError)
    kinds = {NodeDown.kind, RetriesExhausted.kind, RecoveryFailed.kind,
             AuthError.kind, SeedGone.kind, TransportError.kind}
    assert len(kinds) == 6          # discriminators are distinct


def test_auth_and_renew_raise_typed(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = ModelInstance.create(nodes[0], hello_cfg.name, hello_params,
                                  kind="weights")
    handle = nodes[0].prepare_fork(parent)
    with pytest.raises(AuthError) as ei:
        nodes[0].auth_seed(handle.handler_id, handle.auth_key ^ 1)
    assert ei.value.kind == "bad_credentials"
    with pytest.raises(SeedGone):
        nodes[0].renew_seed(handle.handler_id + 999)


# ---------------------------------------------------------------------------
# Transport robustness: timeout / retry / backoff, per-backend semantics
# ---------------------------------------------------------------------------

def test_flap_window_is_time_pure(cluster):
    net, nodes = cluster
    inj = _install(net, flaps=(Flap(2.0, 5.0, "node1"),))
    assert not inj.flapped("node1")
    net.sim_time = 3.0              # a handler-local clock mid-window
    assert inj.flapped("node1") and inj.dark("node1")
    net.sim_time = 5.0              # windows are half-open [t0, t1)
    assert not inj.dark("node1")


def test_crash_darkness_precedes_the_crash_event(cluster):
    # the data plane must see a node dark the moment the handler-local
    # clock passes the crash instant, even before the crash EVENT (the
    # control-plane teardown) has dispatched on the loop
    net, nodes = cluster
    inj = _install(net, crashes=(Crash(4.0, "node2"),))
    assert not inj.dark("node2")
    net.sim_time = 4.0
    assert inj.dark("node2")
    assert "node2" in net.nodes     # teardown has NOT run — only darkness


def test_retries_exhausted_meters_and_backoff(cluster, hello_cfg,
                                              hello_params):
    net, nodes = cluster            # default transport: dct (max_retries=3)
    parent, handle, child = _fork_pair(net, nodes, hello_cfg, hello_params)
    _install(net, flaps=(Flap(0.0, ALWAYS, "node0"),))
    t0 = net.sim_time
    bytes0 = net.meter["dct.bytes"]     # the resume's descriptor fetch
    with pytest.raises(RecoveryFailed) as ei:
        child.ensure_all()
    # the chain bottomed out on the transport's typed give-up
    assert isinstance(ei.value.__cause__, RetriesExhausted)
    m = net.meter
    retries_cfg = net.transport_obj("dct").max_retries
    assert m["dct.timeouts"] == m["timeouts"] == retries_cfg + 1
    assert m["dct.retries"] == m["retries"] == retries_cfg
    # each failed attempt held the lanes for the op timeout, each retry
    # backed off linearly — and moved zero payload bytes
    model = net.model
    waited = (retries_cfg + 1) * model.op_timeout_s \
        + model.retry_backoff_s * sum(range(1, retries_cfg + 1))
    assert net.sim_time - t0 == pytest.approx(waited)
    assert m["backoff_wait_s"] == pytest.approx(
        model.retry_backoff_s * sum(range(1, retries_cfg + 1)))
    assert m["page_pages_moved"] == 0 and m["dct.bytes"] == bytes0


def test_rc_flap_tears_down_and_reestablishes(hello_cfg, hello_params):
    net = Network(transport="rc")
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
    parent, handle, child = _fork_pair(net, nodes, hello_cfg, hello_params)
    child.fetch_pages(child.leaf_names[0], np.array([0]))   # warm the QP
    setups0 = net.meter["rc.setups"]
    # flap long enough to eat exactly one attempt: the first retry lands
    # past the window edge and succeeds
    t0 = net.sim_time
    _install(net, flaps=(Flap(t0, t0 + 0.5 * net.model.op_timeout_s,
                              "node0"),))
    child.ensure_all()
    m = net.meter
    assert m["rc.timeouts"] == 1 and m["rc.retries"] == 1
    # RC semantics: the timed-out QP went to the error state — torn down at
    # both endpoints, and the retry re-paid establishment as churn
    assert m["rc.conn_faulted"] == 1
    assert m["rc.conn_reestablished"] >= 1
    assert m["rc.setups"] > setups0
    # the recovered read really moved the pages
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(
            child.materialize_pytree())[0]).ravel(),
        np.asarray(jax.tree_util.tree_leaves(
            parent.materialize_pytree())[0]).ravel())


def test_dct_flap_retries_without_conn_churn(cluster, hello_cfg,
                                             hello_params):
    net, nodes = cluster
    parent, handle, child = _fork_pair(net, nodes, hello_cfg, hello_params)
    t0 = net.sim_time
    _install(net, flaps=(Flap(t0, t0 + 0.5 * net.model.op_timeout_s,
                              "node0"),))
    child.ensure_all()
    m = net.meter
    assert m["dct.timeouts"] == 1 and m["dct.retries"] == 1
    # DC contexts survive an op timeout: retries are cheap, no teardown
    assert m["dct.conn_faulted"] == 0


def test_rpc_fails_over_immediately(cluster):
    net, nodes = cluster
    _install(net, flaps=(Flap(0.0, ALWAYS, "node1"),))
    assert net.transport_obj("rpc").max_retries == 0
    with pytest.raises(RetriesExhausted) as ei:
        net.rpc("node0", "node1", 64, lambda: None, transport="rpc")
    assert ei.value.kind == "retries_exhausted"
    assert net.meter["rpc.timeouts"] == 1 and net.meter["rpc.retries"] == 0


def test_empty_plan_injector_perturbs_nothing(hello_cfg, hello_params):
    def run(install_empty):
        net = Network()
        nodes = [NodeRuntime(f"node{i}", net, page_elems=1024)
                 for i in range(2)]
        if install_empty:
            _install(net)
        parent, handle, child = _fork_pair(net, nodes, hello_cfg,
                                           hello_params)
        child.ensure_all()
        return net.sim_time, dict(net.meter)
    assert run(False) == run(True)


# ---------------------------------------------------------------------------
# Node.crash(): both-endpoint slot release, peer cache drop, idempotency
# ---------------------------------------------------------------------------

def test_crash_releases_conns_and_peer_caches(hello_cfg, hello_params):
    net = Network(transport="rc")
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024,
                         cache_enabled=True) for i in range(3)]
    parent, handle, child = _fork_pair(net, nodes, hello_cfg, hello_params)
    child.ensure_all()
    # the child's sibling cache holds entries keyed on the parent, and a
    # live QP occupies a slot in BOTH endpoints' pools
    assert any(k[0] == "node0" for k in nodes[1]._page_cache)
    assert any("node0" in c.nodes for c in net.conns.conns.values())

    nodes[0].crash()
    assert "node0" not in net.nodes
    assert nodes[0].memory_bytes() == 0
    # every connection with a slot on the dead node is gone from every
    # pool — the peer re-pays setup, it does not talk to a ghost QP
    assert "node0" not in net.conns.pools
    assert not any("node0" in c.nodes for c in net.conns.conns.values())
    # surviving peers forgot every cache entry keyed on the dead node
    assert not any(k[0] == "node0" for k in nodes[1]._page_cache)
    # its seed registry emptied: the handle reads dead, and a second
    # crash is a no-op
    assert not handle.alive and nodes[0].seeds == {}
    nodes[0].crash()
    assert "node0" not in net.nodes


def test_crash_mid_read_surfaces_typed_failure(cluster, hello_cfg,
                                               hello_params):
    net, nodes = cluster
    parent, handle, child = _fork_pair(net, nodes, hello_cfg, hello_params)
    nodes[0].crash()
    # no router, no coordinator hook: the chain must end in a TYPED error
    # (callers degrade to coldstart), never a hang or a KeyError
    with pytest.raises(RecoveryFailed) as ei:
        child.ensure_all()
    assert ei.value.kind == "recovery_failed"
    assert isinstance(ei.value.__cause__, NodeDown)


# ---------------------------------------------------------------------------
# Mid-fan-out parent crash: the tree guard must not leak
# ---------------------------------------------------------------------------

def test_fan_out_parent_crash_leaks_nothing(cluster, hello_cfg,
                                            hello_params):
    net, nodes = cluster
    parent = ModelInstance.create(nodes[0], hello_cfg.name, hello_params,
                                  kind="weights")
    handle = nodes[0].prepare_fork(parent)

    def targets():
        yield nodes[1]
        nodes[0].crash()            # parent fail-stops mid-fan-out
        yield nodes[2]

    with pytest.raises(NodeDown):
        build_fork_tree(handle, targets(), tree_degree=2)
    # the guard reclaimed the partial tree: the already-forked child is
    # freed, no re-seed SeedEntry survives, and no DC target dangles
    assert nodes[1].instances == {} and nodes[1].seeds == {}
    assert net._dc_targets == {}


def test_fan_out_reseed_crash_reclaims_reseeds(cluster, hello_cfg,
                                               hello_params):
    net, nodes = cluster
    parent = ModelInstance.create(nodes[0], hello_cfg.name, hello_params,
                                  kind="weights")
    handle = nodes[0].prepare_fork(parent)

    def targets():
        yield nodes[1]
        yield nodes[2]              # root quota (=degree) exhausted here
        yield nodes[3]              # forces promotion: re-seed on node1
        nodes[1].crash()            # ...which then fail-stops
        yield nodes[2]              # served by the dead re-seed -> NodeDown

    with pytest.raises(NodeDown):
        build_fork_tree(handle, targets(), tree_degree=2)
    # only the root's SeedEntry (and its DC targets) survive the close
    assert len(nodes[0].seeds) == 1
    assert all(n.seeds == {} for n in nodes[2:])
    assert all(nid == "node0" for nid, _ in net._dc_targets)
    # surviving children were freed by the guard, nothing orphaned
    assert all(n.instances == {} for n in nodes[2:])


# ---------------------------------------------------------------------------
# Recovery chain through the platform (sibling -> re-seed -> degradation)
# ---------------------------------------------------------------------------

def _mk_platform(hello_cfg, hello_params, n=3, **coord_kw):
    net = Network()
    clock = FakeClock()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, clock=clock)
             for i in range(n)]
    coord = Coordinator(net, nodes, clock=clock, **coord_kw)
    coord.register_function(FunctionDef(
        name="f", arch=hello_cfg.name, make_params=lambda: hello_params,
        behavior=lambda inst, ctx: {"ok": True}))
    return net, nodes, coord


def test_sibling_reroute_off_lost_parent(hello_cfg, hello_params):
    # rung 1 in its usual form: the Router consults membership BEFORE each
    # hop-1 read, so a lost owner's share is re-planned onto the sibling
    # replica proactively — the reads never even fail
    net, nodes, coord = _mk_platform(hello_cfg, hello_params, n=4,
                                     seed_replicas=2, reroute_backlog=0.05)
    seed = coord.deploy_seed("f", replicas=2)
    spare = next(n for n in nodes if n.node_id not in seed.parent_nodes)
    inst = coord.acquire_instance("f", node=spare, policy="fork")
    victim = inst.aspace[inst.leaf_names[0]].ancestry[0]
    coord.nodes[victim].crash()
    inst.ensure_all()
    assert net.meter["reroutes"] >= 1
    assert net.meter["recovery.reseed"] == 0    # sibling served everything
    assert all(v.ancestry[0] != victim for v in inst.aspace.values())
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(
            inst.materialize_pytree())[0]).ravel(),
        np.asarray(jax.tree_util.tree_leaves(hello_params)[0]).ravel())


def test_recovery_sibling_rung_restamps_stale_stamp(hello_cfg,
                                                    hello_params):
    # rung 1 in its defensive form (`recovery.sibling`): a re-routed plan
    # whose VMA stamp lags behind (lazy re-stamp) fails its read against
    # the dead owner — the recovery chain's router sync must re-point the
    # stamp at the sibling and refetch only the still-missing pages
    net, nodes, coord = _mk_platform(hello_cfg, hello_params, n=4,
                                     seed_replicas=2, reroute_backlog=0.05)
    seed = coord.deploy_seed("f", replicas=2)
    spare = next(n for n in nodes if n.node_id not in seed.parent_nodes)
    inst = coord.acquire_instance("f", node=spare, policy="fork")
    name = inst.leaf_names[0]
    vma = inst.aspace[name]
    victim = vma.ancestry[0]
    coord.nodes[victim].crash()
    # the plan already moved off the lost owner (another VMA's fault
    # triggered the replan); this VMA's stamp still points at the ghost
    inst.router.plan.reroute(victim, inst.router._fallback_plan(victim))
    plist = np.nonzero(vma.missing_mask())[0]
    inst._recover_group(vma, victim, plist, NodeDown(victim), depth=0)
    assert net.meter["recovery.sibling"] == 1
    assert net.meter["recovery.pages"] == plist.size
    assert vma.ancestry[0] != victim
    assert not vma.missing_mask()[plist].any()
    # idempotent: nothing left to recover, re-touching moves no more bytes
    before = net.meter["recovery.bytes"]
    inst.ensure_all()
    assert net.meter["recovery.bytes"] == before
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(
            inst.materialize_pytree())[0]).ravel(),
        np.asarray(jax.tree_util.tree_leaves(hello_params)[0]).ravel())


def test_recovery_reseed_from_coordinator(hello_cfg, hello_params):
    net, nodes, coord = _mk_platform(hello_cfg, hello_params, n=3,
                                     seed_replicas=2)
    seed = coord.deploy_seed("f", replicas=2)
    spare = next(n for n in nodes if n.node_id not in seed.parent_nodes)
    inst = coord.acquire_instance("f", node=spare, policy="fork")
    for nid in list(seed.parent_nodes):
        coord.nodes[nid].crash()    # EVERY replica dies mid-execution
    inst.ensure_all()               # rung 2: coordinator redeploys + restamps
    assert net.meter["recovery.reseed"] >= 1
    assert net.meter["recovery.reseed_fetches"] >= 1
    assert coord.lease_telemetry["f"]["reseeded"] == 1
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(
            inst.materialize_pytree())[0]).ravel(),
        np.asarray(jax.tree_util.tree_leaves(hello_params)[0]).ravel())


def test_recovery_exhausts_to_typed_failure(hello_cfg, hello_params):
    # no auto-seed, no replicas: both rungs come up empty and the chain
    # must surface RecoveryFailed (the engine's cue to degrade to cold)
    net, nodes, coord = _mk_platform(hello_cfg, hello_params,
                                     auto_seed=False)
    coord.deploy_seed("f", node=nodes[0])
    inst = coord.acquire_instance("f", node=nodes[1], policy="fork")
    nodes[0].crash()
    with pytest.raises(RecoveryFailed):
        inst.ensure_all()


# ---------------------------------------------------------------------------
# parent_lost accounting: each lost replica counts exactly once
# ---------------------------------------------------------------------------

def _lost(coord):
    return coord.lease_telemetry.get("f", {}).get("parent_lost", 0)


def test_parent_lost_once_plain_acquire(hello_cfg, hello_params):
    net, nodes, coord = _mk_platform(hello_cfg, hello_params)
    coord.deploy_seed("f", node=nodes[0])
    nodes[0].crash()
    inst = coord.acquire_instance("f", node=nodes[1], policy="fork")
    assert inst.ancestry == []      # degraded to coldstart
    assert _lost(coord) == 1
    # later passes must not re-attribute the same loss — in any bucket
    coord.gc()
    coord.acquire_instance("f", node=nodes[1], policy="fork")
    assert _lost(coord) == 1
    assert "reclaimed" not in coord.lease_telemetry["f"]
    assert "expiries" not in coord.lease_telemetry["f"]


def test_parent_lost_once_plain_gc_and_renew(hello_cfg, hello_params):
    net, nodes, coord = _mk_platform(hello_cfg, hello_params)
    coord.deploy_seed("f", node=nodes[0])
    nodes[0].crash()
    coord.renew_seed("f")           # purges, must not count renewals
    coord.gc()
    coord.acquire_instance("f", node=nodes[1], policy="fork")
    assert _lost(coord) == 1
    assert "renewals" not in coord.lease_telemetry["f"]
    assert "f" not in coord.seed_store or coord.seed_store["f"].alive


def test_parent_lost_once_sharded(hello_cfg, hello_params):
    net, nodes, coord = _mk_platform(hello_cfg, hello_params, n=4,
                                     seed_replicas=2)
    seed = coord.deploy_seed("f", replicas=2)
    first, second = seed.parent_nodes
    spare = next(n for n in nodes if n.node_id not in seed.parent_nodes)
    coord.nodes[first].crash()
    inst = coord.acquire_instance("f", node=spare, policy="fork")
    assert inst.ancestry            # still forked, from the survivor
    assert _lost(coord) == 1
    coord.gc()                      # re-purge: no double count, and the
    assert _lost(coord) == 1        # shard set heals back to target
    coord.nodes[second].crash()
    coord.gc()
    assert _lost(coord) == 2
    assert "reclaimed" not in coord.lease_telemetry["f"]


# ---------------------------------------------------------------------------
# Replay integration + seeded chaos property
# ---------------------------------------------------------------------------

def _chaos_replay(plan, seed=7, n_nodes=6, replicas=1):
    trace = Trace("chaos", {"f": (4, 3, 4)})
    fn = SimFunction("f", state_bytes=8 * 1024 * 4, touch_frac=0.5,
                     hold_s=30.0)
    net, nodes = build_cluster(n_nodes, page_elems=1024)
    eng = ReplayEngine(trace, ForkOnDemand(replicas=replicas, prefetch=0),
                       [fn], network=net, nodes=nodes, seed=seed,
                       faults=plan)
    return eng, eng.run()


def test_replay_crash_lands_in_digest_and_rollup():
    plan = FaultPlan(crashes=(Crash(20.0, "n0"), Crash(25.0, "n1")))
    eng, res = _chaos_replay(plan)
    labels = [label for _, label in eng.loop.log]
    assert "fault:crash:n0" in labels and "fault:crash:n1" in labels
    s = res.summary()
    assert s["faults"]["crashes_fired"] == 2
    assert s["faults"]["plan"]["crashes"] == [[20.0, "n0"], [25.0, "n1"]]
    assert 0.0 <= s["faults"]["completion_rate"] <= 1.0


def test_replay_empty_plan_summary_matches_fault_free():
    base = _chaos_replay(None)[1].summary()
    zero = _chaos_replay(FaultPlan())[1].summary()
    assert zero == base             # includes the event-log digest


try:        # only the chaos property needs hypothesis (CI installs it);
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    given = None

if given is not None:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**12),
           crash_rate=st.sampled_from([0.0, 0.2, 0.4]),
           flap_rate=st.sampled_from([0.0, 0.3]),
           op_fail=st.sampled_from([0.0, 0.05]))
    def test_chaos_replay_complete_or_typed(seed, crash_rate, flap_rate,
                                            op_fail):
        """Under ANY seeded fault plan the replay terminates with every
        invocation accounted (completed or typed-failed), payload bytes
        are conserved across retries (failed attempts move nothing), and
        the same seed yields the same run, byte for byte."""
        plan = FaultPlan.random(seed, [f"n{i}" for i in range(6)], 110.0,
                                crash_rate=crash_rate, flap_rate=flap_rate,
                                flap_len_s=20.0, op_fail_rate=op_fail)
        eng, res = _chaos_replay(plan)
        s = res.summary()
        # complete-or-typed: nothing hangs, nothing vanishes
        assert sum(res.decisions.values()) == res.invocations
        if not plan.empty():
            assert s["faults"]["failed"] == res.decisions.get("failed", 0)
        # conservation: the wire meter agrees with the folded per-child
        # stats — a timed-out attempt moved zero pages, a recovered page
        # moved once per successful read (replicas=1, so no eager replica
        # restores pollute the global meter)
        folded = sum(res.payload_pages.get(k, 0)
                     for k in ("pages_rdma", "pages_rpc",
                               "prefetch_wasted"))
        assert eng.net.meter["page_pages_moved"] == folded
        # determinism: same plan, same seed -> bit-identical summary
        assert _chaos_replay(plan)[1].summary() == s
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(the CI chaos job runs this)")
    def test_chaos_replay_complete_or_typed():
        pass
