"""Per-node link capacity in the sim clock (NetModel.node_links), the
async connection-setup fix, channel_wait_s stall metering, Router hot-spot
re-routing, and the placement-aware sharded fork tree.

Invariants pinned here:

* K-way fan-in from one parent queues on that parent's NIC in *sim_time*
  (not just the node_busy ledger), and finishes no earlier than the
  parent-link serialization bound;
* S=1 -> 2 -> 4 seed sharding relieves the bound at equal bytes moved;
* a reroute sweep moves ZERO extra bytes — byte-identical to the static
  plan, only the queueing differs;
* an async read over a COLD connection leaves the clock untouched at
  issue (the stall async prefetch exists to hide).
"""
import jax
import numpy as np
import pytest

from repro.core.instance import ModelInstance
from repro.core.prefetch import issue_fan_in
from repro.fork import ForkPolicy
from repro.net import NetModel, Network
from repro.placement import TransportAwareScheduler, route_demand
from repro.platform.coordinator import Coordinator, FunctionDef
from repro.platform.node import NodeRuntime

from conftest import FakeClock


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _mk_coord(hello_cfg, hello_params, n_nodes=12, node_links=1):
    net = Network(model=NetModel(node_links=node_links))
    clock = FakeClock()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, clock=clock)
             for i in range(n_nodes)]
    coord = Coordinator(net, nodes, clock=clock)
    coord.register_function(FunctionDef(
        name="f", arch=hello_cfg.name,
        make_params=lambda: hello_params,
        behavior=lambda inst, ctx: {"ok": True}))
    return net, nodes, coord


def _issue_all(child):
    """Put one child's entire working set in flight (async)."""
    issue_fan_in([child])


def _heat_link(net, node, seconds_of_pages=4096):
    """Organically occupy ``node``'s link: one large async read from a
    bystander rides the real charge path and backlogs the NIC."""
    frames = node.pool.alloc("float32", seconds_of_pages)
    key = net.create_dc_target(node.node_id)
    net.read_pages("bystander", node.node_id, "float32", frames, key,
                   async_read=True)
    return net.link_backlog(node.node_id)


# ---------------------------------------------------------------------------
# the link clock: fan-in serializes on the parent NIC
# ---------------------------------------------------------------------------


def test_async_fan_in_queues_on_parent_link():
    """K children reading from one parent over K distinct channels used to
    overlap for free; with the link clock their completions stack up."""
    net = Network()
    owner = NodeRuntime("owner", net, page_elems=64)
    key = net.create_dc_target("owner")
    t0 = net.sim_time
    done = []
    for i in range(4):
        frames = owner.pool.alloc("float32", 16)
        net.read_pages(f"child{i}", "owner", "float32", frames, key,
                       async_read=True)
        done.append(net.channel_busy(f"child{i}", "owner"))
    assert all(b > a for a, b in zip(done, done[1:])), \
        "fan-in must queue on the owner link"
    # the serialization bound: last completion >= total wire time served
    assert done[-1] - t0 >= net.node_busy("owner") - 1e-12
    assert net.link_busy_until("owner") == done[-1]


def test_link_clock_disabled_restores_channel_only_overlap():
    net = Network(model=NetModel(node_links=0))
    owner = NodeRuntime("owner", net, page_elems=64)
    key = net.create_dc_target("owner")
    done = []
    for i in range(4):
        frames = owner.pool.alloc("float32", 16)
        net.read_pages(f"child{i}", "owner", "float32", frames, key,
                       async_read=True)
        done.append(net.channel_busy(f"child{i}", "owner"))
    # distinct channels, no link budget: identical wire time each, with
    # only the first paying the (deferred) dct setup
    assert done[1] == done[2] == done[3]
    assert net.link_free("owner") == 0.0 and net.link_backlog("owner") == 0.0


def test_wider_link_admits_parallel_transfers():
    stamps = {}
    for links in (1, 2):
        net = Network(model=NetModel(node_links=links))
        owner = NodeRuntime("owner", net, page_elems=64)
        key = net.create_dc_target("owner")
        # 3 transfers over 2 lanes: lanes drain unevenly, so the makespan
        # (last busy lane) and the next-free stamp genuinely differ
        for i in range(3):
            frames = owner.pool.alloc("float32", 16)
            net.read_pages(f"child{i}", "owner", "float32", frames, key,
                           async_read=True)
        stamps[links] = net.link_busy_until("owner")
        if links > 1:
            assert net.link_free("owner") < net.link_busy_until("owner"), \
                "next-free lane != last-busy lane on a wide link"
    assert stamps[2] < stamps[1], "a 2-lane NIC drains a 3-way fan-in faster"


def test_sync_fan_in_elapsed_meets_serialization_bound(hello_cfg,
                                                       hello_params):
    """K children draining one single-replica seed: sim elapsed >= the
    parent's total wire seconds (its NIC is the only data path)."""
    net, nodes, coord = _mk_coord(hello_cfg, hello_params, n_nodes=6)
    seed = coord.deploy_seed("f", nodes[0])
    children = [seed.resume_on(nodes[1 + i], ForkPolicy(async_prefetch=64))
                for i in range(4)]
    t0, busy0 = net.sim_time, net.node_busy("node0")
    issue_fan_in(children)
    for c in children:
        c.prefetch_engine.drain_all()
    wire = net.node_busy("node0") - busy0
    assert wire > 0
    assert net.sim_time - t0 >= wire - 1e-12
    for c in children:
        got = c.materialize_pytree()
        for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharding_relieves_parent_link_bound(hello_cfg, hello_params):
    """S=1 -> 2 -> 4 replicas at equal bytes: the async fan-in makespan
    (last busy parent link) strictly shrinks as NICs are added."""
    makespan, moved = {}, {}
    for s in (1, 2, 4):
        net, nodes, coord = _mk_coord(hello_cfg, hello_params)
        seed = coord.deploy_seed("f", nodes[0], replicas=s)
        parents = [seed.parent_node] if s == 1 else list(seed.parent_nodes)
        children = [seed.resume_on(nodes[4 + i],
                                   ForkPolicy(async_prefetch=256,
                                              descriptor_fetch="rpc"))
                    for i in range(6)]
        t0, b0 = net.sim_time, net.meter["dct.bytes"]
        issue_fan_in(children)
        makespan[s] = max(net.link_busy_until(p) for p in parents) - t0
        moved[s] = net.meter["dct.bytes"] - b0
    assert moved[1] == moved[2] == moved[4], "working set must not scale with S"
    assert makespan[1] > makespan[2] > makespan[4]


# ---------------------------------------------------------------------------
# channel_wait_s: sync stalls are metered, not absorbed
# ---------------------------------------------------------------------------


def test_sync_stall_on_busy_channel_metered():
    net = Network()
    owner = NodeRuntime("owner", net, page_elems=64)
    key = net.create_dc_target("owner")
    net.set_channel_busy("child", "owner", net.sim_time + 0.5)
    frames = owner.pool.alloc("float32", 4)
    net.read_pages("child", "owner", "float32", frames, key,
                   transport="tpu_ici")     # connectionless: no setup term
    assert net.meter["channel_wait_s"] == pytest.approx(0.5)
    assert "channel_wait_s" in net.snapshot()


def test_sync_stall_behind_hot_link_metered():
    """A sync reader queues behind another child's transfer at the SAME
    owner even though the two ride different channels."""
    net = Network()
    owner = NodeRuntime("owner", net, page_elems=64)
    key = net.create_dc_target("owner")
    backlog = _heat_link(net, owner, 1024)
    assert backlog > 0
    frames = owner.pool.alloc("float32", 4)
    net.read_pages("child", "owner", "float32", frames, key,
                   transport="tpu_ici")
    assert net.meter["channel_wait_s"] == pytest.approx(backlog)


# ---------------------------------------------------------------------------
# async connection setup must not block the clock (satellite regression)
# ---------------------------------------------------------------------------


def test_cold_rc_async_prefetch_leaves_clock_untouched(hello_cfg,
                                                       hello_params):
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
    parent = ModelInstance.create(nodes[0], hello_cfg.name, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(
        async_prefetch=64, page_fetch="rc", descriptor_fetch="rpc"))
    assert not net.has_connection("rc", "node1", "node0")   # still cold
    t0 = net.sim_time
    _issue_all(child)
    # the 4 ms QP connect did NOT stall the child's clock...
    assert net.sim_time == t0
    assert net.meter["rc.setups"] == 1                      # ...but is metered
    # ...and is served on the channel ahead of the payload
    assert net.channel_busy("node1", "node0") > t0 + net.model.rc_setup
    child.prefetch_engine.drain_all()
    assert net.sim_time >= t0 + net.model.rc_setup
    got = child.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Router: load-triggered RoutePlan.reroute
# ---------------------------------------------------------------------------


def _touch_all(child):
    for name in child.leaf_names:
        child.touch_pages(name, np.arange(child.aspace[name].npages))


def _routed_run(hello_cfg, hello_params, reroute_backlog):
    """One S=2 fan-out with parent[0]'s link pre-heated; returns
    (child, sim elapsed, page bytes moved, net)."""
    net, nodes, coord = _mk_coord(hello_cfg, hello_params, n_nodes=6)
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    hot = seed.parent_nodes[0]
    child = seed.resume_on(nodes[4], ForkPolicy(
        descriptor_fetch="rpc", reroute_backlog=reroute_backlog))
    _heat_link(net, coord.nodes[hot], 4096)
    t0, b0 = net.sim_time, net.meter["dct.bytes"]
    _touch_all(child)
    return child, net.sim_time - t0, net.meter["dct.bytes"] - b0, net


def test_reroute_diverts_hot_parent_and_moves_zero_extra_bytes(hello_cfg,
                                                               hello_params):
    static_child, static_s, static_bytes, static_net = _routed_run(
        hello_cfg, hello_params, reroute_backlog=None)
    routed_child, routed_s, routed_bytes, routed_net = _routed_run(
        hello_cfg, hello_params, reroute_backlog=1e-5)
    # the static plan stalls behind the hot NIC (and says so in the meter)
    assert static_child.router is None
    assert static_net.meter["channel_wait_s"] > 0
    # the reroute sweep is byte-identical: same pages, different NIC
    assert routed_bytes == static_bytes
    assert routed_child.router.reroutes > 0
    assert routed_net.meter["reroutes"] == routed_child.router.reroutes
    assert routed_s < static_s, "re-routing must dodge the hot-parent stall"
    got = routed_child.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_router_reroutes_around_crashed_owner(hello_cfg, hello_params):
    """Crash degradation through the same mechanism: a planned owner that
    left the network is infinitely hot, so a routed child's reads divert
    to the surviving replica instead of raising ConnectionError."""
    net, nodes, coord = _mk_coord(hello_cfg, hello_params, n_nodes=6)
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    child = seed.resume_on(nodes[4], ForkPolicy(
        descriptor_fetch="rpc", reroute_backlog=1e-3))
    victim = next(vma.ancestry[0] for vma in child.aspace.values())
    survivor = next(p for p in seed.parent_nodes if p != victim)
    coord.nodes[victim].crash()
    got = child.materialize_pytree()            # no ConnectionError
    assert child.router.reroutes > 0
    assert all(vma.ancestry[0] == survivor or not vma.ancestry
               for vma in child.aspace.values()
               if vma.name in child.router.plan.routes)
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lazy_restamp_never_targets_crashed_owner(hello_cfg, hello_params):
    """A VMA whose plan moved on an EARLIER fault re-stamps lazily; if the
    new owner crashed in between, the Router must re-route again (or keep
    the live stamp) instead of pointing the page table at a dead node."""
    net, nodes, coord = _mk_coord(hello_cfg, hello_params, n_nodes=6)
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    child = seed.resume_on(nodes[4], ForkPolicy(
        descriptor_fetch="rpc", reroute_backlog=1e-5))
    plan = child.router.plan
    by_owner = {}
    for name, r in plan.routes.items():
        by_owner.setdefault(r.owner, []).append(name)
    hot, names = max(by_owner.items(), key=lambda e: len(e[1]))
    assert len(names) >= 2, "need two VMAs planned on one owner"
    vma_a, vma_b = names[0], names[1]
    other = next(o for o in by_owner if o != hot)
    _heat_link(net, coord.nodes[hot], 4096)
    child.touch_pages(vma_a, [0])           # reroutes hot's share to other
    assert child.aspace[vma_a].ancestry[0] == other
    assert plan.routes[vma_b].owner == other    # plan moved...
    assert child.aspace[vma_b].ancestry[0] == hot   # ...stamp lags (lazy)
    coord.nodes[other].crash()              # new owner dies before b faults
    child.touch_pages(vma_b, [0])           # must not raise ConnectionError
    assert child.aspace[vma_b].ancestry[0] == hot, \
        "lazy re-stamp must never target a crashed owner"
    got = child.materialize_pytree()        # everything still serves
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_router_not_attached_without_policy_or_shards(hello_cfg,
                                                      hello_params):
    net, nodes, coord = _mk_coord(hello_cfg, hello_params, n_nodes=6)
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    assert seed.resume_on(nodes[4]).router is None          # no threshold
    coord.register_function(FunctionDef(
        name="g", arch=hello_cfg.name, make_params=lambda: hello_params,
        behavior=lambda inst, ctx: {"ok": True}))
    lone = coord.deploy_seed("g", nodes[1])
    child = lone.resume_on(nodes[5], ForkPolicy())
    assert child.router is None                             # plain handle


def test_coordinator_reroute_backlog_reaches_fork_policy(hello_cfg,
                                                         hello_params):
    net = Network()
    clock = FakeClock()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, clock=clock)
             for i in range(6)]
    coord = Coordinator(net, nodes, clock=clock, reroute_backlog=2e-4)
    coord.register_function(FunctionDef(
        name="f", arch=hello_cfg.name, make_params=lambda: hello_params,
        behavior=lambda inst, ctx: {"ok": True}))
    coord.deploy_seed("f", nodes[0], replicas=2)
    out, inst = coord.invoke("f", node=nodes[4])
    assert out["ok"]
    assert inst.router is not None and inst.router.threshold == 2e-4


# ---------------------------------------------------------------------------
# scheduler: setup estimates dedupe; link backlog scores
# ---------------------------------------------------------------------------


def test_scheduler_setup_estimate_deduped_per_connection():
    """A 40-VMA plan routed to one owner is ONE connection, not 40."""
    net = Network()
    for i in range(3):
        NodeRuntime(f"node{i}", net, page_elems=64)
    sched = TransportAwareScheduler(net)
    one = sched.score("node1", route_demand(["node0"], ["rc"]))
    many = sched.score("node1", route_demand(["node0"], ["rc"]) * 40)
    assert many == one == pytest.approx(net.model.rc_setup)
    # None and the spelled-out default backend are the same connection
    spelled = sched.score("node1", [("node0", None),
                                    ("node0", net.transport)])
    assert spelled == sched.score("node1", [("node0", None)])


def test_scheduler_scores_candidate_link_backlog():
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=64) for i in range(3)]
    _heat_link(net, nodes[1], 1024)         # node1's NIC is busy
    sched = TransportAwareScheduler(net)
    demand = route_demand(["node0"], [None])
    picked = sched.pick({n.node_id: n for n in nodes},
                        exclude={"node0"}, demand=demand)
    assert picked.node_id == "node2", "children avoid a backlogged NIC"


# ---------------------------------------------------------------------------
# placement-aware sharded fork trees
# ---------------------------------------------------------------------------


def test_sharded_fan_out_tree_promotes_reseeds(hello_cfg, hello_params):
    from repro.fork.tree import ForkTree
    net, nodes, coord = _mk_coord(hello_cfg, hello_params, n_nodes=10)
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    targets = [nodes[3 + i] for i in range(6)]
    tree = seed.fan_out(targets, ForkPolicy(descriptor_fetch="rpc"),
                        tree_degree=1)
    assert isinstance(tree, ForkTree) and len(tree) == 6
    # the sharded root serves tree_degree x S children before any promotion
    served = tree.served_by()
    assert served[(seed.parent_node, seed.handler_id)] == 2
    assert tree.seeds and tree.depth() >= 2
    for child in tree.children:
        got = child.materialize_pytree()
        for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tree.close()
    assert all(not h.alive for h in tree.seeds)
    assert seed.alive, "closing the tree never reclaims the root seed"


def test_sharded_fan_out_flat_mode_unchanged(hello_cfg, hello_params):
    net, nodes, coord = _mk_coord(hello_cfg, hello_params, n_nodes=8)
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    children = seed.fan_out([nodes[4], nodes[5]])
    assert isinstance(children, list) and len(children) == 2
