"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cow_scatter.kernel import cow_scatter
from repro.kernels.cow_scatter.ref import cow_scatter_ref
from repro.kernels.page_gather.kernel import page_gather
from repro.kernels.page_gather.ops import page_gather as page_gather_op
from repro.kernels.page_gather.ref import page_gather_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@pytest.mark.parametrize("F,E,n", [(8, 128, 3), (32, 512, 32), (64, 1024, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_page_gather_sweep(F, E, n, dtype):
    key = jax.random.PRNGKey(F * E + n)
    if dtype == jnp.int32:
        frames = jax.random.randint(key, (F, E), 0, 1000)
    else:
        frames = jax.random.normal(key, (F, E), dtype)
    ids = jax.random.randint(key, (n,), 0, F)
    got = page_gather(frames, ids, interpret=True)
    want = page_gather_ref(frames, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_page_gather_duplicate_ids():
    frames = jnp.arange(8 * 128, dtype=jnp.float32).reshape(8, 128)
    ids = jnp.array([5, 5, 5], jnp.int32)
    got = page_gather(frames, ids, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.stack([frames[5]] * 3)))


def test_page_gather_op_backend_switch():
    frames = jnp.arange(4 * 128, dtype=jnp.float32).reshape(4, 128)
    ids = jnp.array([2, 0], jnp.int32)
    for backend in ("auto", "kernel", "ref"):
        got = page_gather_op(frames, ids, backend=backend)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(frames[jnp.asarray(ids)]))


@pytest.mark.parametrize("F,E,n", [(8, 128, 3), (16, 256, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cow_scatter_sweep(F, E, n, dtype):
    key = jax.random.PRNGKey(F + n)
    frames = jax.random.normal(key, (F, E), dtype)
    ids = np.random.default_rng(0).choice(F, size=n, replace=False).astype(np.int32)
    pages = jax.random.normal(jax.random.PRNGKey(1), (n, E), dtype)
    want = cow_scatter_ref(frames, jnp.asarray(ids), pages)
    # kernel donates `frames` (in-place COW commit) — call it last
    got = cow_scatter(frames, jnp.asarray(ids), pages, interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_cow_scatter_leaves_other_frames():
    frames = jnp.ones((6, 128), jnp.float32)
    pages = jnp.zeros((1, 128), jnp.float32)
    got = cow_scatter(frames, jnp.array([3], jnp.int32), pages, interpret=True)
    assert float(got[3].sum()) == 0.0
    assert float(got[0].sum()) == 128.0


@pytest.mark.parametrize("B,K,G,hd,Tp,P,F", [
    (2, 2, 4, 128, 8, 4, 16),
    (1, 1, 8, 128, 16, 2, 8),       # MQA
    (3, 4, 1, 256, 8, 3, 24),       # MHA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, K, G, hd, Tp, P, F, dtype):
    keys = [jax.random.PRNGKey(i) for i in range(5)]
    q = jax.random.normal(keys[0], (B, K, G, hd), dtype)
    pk = jax.random.normal(keys[1], (F, Tp, K, hd), dtype)
    pv = jax.random.normal(keys[2], (F, Tp, K, hd), dtype)
    pt = jax.random.randint(keys[3], (B, P), 0, F)
    vt = jax.random.randint(keys[4], (B, P), 0, F)
    lengths = jax.random.randint(keys[4], (B,), 1, P * Tp + 1)
    got = paged_attention(q, pk, pv, pt, lengths, v_page_table=vt,
                          interpret=True)
    want = paged_attention_ref(q, pk, pv, pt, lengths, v_page_table=vt)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, err


def test_paged_attention_window_starts():
    B, K, G, hd, Tp, P, F = 2, 1, 2, 128, 8, 4, 12
    q = jax.random.normal(jax.random.PRNGKey(0), (B, K, G, hd))
    pk = jax.random.normal(jax.random.PRNGKey(1), (F, Tp, K, hd))
    pv = jax.random.normal(jax.random.PRNGKey(2), (F, Tp, K, hd))
    pt = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0, F)
    lengths = jnp.array([30, 25], jnp.int32)
    starts = jnp.array([10, 0], jnp.int32)
    got = paged_attention(q, pk, pv, pt, lengths, starts=starts, interpret=True)
    want = paged_attention_ref(q, pk, pv, pt, lengths, starts=starts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # and starts matter
    want0 = paged_attention_ref(q, pk, pv, pt, lengths)
    assert float(jnp.abs(want - want0).max()) > 1e-4
