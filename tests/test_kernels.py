"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cow_scatter.kernel import cow_scatter
from repro.kernels.cow_scatter.ref import cow_scatter_ref
from repro.kernels.page_gather.kernel import page_gather
from repro.kernels.page_gather.ops import page_gather as page_gather_op
from repro.kernels.page_gather.ref import page_gather_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@pytest.mark.parametrize("F,E,n", [(8, 128, 3), (32, 512, 32), (64, 1024, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_page_gather_sweep(F, E, n, dtype):
    key = jax.random.PRNGKey(F * E + n)
    if dtype == jnp.int32:
        frames = jax.random.randint(key, (F, E), 0, 1000)
    else:
        frames = jax.random.normal(key, (F, E), dtype)
    ids = jax.random.randint(key, (n,), 0, F)
    got = page_gather(frames, ids, interpret=True)
    want = page_gather_ref(frames, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_page_gather_duplicate_ids():
    frames = jnp.arange(8 * 128, dtype=jnp.float32).reshape(8, 128)
    ids = jnp.array([5, 5, 5], jnp.int32)
    got = page_gather(frames, ids, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.stack([frames[5]] * 3)))


def test_page_gather_op_backend_switch():
    frames = jnp.arange(4 * 128, dtype=jnp.float32).reshape(4, 128)
    ids = jnp.array([2, 0], jnp.int32)
    for backend in ("auto", "kernel", "ref"):
        got = page_gather_op(frames, ids, backend=backend)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(frames[jnp.asarray(ids)]))


@pytest.mark.parametrize("F,E,n", [(8, 128, 3), (16, 256, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cow_scatter_sweep(F, E, n, dtype):
    key = jax.random.PRNGKey(F + n)
    frames = jax.random.normal(key, (F, E), dtype)
    ids = np.random.default_rng(0).choice(F, size=n, replace=False).astype(np.int32)
    pages = jax.random.normal(jax.random.PRNGKey(1), (n, E), dtype)
    want = cow_scatter_ref(frames, jnp.asarray(ids), pages)
    # kernel donates `frames` (in-place COW commit) — call it last
    got = cow_scatter(frames, jnp.asarray(ids), pages, interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_cow_scatter_leaves_other_frames():
    frames = jnp.ones((6, 128), jnp.float32)
    pages = jnp.zeros((1, 128), jnp.float32)
    got = cow_scatter(frames, jnp.array([3], jnp.int32), pages, interpret=True)
    assert float(got[3].sum()) == 0.0
    assert float(got[0].sum()) == 128.0


@pytest.mark.parametrize("B,K,G,hd,Tp,P,F", [
    (2, 2, 4, 128, 8, 4, 16),
    (1, 1, 8, 128, 16, 2, 8),       # MQA
    (3, 4, 1, 256, 8, 3, 24),       # MHA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, K, G, hd, Tp, P, F, dtype):
    keys = [jax.random.PRNGKey(i) for i in range(5)]
    q = jax.random.normal(keys[0], (B, K, G, hd), dtype)
    pk = jax.random.normal(keys[1], (F, Tp, K, hd), dtype)
    pv = jax.random.normal(keys[2], (F, Tp, K, hd), dtype)
    pt = jax.random.randint(keys[3], (B, P), 0, F)
    vt = jax.random.randint(keys[4], (B, P), 0, F)
    lengths = jax.random.randint(keys[4], (B,), 1, P * Tp + 1)
    got = paged_attention(q, pk, pv, pt, lengths, v_page_table=vt,
                          interpret=True)
    want = paged_attention_ref(q, pk, pv, pt, lengths, v_page_table=vt)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, err


def test_paged_attention_window_starts():
    B, K, G, hd, Tp, P, F = 2, 1, 2, 128, 8, 4, 12
    q = jax.random.normal(jax.random.PRNGKey(0), (B, K, G, hd))
    pk = jax.random.normal(jax.random.PRNGKey(1), (F, Tp, K, hd))
    pv = jax.random.normal(jax.random.PRNGKey(2), (F, Tp, K, hd))
    pt = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0, F)
    lengths = jnp.array([30, 25], jnp.int32)
    starts = jnp.array([10, 0], jnp.int32)
    got = paged_attention(q, pk, pv, pt, lengths, starts=starts, interpret=True)
    want = paged_attention_ref(q, pk, pv, pt, lengths, starts=starts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # and starts matter
    want0 = paged_attention_ref(q, pk, pv, pt, lengths)
    assert float(jnp.abs(want - want0).max()) > 1e-4


# -- run-table (extent-run) variants, dispatch, fused assemble/patch ---------

from repro.kernels import dispatch
from repro.kernels.cow_scatter.ops import (cow_scatter as cow_scatter_op,
                                           cow_scatter_runs, scatter_patch)
from repro.kernels.page_gather.kernel import page_gather_runs as _pgr_kernel
from repro.kernels.page_gather.ops import (gather_assemble, page_gather_runs)
from repro.kernels.page_gather.ref import expand_runs

BACKENDS = ("auto", "kernel", "interpret", "jnp", "ref")


def test_expand_runs_matches_concat_of_aranges():
    rng = np.random.default_rng(7)
    for _ in range(20):
        k = int(rng.integers(1, 8))
        starts = rng.integers(0, 100, k)
        lens = rng.integers(0, 6, k)          # zero-length runs included
        want = np.concatenate(
            [np.arange(s, s + l) for s, l in zip(starts, lens)] or
            [np.zeros(0, np.int64)])
        keep = lens > 0
        got = expand_runs(starts[keep], lens[keep])
        np.testing.assert_array_equal(got, want.astype(np.int32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("runs", [
    [(0, 1)],                                  # single-page single-run
    [(3, 4), (10, 2), (20, 1)],                # mixed lengths
    [(12, 1), (4, 1), (30, 1)],                # all singletons, unsorted
    [(0, 8), (16, 8)],                         # uniform long runs
])
def test_page_gather_runs_all_backends(dtype, runs):
    F, E = 40, 128
    key = jax.random.PRNGKey(3)
    if dtype == jnp.int32:
        frames = jax.random.randint(key, (F, E), 0, 1000)
    else:
        frames = jax.random.normal(key, (F, E), dtype)
    starts = np.array([s for s, _ in runs], np.int64)
    lens = np.array([l for _, l in runs], np.int64)
    ids = expand_runs(starts, lens)
    want = np.asarray(frames)[ids]
    for backend in BACKENDS:
        got = page_gather_runs(frames, starts, lens, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"backend={backend}")


def test_page_gather_runs_empty_and_zero_len():
    frames = jnp.arange(8 * 128, dtype=jnp.float32).reshape(8, 128)
    for backend in BACKENDS:
        got = page_gather_runs(frames, [], [], backend=backend)
        assert got.shape == (0, 128)
        # zero-length runs are filtered before the kernel sees them
        got = page_gather_runs(frames, [2, 5], [0, 3], backend=backend)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(frames)[5:8])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cow_scatter_runs_all_backends(dtype):
    F, E = 32, 128
    runs = [(0, 3), (8, 1), (20, 4)]
    starts = np.array([s for s, _ in runs], np.int64)
    lens = np.array([l for _, l in runs], np.int64)
    ids = expand_runs(starts, lens)
    pages = jax.random.normal(jax.random.PRNGKey(1), (ids.size, E), dtype)
    want = None
    for backend in BACKENDS:
        frames = jax.random.normal(jax.random.PRNGKey(0), (F, E), dtype)
        got = np.asarray(cow_scatter_runs(frames, starts, lens, pages,
                                          backend=backend), np.float32)
        if want is None:
            base = np.asarray(frames, np.float32).copy()
            base[ids] = np.asarray(pages, np.float32)
            want = base
        np.testing.assert_array_equal(got, want, err_msg=f"backend={backend}")


def test_cow_scatter_runs_empty():
    frames = jnp.ones((4, 128), jnp.float32)
    for backend in BACKENDS:
        got = cow_scatter_runs(frames, [], [], jnp.zeros((0, 128)),
                               backend=backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(frames))


@pytest.mark.parametrize("shape", [(300,), (3, 129), (1, 1), (257,)])
def test_gather_assemble_matches_manual(shape):
    F, E = 16, 128
    frames = jax.random.normal(jax.random.PRNGKey(2), (F, E))
    size = int(np.prod(shape))
    n = -(-size // E)
    ids = np.random.default_rng(0).choice(F, n, replace=False).astype(np.int32)
    want = np.asarray(frames)[ids].reshape(-1)[:size].reshape(shape)
    for backend in BACKENDS:
        got = gather_assemble(frames, ids, shape, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"backend={backend}")


@pytest.mark.parametrize("shape", [(300,), (5, 70), (256,)])
def test_scatter_patch_matches_manual(shape):
    E = 128
    size = int(np.prod(shape))
    n = -(-size // E)
    rng = np.random.default_rng(1)
    t0 = rng.standard_normal(shape).astype(np.float32)
    ids = rng.choice(n, max(1, n // 2), replace=False).astype(np.int32)
    rows = rng.standard_normal((ids.size, E)).astype(np.float32)
    buf = np.zeros(n * E, np.float32)
    buf[:size] = t0.reshape(-1)
    buf.reshape(n, E)[ids] = rows
    want = buf[:size].reshape(shape)
    for backend in BACKENDS:
        got = scatter_patch(jnp.asarray(t0), ids, jnp.asarray(rows),
                            page_elems=E, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"backend={backend}")


def test_scatter_patch_empty_ids_is_identity():
    t = jnp.arange(10.0)
    got = scatter_patch(t, [], jnp.zeros((0, 128)), page_elems=128)
    assert got is t


def test_dispatch_auto_off_tpu_uses_jnp_and_meters():
    dispatch.reset_meters()
    frames = jnp.arange(4 * 128, dtype=jnp.float32).reshape(4, 128)
    page_gather_op(frames, jnp.array([1, 3], jnp.int32), backend="auto")
    meters = dispatch.kernel_meters()
    if dispatch.kernel_available():
        assert meters.get("kernel.page_gather.pallas", 0) == 1
    else:
        assert meters.get("kernel.page_gather.jnp", 0) == 1
    # drain folds into the caller's Counter and clears the module meter
    from collections import Counter
    sink = Counter()
    dispatch.drain_meters_into(sink)
    assert sum(sink.values()) >= 1
    assert not dispatch.kernel_meters()


def test_dispatch_kernel_off_tpu_warns_and_interprets():
    if dispatch.kernel_available():
        pytest.skip("compiled Pallas available; fallback path not taken")
    frames = jnp.arange(4 * 128, dtype=jnp.float32).reshape(4, 128)
    with pytest.warns(RuntimeWarning):
        dispatch._warned.clear()      # warn-once: re-arm for this test
        got = page_gather_op(frames, jnp.array([0], jnp.int32),
                             backend="kernel")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(frames[:1]))


def test_dispatch_rejects_unknown_backend():
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda", kernel_name="page_gather")


def test_page_gather_runs_kernel_interpret_direct():
    # the raw run-table kernel (scalar-prefetched starts/lens/offs tables)
    F, E = 24, 128
    frames = jax.random.normal(jax.random.PRNGKey(9), (F, E))
    starts = np.array([2, 10, 20], np.int64)
    lens = np.array([4, 1, 3], np.int64)
    offs = np.concatenate([[0], np.cumsum(lens)[:-1]])
    got = _pgr_kernel(frames, jnp.asarray(starts, jnp.int32),
                      jnp.asarray(lens, jnp.int32),
                      jnp.asarray(offs, jnp.int32),
                      max_len=4, n_out=8, interpret=True)
    want = np.asarray(frames)[expand_runs(starts, lens)]
    np.testing.assert_array_equal(np.asarray(got), want)
