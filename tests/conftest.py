import dataclasses

import jax
import pytest

from repro.configs.base import get_arch, reduce_for_smoke
from repro.net import Network
from repro.models import lm
from repro.platform.coordinator import Coordinator, FunctionDef
from repro.platform.node import NodeRuntime


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture()
def cluster():
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(4)]
    return net, nodes


@pytest.fixture()
def platform(hello_cfg, hello_params):
    """A 3-node coordinator cluster on a FakeClock with one function "f"."""
    net = Network()
    clock = FakeClock()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, clock=clock)
             for i in range(3)]
    coord = Coordinator(net, nodes, clock=clock)

    def behavior(inst, ctx):
        inst.ensure_tensor(inst.leaf_names[0])
        return {"ok": True}

    coord.register_function(FunctionDef(
        name="f", arch=hello_cfg.name,
        make_params=lambda: hello_params, behavior=behavior))
    return net, nodes, coord, clock


@pytest.fixture(scope="session")
def smoke_cfg():
    return reduce_for_smoke(get_arch("stablelm-3b"))


@pytest.fixture(scope="session")
def smoke_params(smoke_cfg):
    return lm.init_params(jax.random.PRNGKey(0), smoke_cfg)


@pytest.fixture(scope="session")
def hello_cfg():
    return dataclasses.replace(get_arch("micro-hello"), compute_dtype="float32")


@pytest.fixture(scope="session")
def hello_params(hello_cfg):
    return lm.init_params(jax.random.PRNGKey(0), hello_cfg)
