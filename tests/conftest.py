import dataclasses

import jax
import pytest

from repro.configs.base import get_arch, reduce_for_smoke
from repro.core.network import Network
from repro.models import lm
from repro.platform.node import NodeRuntime


@pytest.fixture()
def cluster():
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(4)]
    return net, nodes


@pytest.fixture(scope="session")
def smoke_cfg():
    return reduce_for_smoke(get_arch("stablelm-3b"))


@pytest.fixture(scope="session")
def smoke_params(smoke_cfg):
    return lm.init_params(jax.random.PRNGKey(0), smoke_cfg)


@pytest.fixture(scope="session")
def hello_cfg():
    return dataclasses.replace(get_arch("micro-hello"), compute_dtype="float32")


@pytest.fixture(scope="session")
def hello_params(hello_cfg):
    return lm.init_params(jax.random.PRNGKey(0), hello_cfg)
