"""Fleet-scale cluster construction: O(n) build, lazy link state, and
amortized pool growth — what lets replays run thousands of sim nodes."""
import time

import numpy as np

import repro.memory.pool as pool_mod
from benchmarks.common import make_cluster
from repro.memory.pool import PagePool


def test_make_cluster_builds_1000_nodes_with_sim_clock():
    net, nodes = make_cluster(1000, clock="sim")
    assert len(nodes) == 1000
    # per-node lane ledgers and per-pair channels are lazy: none exist
    # before any traffic, so construction does no O(n^2) wiring
    assert len(net._link_busy) == 0
    assert nodes[0].clock() == net.sim_time
    net.sim_time = 42.0
    assert nodes[-1].clock() == 42.0


def _build_time(n):
    t0 = time.perf_counter()
    make_cluster(n, clock="sim")
    return time.perf_counter() - t0


def test_cluster_build_time_is_sublinear_in_pairs():
    """t(4x nodes) must stay near 4x t(x) — quadratic (per-pair) setup
    would make it ~16x.  Generous bound for CI noise."""
    t200 = min(_build_time(200) for _ in range(3))
    t800 = min(_build_time(800) for _ in range(3))
    assert t800 / max(t200, 1e-9) < 10.0


def test_pool_growth_is_amortized(monkeypatch):
    """Allocating N frames one at a time triggers O(log N) pool copies
    (geometric growth), not O(N / grow_frames)."""
    calls = []
    real = np.concatenate

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(pool_mod.np, "concatenate", counting)
    pool = PagePool(page_elems=64)
    n = 4000
    for _ in range(n):
        pool.alloc("float32", 1)
    assert pool.num_allocated("float32") == n
    assert len(calls) <= int(np.log2(n)) + 2


def test_initial_frames_reserve_skips_growth_copies(monkeypatch):
    calls = []
    real = np.concatenate

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(pool_mod.np, "concatenate", counting)
    pool = PagePool(page_elems=64, initial_frames=4096)
    for _ in range(4096):
        pool.alloc("float32", 1)
    assert not calls                     # the reserve absorbed every alloc
