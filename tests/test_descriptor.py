"""Descriptor codec + pytree path utilities."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.descriptor import (Descriptor, flatten_with_names,
                                   unflatten_from_paths)


def test_flatten_unflatten_nested():
    tree = {"a": {"b": [jnp.ones(2), jnp.zeros(3)]},
            "c": [{"d": jnp.full(4, 7.0)}]}
    names, paths, leaves = flatten_with_names(tree)
    rebuilt = unflatten_from_paths(paths, leaves)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert "a/b/0" in names and "c/0/d" in names


def test_descriptor_roundtrip_and_size():
    d = Descriptor(
        arch="micro", kind="weights", parent_node="node0", handler_id=3,
        ancestry=["node1", "node2"],
        leaf_paths=[["a", 0], ["b"]],
        vmas=[{"name": "a/0", "shape": [2, 2], "dtype": "float32",
               "npages": 1, "owner_hop": b"\x00", "frames": b"\x01\x00\x00\x00",
               "dc_keys": {1: 5}}],
        registers={"step": 7, "rng": np.arange(2, dtype=np.uint32)},
        extra={"prepared_keys": {"a/0": 9}, "leaf_names": ["a/0", "b"]},
    )
    blob = d.to_bytes()
    e = Descriptor.from_bytes(blob)
    assert e.arch == "micro" and e.handler_id == 3
    assert e.ancestry == ["node1", "node2"]
    assert e.registers["step"] == 7
    np.testing.assert_array_equal(e.registers["rng"], d.registers["rng"])
    assert e.extra["prepared_keys"]["a/0"] == 9
    # metadata-only: small
    assert len(blob) < 4096


def test_descriptor_is_metadata_only(cluster, hello_cfg, hello_params):
    """The paper's core claim: descriptor KBs vs instance MBs."""
    from repro.core.instance import ModelInstance
    net, nodes = cluster
    inst = ModelInstance.create(nodes[0], hello_cfg.name, hello_params)
    handle = nodes[0].prepare_fork(inst)
    blob = nodes[0].seeds[handle.handler_id].blob
    assert len(blob) < inst.total_bytes() / 50, \
        f"descriptor {len(blob)}B not << state {inst.total_bytes()}B"


def test_sharded_routed_descriptor_stays_kb_sized():
    """Size regression for the placement plane: a GB-scale, sharded,
    route-annotated descriptor (per-VMA owner chains + transports + the
    route map) must keep the paper's metadata-only property — KBs of
    descriptor for GBs of instance state."""
    from repro.core.pagetable import VMA

    parents = [f"parent{i}" for i in range(4)]
    transports = ["dct", "tpu_ici", "shared_fs", None]
    vmas, routes, total = [], {}, 0
    # 8 x 1 GiB tensors at 4 MiB pages: 256-entry page tables each
    for i in range(8):
        shape = (256, 1024, 1024)                       # 1 GiB float32
        v = VMA.new_local(f"layers/{i}/w", shape, "float32",
                          np.arange(256, dtype=np.int32))
        v.ancestry = [parents[i % 4], "origin"]         # sharded owner chain
        v.transport = transports[i % 4]
        v.dc_keys = {1: 1000 + i, 2: 2000 + i}
        vmas.append(v)
        routes[v.name] = {"owner": v.ancestry[0], "transport": v.transport}
        total += v.nbytes()
    d = Descriptor(
        arch="gb-scale", kind="weights", parent_node="parent0", handler_id=1,
        ancestry=["origin"],
        leaf_paths=[["layers", i, "w"] for i in range(8)],
        vmas=[v.table_dict() for v in vmas],
        registers={"step": 0},
        extra={"prepared_keys": {v.name: 3000 + i
                                 for i, v in enumerate(vmas)},
               "leaf_names": [v.name for v in vmas]},
        routes=routes,
    )
    blob = d.to_bytes()
    assert total >= 8 * 2**30
    assert len(blob) < 64 * 1024, \
        f"route-annotated descriptor ballooned to {len(blob)}B"
    assert len(blob) < total / 100_000, \
        f"descriptor {len(blob)}B not metadata-sized vs {total}B state"
    e = Descriptor.from_bytes(blob)
    assert e.routes["layers/0/w"]["owner"] == "parent0"
    assert e.vma_objects()[1].transport == "tpu_ici"
    assert e.vma_objects()[1].ancestry == ["parent1", "origin"]
