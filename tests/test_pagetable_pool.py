"""Unit tests: page pool allocator, tensor paging, VMA hop encoding."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pagetable import F_DIRTY, F_PRESENT, MAX_HOPS, VMA
from repro.memory import paging
from repro.memory.pool import PagePool


def test_pool_alloc_free_cycle():
    pool = PagePool(page_elems=256, grow_frames=8)
    a = pool.alloc(jnp.float32, 5)
    assert len(set(a.tolist())) == 5
    assert pool.num_allocated(jnp.float32) == 5
    pool.free(jnp.float32, a[:2])
    assert pool.num_allocated(jnp.float32) == 3
    b = pool.alloc(jnp.float32, 4)
    assert set(b.tolist()).isdisjoint(set(a[2:].tolist()))


def test_pool_rw_roundtrip():
    pool = PagePool(page_elems=128)
    frames = pool.alloc(jnp.bfloat16, 3)
    data = jnp.arange(3 * 128, dtype=jnp.bfloat16).reshape(3, 128)
    pool.write_pages(jnp.bfloat16, frames, data)
    got = pool.read_pages(jnp.bfloat16, frames)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(data, np.float32))


def test_pool_dtype_isolation():
    pool = PagePool(page_elems=64)
    f32 = pool.alloc(jnp.float32, 2)
    bf16 = pool.alloc(jnp.bfloat16, 2)
    assert pool.bytes_allocated() == 2 * 64 * 4 + 2 * 64 * 2


def test_paging_roundtrip():
    x = jnp.arange(1000, dtype=jnp.float32).reshape(10, 100)
    pages = paging.to_pages(x, 256)
    assert pages.shape == (4, 256)
    y = paging.from_pages(pages, (10, 100), jnp.float32)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_vma_child_view_hops_and_keys():
    v = VMA.new_local("w", (4, 4), "float32", np.arange(3, dtype=np.int32))
    v.dc_keys = {}
    c1 = v.child_view(parent_key=101)
    assert (c1.owner_hop == 1).all()
    assert c1.dc_keys == {1: 101}
    assert not c1.resident_mask().any()
    c2 = c1.child_view(parent_key=202)
    assert (c2.owner_hop == 2).all()
    assert c2.dc_keys == {1: 202, 2: 101}


def test_vma_hop_overflow():
    v = VMA.new_local("w", (4,), "float32", np.arange(1, dtype=np.int32))
    for i in range(MAX_HOPS):
        v = v.child_view(i)
    with pytest.raises(OverflowError):
        v.child_view(99)


def test_vma_partial_residency():
    v = VMA.new_local("w", (8,), "float32", np.arange(4, dtype=np.int32))
    c = v.child_view(7)
    c.mark_resident([1, 3], [10, 11])
    assert set(c.missing_pages().tolist()) == {0, 2}
    assert c.frames[1] == 10 and c.owner_hop[1] == 0
    assert c.owner_hop[0] == 1


def test_vma_table_roundtrip():
    v = VMA.new_local("a/b/w", (3, 5), "bfloat16", np.arange(2, dtype=np.int32))
    v.dc_keys = {1: 42, 3: 77}
    w = VMA.from_table_dict(v.table_dict())
    assert w.name == v.name and w.shape == v.shape and w.dtype == v.dtype
    np.testing.assert_array_equal(w.frames, v.frames)
    assert w.dc_keys == v.dc_keys
