"""Loop-aware HLO cost extraction: exact on a handcrafted module."""
import textwrap

from repro.distributed import hlo_analysis as H
from repro.distributed.roofline import roofline

HLO = textwrap.dedent("""
HloModule jit_step, is_scheduled=true

%body (p: (s32[], f32[8,32])) -> (s32[], f32[8,32]) {
  %p = (s32[], f32[8,32]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,32]{1,0} get-tuple-element(%p), index=1
  %ag = f32[32,32]{1,0} all-gather(%g1), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %dot = f32[8,32]{1,0} dot(%g1, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,32]{1,0} all-reduce(%dot), channel_id=2, replica_groups=[4,2]<=[8], to_apply=%sum
  %c1 = s32[] constant(1)
  %add = s32[] add(%g0, %c1)
  ROOT %t = (s32[], f32[8,32]) tuple(%add, %ar)
}

%cond (p2: (s32[], f32[8,32])) -> pred[] {
  %p2 = (s32[], f32[8,32]) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(6)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,32]) -> f32[8,32] {
  %x = f32[8,32]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tup = (s32[], f32[8,32]) tuple(%c0, %x)
  %w = (s32[], f32[8,32]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"6"}}
  ROOT %out = f32[8,32]{1,0} get-tuple-element(%w), index=1
}
""")


def test_multipliers_and_flops():
    out = H.analyze(HLO)
    # dot: 2*8*32*32 flops, executed 6 times
    assert out["dot_flops"] == 6 * 2 * 8 * 32 * 32
    coll = out["collectives"]
    assert coll["all-gather"] == 6 * 32 * 32 * 4
    assert coll["all-reduce"] == 6 * 8 * 32 * 4
    assert coll["all-gather_ops"] == 6
    # ring model: all-reduce counts 2x
    total = H.total_collective_bytes(coll)
    assert total == 6 * 32 * 32 * 4 + 2 * 6 * 8 * 32 * 4


def test_shape_bytes_tuple_types():
    assert H._type_bytes("(f32[2,3]{1,0}, bf16[4]{0})") == 24 + 8
    assert H._type_bytes("pred[7]") == 7
    assert H._type_bytes("s32[]") == 4


def test_roofline_terms_and_dominance():
    r = roofline(flops_global=197e12 * 256, bytes_global=819e9 * 256 * 2,
                 coll_bytes_global=0, chips=256)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.fraction_of_roofline(197e12 * 256) - 0.5) < 1e-9


def test_nested_loop_multiplier():
    hlo = HLO.replace('ENTRY %main', '%outer_unused').replace(
        "ROOT %out = f32[8,32]{1,0} get-tuple-element(%w), index=1",
        "ROOT %out = f32[8,32]{1,0} get-tuple-element(%w), index=1")
    # wrap: outer while with trip 3 calling %body? Construct a two-level module
    two = textwrap.dedent("""
    HloModule nest
    %inner (p: s32[]) -> s32[] {
      %p = s32[] parameter(0)
      %d = f32[4,4]{1,0} constant({...})
      %dot = f32[4,4]{1,0} dot(%d, %d), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %q = s32[] add(%p, %p)
    }
    %icond (x: s32[]) -> pred[] {
      %x = s32[] parameter(0)
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%x, %n), direction=LT
    }
    %obody (p: s32[]) -> s32[] {
      %p = s32[] parameter(0)
      %w2 = s32[] while(%p), condition=%icond, body=%inner, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %r = s32[] add(%w2, %w2)
    }
    %ocond (x: s32[]) -> pred[] {
      %x = s32[] parameter(0)
      %n = s32[] constant(3)
      ROOT %lt = pred[] compare(%x, %n), direction=LT
    }
    ENTRY %m (a: s32[]) -> s32[] {
      %a = s32[] parameter(0)
      ROOT %w = s32[] while(%a), condition=%ocond, body=%obody, backend_config={"known_trip_count":{"n":"3"}}
    }
    """)
    out = H.analyze(two)
    assert out["dot_flops"] == 3 * 5 * 2 * 4 * 4 * 4
