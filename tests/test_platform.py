"""Coordinator / lifecycle / workflow integration tests (§6)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.instance import ModelInstance
from repro.net import Network
from repro.models import lm
from repro.platform.coordinator import Coordinator, FunctionDef
from repro.platform.node import NodeRuntime
from repro.platform.workflow import Workflow, WorkflowFunc, run_workflow

# the shared `platform` fixture (3-node coordinator on a FakeClock) lives in
# conftest.py


def test_first_coldstart_becomes_seed(platform):
    net, nodes, coord, clock = platform
    assert "f" not in coord.seed_store
    out, inst = coord.invoke("f", policy="fork")
    assert out["ok"] and "f" in coord.seed_store
    # second invoke forks instead of coldstarting: lazy child
    out2, inst2 = coord.invoke("f", policy="fork")
    assert inst2.ancestry, "second invoke must be a fork child"


def test_seed_timeout_gc(platform):
    net, nodes, coord, clock = platform
    coord.invoke("f")
    handle = coord.seed_store["f"]
    clock.t = handle.lease_deadline + 1
    freed = coord.gc()
    assert freed["seeds"] == 1 and "f" not in coord.seed_store


def test_seed_renew(platform):
    net, nodes, coord, clock = platform
    coord.invoke("f")
    clock.t = 500.0
    coord.renew_seed("f")
    clock.t = 700.0           # < 500 + 600
    coord.gc()
    assert "f" in coord.seed_store


def test_cache_policy_is_per_node_and_single_use(platform):
    net, nodes, coord, clock = platform
    out, inst = coord.invoke("f", policy="cache", node=nodes[0])
    coord.release("f", inst, policy="cache")
    # reuse on the same node hits the cache
    out2, inst2 = coord.invoke("f", policy="cache", node=nodes[0])
    assert inst2 is inst
    coord.release("f", inst2, policy="cache")
    # a different node cannot use it -> coldstart
    out3, inst3 = coord.invoke("f", policy="cache", node=nodes[1])
    assert inst3 is not inst


def test_node_crash_reroutes_to_coldstart(platform):
    net, nodes, coord, clock = platform
    coord.invoke("f")                      # seed on some node
    handle = coord.seed_store["f"]
    coord.nodes[handle.parent_node].crash()
    out, inst = coord.invoke("f", node=next(
        n for n in nodes if n.alive and n.node_id != handle.parent_node))
    assert out["ok"]


def test_workflow_fork_state_transfer(platform, hello_cfg, hello_params):
    net, nodes, coord, clock = platform
    payload = np.arange(4096, dtype=np.float32)

    def up(inst, ctx):
        inst.add_tensor("globals/market", jnp.asarray(payload))
        return {"rows": 1}

    def down(inst, ctx):
        got = np.asarray(inst.ensure_tensor("globals/market"))
        np.testing.assert_array_equal(got, payload)
        return {"sum": float(got.sum())}

    coord.register_function(FunctionDef("up", hello_cfg.name,
                                        lambda: hello_params, up))
    coord.register_function(FunctionDef("down", hello_cfg.name,
                                        lambda: hello_params, down))
    wf = Workflow("t")
    wf.add(WorkflowFunc("U", "up"))
    wf.add(WorkflowFunc("D", "down", fork_from="U"))
    wf.edge("U", "D")
    res = run_workflow(coord, wf, {}, transfer="fork", fan_out={"D": 3})
    assert len(res["D"]) == 3
    for r in res["D"]:
        assert r["sum"] == float(payload.sum())
    # fork tree closed: no dangling short-lived seeds beyond long-lived ones
    assert not coord.fork_trees


def test_workflow_message_baseline(platform, hello_cfg, hello_params):
    net, nodes, coord, clock = platform

    def up(inst, ctx):
        return {"data": np.ones(128, np.float32)}

    def down(inst, ctx):
        assert "msg:U" in ctx
        return {"got": float(ctx["msg:U"]["data"].sum())}

    coord.register_function(FunctionDef("up", hello_cfg.name,
                                        lambda: hello_params, up))
    coord.register_function(FunctionDef("down", hello_cfg.name,
                                        lambda: hello_params, down))
    wf = Workflow("m")
    wf.add(WorkflowFunc("U", "up"))
    wf.add(WorkflowFunc("D", "down"))
    wf.edge("U", "D")
    res = run_workflow(coord, wf, {}, transfer="message")
    assert res["D"]["got"] == 128.0
    assert net.meter["msg_bytes"] > 0


def test_dangling_seed_gc_by_max_lifetime(platform):
    net, nodes, coord, clock = platform
    out, inst = coord.invoke("f")
    # simulate a short-lived seed left behind by a crashed coordinator
    inst.node.prepare_fork(inst)
    clock.t = 901.0
    freed = coord.gc()
    assert freed["dangling"] >= 1
