"""Lease-based ForkHandle control plane: lease expiry/renewal, revocation
generations, fan-out fork trees, handle serialization, policy validation,
lease telemetry, and the coordinator lifecycle fixes that ride on the API
(pick_node, seed-instance pinning, bounded page cache)."""
import importlib.util
import math

import jax
import numpy as np
import pytest

from repro.core.instance import ModelInstance
from repro.net import Network
from repro.fork import (AccessRevoked, ForkHandle, ForkPolicy, ForkTree,
                        LeaseExpired)
from repro.platform.node import NodeRuntime

from conftest import FakeClock


@pytest.fixture()
def leased_cluster():
    net = Network()
    clock = FakeClock()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, clock=clock)
             for i in range(10)]
    return net, nodes, clock


def _mk_parent(node, cfg, params):
    return ModelInstance.create(node, cfg.name, params, kind="weights")


# ---------------------------------------------------------------------------
# leases
# ---------------------------------------------------------------------------


def test_lease_expired_resume_raises(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent, lease=10.0)
    assert not handle.expired and handle.remaining() == pytest.approx(10.0)
    handle.resume_on(nodes[1])                      # fresh: fine
    clock.t = 10.0                                  # deadline is exclusive
    assert handle.expired
    with pytest.raises(LeaseExpired):
        handle.resume_on(nodes[2])


def test_lease_renewal_extends_deadline(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent, lease=10.0)
    clock.t = 6.0
    handle.renew()                                  # default: original duration
    assert handle.lease_deadline == pytest.approx(16.0)
    clock.t = 15.0
    handle.resume_on(nodes[1])                      # still fresh post-renewal
    handle.renew(extend=100.0)
    assert handle.lease_deadline == pytest.approx(115.0)


def test_unbounded_lease_never_expires(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)          # lease=None
    clock.t = 1e9
    assert not handle.expired and handle.remaining() == math.inf
    handle.resume_on(nodes[1])


def test_invalid_lease_rejected(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    with pytest.raises(ValueError):
        nodes[0].prepare_fork(parent, lease=0.0)


# ---------------------------------------------------------------------------
# revocation generations
# ---------------------------------------------------------------------------


def test_revoke_bumps_generation(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    copy = ForkHandle.from_dict(handle.to_dict())   # an outstanding copy
    fresh = handle.revoke()
    assert fresh.generation == handle.generation + 1
    for stale in (handle, copy):
        with pytest.raises(AccessRevoked):
            stale.resume_on(nodes[1])
    # the seed itself stays prepared: the new-generation handle still works
    child = fresh.resume_on(nodes[1])
    assert child.arch == hello_cfg.name
    # a second revocation invalidates the first reissue too
    newer = fresh.revoke()
    with pytest.raises(AccessRevoked):
        fresh.resume_on(nodes[2])
    newer.resume_on(nodes[2])


def test_revoke_kills_rebuilt_wire_credentials(leased_cluster, hello_cfg,
                                               hello_params):
    """A handle rebuilt from raw wire credentials (the old tuple-era attack
    surface) dies at auth after a revoke, like any outstanding copy."""
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    handle.revoke()
    rebuilt = ForkHandle(parent_node="node0", handler_id=handle.handler_id,
                         auth_key=handle.auth_key)
    with pytest.raises(AccessRevoked):
        rebuilt.resume_on(nodes[1])


# ---------------------------------------------------------------------------
# handle lifecycle: context manager, serialization, reclaim
# ---------------------------------------------------------------------------


def test_context_manager_auto_reclaims(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    with nodes[0].prepare_fork(parent) as handle:
        handle.resume_on(nodes[1])
    assert handle.handler_id not in nodes[0].seeds
    with pytest.raises(PermissionError):
        handle.resume_on(nodes[2])
    handle.reclaim()                                # idempotent


def test_handle_serialization_roundtrip(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent, lease=50.0)
    wire = ForkHandle.from_json(handle.to_json())
    assert wire == handle                           # runtime excluded from eq
    # resume needs no rebinding (child reaches the parent via its network)
    child = wire.resume_on(nodes[1])
    assert child.arch == hello_cfg.name
    # parent-side lifecycle calls need an explicit rebind
    with pytest.raises(RuntimeError):
        wire.renew()
    with pytest.raises(ValueError):
        wire.bind(nodes[3])                         # wrong node refused
    wire.bind(nodes[0]).renew(extend=99.0)
    assert nodes[0].seeds[handle.handler_id].lease_deadline == pytest.approx(99.0)


def test_unbounded_handle_serializes_to_strict_json(leased_cluster, hello_cfg,
                                                    hello_params):
    """lease=None handles must produce RFC-8259 JSON (no bare Infinity) so
    non-Python control planes can parse the wire record."""
    import json

    def _reject_constant(name):
        raise ValueError(f"non-strict JSON constant {name!r} on the wire")

    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)          # unbounded lease
    s = handle.to_json()
    json.loads(s, parse_constant=_reject_constant)  # strict parse succeeds
    wire = ForkHandle.from_json(s)
    assert wire.lease_deadline == math.inf and wire == handle
    wire.resume_on(nodes[1])


def test_policy_validation():
    with pytest.raises(ValueError):
        ForkPolicy(prefetch=-1)
    with pytest.raises(ValueError):
        ForkPolicy(descriptor_fetch="bogus")
    with pytest.raises(ValueError):
        ForkPolicy(page_fetch="bogus")
    with pytest.raises(ValueError):
        ForkPolicy(lazy=1)
    with pytest.raises(TypeError):
        ForkPolicy.coerce(42)
    assert ForkPolicy.coerce({"prefetch": 3}).prefetch == 3
    assert ForkPolicy.coerce(None) == ForkPolicy()


# ---------------------------------------------------------------------------
# fan-out fork tree (§6.3)
# ---------------------------------------------------------------------------


def test_fan_out_64_children_degree_8(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent, lease=100.0)
    targets = [nodes[1 + i % 9] for i in range(64)]
    tree = handle.fan_out(targets, ForkPolicy(lazy=True), tree_degree=8)
    assert isinstance(tree, ForkTree) and len(tree) == 64
    # no seed (root included) served more than tree_degree descriptors
    assert max(tree.served_by().values()) <= 8
    # 64 children at degree 8: root serves 8, 7 promoted re-seeds serve 56
    assert len(tree.seeds) == 7
    assert tree.depth() == 2
    assert sorted(tree.levels).count(1) == 8 and tree.levels.count(2) == 56
    # a deep child still reads the original bits through the hop chain
    deep = tree.children[tree.levels.index(2)]
    name = deep.leaf_names[0]
    np.testing.assert_array_equal(
        np.asarray(deep.ensure_tensor(name)),
        np.asarray(parent.ensure_tensor(name)))
    # one close() reclaims every short-lived re-seed but never the root
    tree.close()
    for reseed in tree.seeds:
        assert reseed.handler_id not in reseed.runtime.seeds
    assert handle.handler_id in nodes[0].seeds
    tree.close()                                    # idempotent
    # lease-expired root refuses further fan-out
    clock.t = 101.0
    with pytest.raises(LeaseExpired):
        handle.fan_out([nodes[1]], tree_degree=8)


def test_fan_out_failure_reclaims_partial_tree(leased_cluster, hello_cfg,
                                               hello_params):
    """A fan-out that fails mid-build must not leak re-seeds or orphaned
    children: the partial tree is reclaimed before the error surfaces."""
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    # degree 2 + a poison third target: root serves 2, one child gets
    # promoted to a re-seed, then resume_on(None) blows up
    with pytest.raises(AttributeError):
        handle.fan_out([nodes[1], nodes[2], None], tree_degree=2)
    assert list(nodes[0].seeds) == [handle.handler_id]  # root survives
    for n in nodes[1:]:
        assert not n.seeds                              # no leaked re-seeds
    assert not any(n.instances for n in nodes[1:])      # children freed
    # the root still serves after the failed fan-out
    handle.resume_on(nodes[3])


def test_fan_out_as_context_manager(leased_cluster, hello_cfg, hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    with handle.fan_out([nodes[1 + i % 9] for i in range(12)],
                        tree_degree=4) as tree:
        assert len(tree) == 12
    assert tree.closed
    with pytest.raises(ValueError):
        handle.fan_out([nodes[1]], tree_degree=0)


# ---------------------------------------------------------------------------
# deprecated shims: removed after their one-release grace period
# ---------------------------------------------------------------------------


def test_tuple_shim_module_is_gone():
    """ROADMAP: the fork_prepare/fork_resume/fork_reclaim tuple shims were
    to be removed one release after the handle migration.  Prove the module
    stayed deleted (CI asserts the same before running the suite)."""
    assert importlib.util.find_spec("repro.core.fork") is None


def test_wire_credentials_drive_same_data_path(hello_cfg, hello_params):
    """A handle rebuilt from raw wire fields (what the tuple API exposed)
    drives the identical data path as the minted handle."""
    def run(rebuild):
        net = Network()
        nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
        parent = _mk_parent(nodes[0], hello_cfg, hello_params)
        handle = nodes[0].prepare_fork(parent)
        if rebuild:
            handle = ForkHandle.from_dict(handle.to_dict())
        child = handle.resume_on(nodes[1], ForkPolicy(lazy=True, prefetch=2))
        child.ensure_all()
        return child.stats, dict(net.meter)

    minted_stats, minted_meter = run(rebuild=False)
    wire_stats, wire_meter = run(rebuild=True)
    assert minted_stats == wire_stats
    assert minted_meter == wire_meter


# ---------------------------------------------------------------------------
# coordinator lifecycle fixes riding on the new API (shared `platform`
# fixture from conftest.py)
# ---------------------------------------------------------------------------


def test_pick_node_no_live_nodes_raises(platform):
    net, nodes, coord, clock = platform
    for n in nodes:
        n.crash()
    with pytest.raises(RuntimeError, match="no live nodes"):
        coord.pick_node()


def test_pick_node_all_excluded_raises(platform):
    net, nodes, coord, clock = platform
    with pytest.raises(RuntimeError, match="no live nodes"):
        coord.pick_node(exclude=tuple(n.node_id for n in nodes))


def test_release_does_not_free_the_platform_seed(platform, hello_params):
    net, nodes, coord, clock = platform
    out, inst = coord.invoke("f", policy="fork")    # coldstart -> becomes seed
    handle = coord.seed_store["f"]
    assert nodes and net                             # fixture sanity
    coord.release("f", inst, policy="fork")
    # the seed's backing instance must survive the release...
    entry = coord.nodes[handle.parent_node].seeds[handle.handler_id]
    assert entry.instance is inst and inst.aspace, "seed instance was freed"
    # ...so a later fork still materializes the pristine state
    out2, child = coord.invoke("f", policy="fork")
    assert child.ancestry, "second invoke must fork, not coldstart"
    got = child.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # non-seed children are still freed on release
    coord.release("f", child, policy="fork")
    assert not child.aspace
    # lease-expiry GC reclaims the pinned seed instance exactly once
    clock.t = handle.lease_deadline + 1
    freed = coord.gc()
    assert freed["seeds"] == 1 and not inst.aspace


def test_seed_store_holds_leased_handles(platform):
    net, nodes, coord, clock = platform
    coord.invoke("f")
    handle = coord.seed_store["f"]
    assert isinstance(handle, ForkHandle)
    assert handle.remaining() == pytest.approx(600.0)
    clock.t = 500.0
    coord.renew_seed("f")
    assert handle.remaining() == pytest.approx(600.0)


def test_renew_rejects_nonpositive_extend(leased_cluster, hello_cfg,
                                          hello_params):
    net, nodes, clock = leased_cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent, lease=10.0)
    for bad in (0.0, -10.0):
        with pytest.raises(ValueError):
            handle.renew(extend=bad)
    assert handle.lease_deadline == pytest.approx(10.0)  # untouched


def test_renewed_seed_survives_dangling_gc(platform):
    """Renewal is a keepalive: it refreshes the node-side creation stamp so
    the MAX_FUNCTION_LIFETIME dangling GC doesn't reclaim a live seed."""
    net, nodes, coord, clock = platform
    coord.invoke("f")
    handle = coord.seed_store["f"]
    clock.t = 500.0
    coord.renew_seed("f")
    clock.t = 901.0                     # > MAX_FUNCTION_LIFETIME since deploy
    coord.gc()
    assert handle.alive and coord._seed_fresh(handle)
    out, child = coord.invoke("f", policy="fork")
    assert child.ancestry, "renewed seed must still serve forks"


def test_stale_store_handle_falls_back_to_coldstart(platform):
    """If the node-side seed vanishes underneath the store (dangling GC),
    renew drops the stale handle and invoke coldstarts instead of raising."""
    net, nodes, coord, clock = platform
    coord.invoke("f")
    handle = coord.seed_store["f"]
    handle.reclaim()                    # simulate node-side reclamation
    assert not handle.alive
    coord.renew_seed("f")               # must not raise; drops the handle
    assert "f" not in coord.seed_store
    coord.deploy_seed("f", nodes[0])    # redeploy, then the same via gc
    coord.seed_store["f"].reclaim()
    out, inst = coord.invoke("f", policy="fork")
    assert out["ok"], "stale handle must reroute to coldstart, not raise"


# ---------------------------------------------------------------------------
# bounded sibling page cache
# ---------------------------------------------------------------------------


def test_page_cache_lru_cap_and_eviction_stat(hello_cfg, hello_params):
    net = Network()
    node = NodeRuntime("n0", net, page_elems=1024, cache_enabled=True,
                       page_cache_cap=4)
    for frame in range(6):
        node.page_cache_put("owner", "float32", frame, frame + 100)
    assert len(node._page_cache) == 4
    assert node.page_cache_stats["evictions"] == 2
    # oldest entries (0, 1) were evicted, newest survive
    assert node.page_cache_get("owner", "float32", 0) is None
    assert node.page_cache_get("owner", "float32", 5) == 105
    # a get refreshes recency: 2 survives the next insert, 3 is evicted
    assert node.page_cache_get("owner", "float32", 2) == 102
    node.page_cache_put("owner", "float32", 7, 107)
    assert node.page_cache_get("owner", "float32", 2) == 102
    assert node.page_cache_get("owner", "float32", 3) is None
    assert node.page_cache_stats["hits"] == 3
    assert node.page_cache_stats["evictions"] == 3


def test_page_cache_bounded_under_fork_load(hello_cfg, hello_params):
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024,
                         cache_enabled=True, page_cache_cap=8)
             for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1])
    child.ensure_all()
    assert len(nodes[1]._page_cache) <= 8
    assert nodes[1].page_cache_stats["evictions"] > 0
