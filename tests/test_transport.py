"""The pluggable data-plane Transport API (repro.net): registry resolution,
capability flags, uniform access control across backends, descriptor DC
keys, per-backend metering, ForkPolicy transport fields, lease telemetry,
and the byte-based sibling page-cache budget."""
import numpy as np
import pytest

from repro.core.instance import ModelInstance
from repro.fork import AccessRevoked, ForkPolicy
from repro.net import (NetModel, Network, Transport, register_transport,
                       resolve_transport, transport_names)
from repro.platform.node import NodeRuntime

from conftest import FakeClock

BUILTIN = ("dct", "rc", "rpc", "shared_fs", "tpu_ici")


def _mk_parent(node, cfg, params):
    return ModelInstance.create(node, cfg.name, params, kind="weights")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_builtin_backends_registered():
    assert set(BUILTIN) <= set(transport_names())
    for name in BUILTIN:
        cls = resolve_transport(name)
        assert cls.name == name
        assert isinstance(cls.one_sided, bool)


def test_unknown_backend_error_lists_registered_names():
    with pytest.raises(ValueError) as ei:
        resolve_transport("infiniband-over-pigeon")
    msg = str(ei.value)
    assert "infiniband-over-pigeon" in msg
    for name in BUILTIN:
        assert name in msg


def test_network_ctor_validates_transport_name():
    with pytest.raises(ValueError, match="registered transports"):
        Network(transport="bogus")
    for name in BUILTIN:
        assert Network(transport=name).transport == name


def test_policy_transport_fields_validated_against_registry():
    for field in ("page_fetch", "descriptor_fetch"):
        with pytest.raises(ValueError) as ei:
            ForkPolicy(**{field: "bogus"})
        assert field in str(ei.value) and "dct" in str(ei.value)


def test_policy_coerce_roundtrip_with_transport_fields():
    p = ForkPolicy.coerce({"page_fetch": "tpu_ici",
                           "descriptor_fetch": "shared_fs", "prefetch": 2})
    assert p.page_fetch == "tpu_ici" and p.descriptor_fetch == "shared_fs"
    assert ForkPolicy.coerce(p) is p
    # defaults: None = the network's default backend
    d = ForkPolicy.coerce(None)
    assert d.page_fetch is None and d.descriptor_fetch is None


def test_core_network_shim_stays_deleted():
    """The repro.core.network re-export finished its one-release
    deprecation window (same warn-then-delete cycle as the repro.core.fork
    tuple shims) and must stay gone."""
    import importlib.util
    assert importlib.util.find_spec("repro.core.network") is None


def test_malformed_backend_rejected_at_registration():
    class NoFlags(Transport):
        name = "_test_noflags"

        def op_latency(self):
            return 0.0

        def bandwidth(self):
            return 1.0

    with pytest.raises(ValueError, match="one_sided"):
        register_transport(NoFlags)
    assert "_test_noflags" not in transport_names()


def test_custom_backend_registration():
    @register_transport
    class _LoopbackTransport(Transport):
        name = "_test_loopback"
        one_sided = True
        legacy_meter = "rdma"

        def op_latency(self):
            return 1e-9

        def bandwidth(self):
            return 1e12

    try:
        net = Network(transport="_test_loopback")
        node = NodeRuntime("n0", net, page_elems=64)
        key = net.create_dc_target("n0")
        frames = node.pool.alloc("float32", 2)
        net.read_pages("n1", "n0", "float32", frames, key)
        assert net.meter["_test_loopback.bytes"] > 0
    finally:
        from repro.net import transport as transport_mod
        transport_mod._REGISTRY.pop("_test_loopback", None)


# ---------------------------------------------------------------------------
# uniform access control: AccessRevoked on every backend after reclaim
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tname", BUILTIN)
def test_reclaim_revokes_page_reads_on_every_backend(tname, hello_cfg,
                                                     hello_params):
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(lazy=True, page_fetch=tname))
    name = child.leaf_names[0]
    vma = child.aspace[name]
    key = vma.dc_keys[1]
    handle.reclaim()
    with pytest.raises(AccessRevoked):
        net.read_pages("node1", "node0", vma.dtype, vma.frames[:1], key,
                       transport=tname)
    # the instance-level fault handler degrades to the fallback daemon
    child.ensure_tensor(name)
    assert child.stats["pages_rpc"] > 0 and child.stats["pages_rdma"] == 0


@pytest.mark.parametrize("tname", BUILTIN)
def test_reclaimed_descriptor_unreadable_on_every_backend(tname, hello_cfg,
                                                          hello_params):
    """Descriptor blobs carry a DC key like any VMA: after reclaim the blob
    read is rejected (the hole the old rdma_read_blob left open)."""
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    entry = nodes[0].seeds[handle.handler_id]
    desc_key, nbytes = entry.desc_key, len(entry.blob)
    assert net.target_valid("node0", desc_key)
    net.read_blob("node1", "node0", nbytes, desc_key, transport=tname)  # live: ok
    handle.reclaim()
    with pytest.raises(AccessRevoked):
        net.read_blob("node1", "node0", nbytes, desc_key, transport=tname)


def test_reclaimed_descriptor_refused_by_two_sided_daemon(hello_cfg,
                                                          hello_params):
    """The parent daemon enforces the descriptor's DC key for RPC-path
    fetches too: reclaim between auth and fetch surfaces as AccessRevoked,
    not a KeyError, on two-sided backends."""
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    entry = nodes[0].seeds[handle.handler_id]
    desc_key = entry.desc_key
    assert nodes[0].seed_blob(handle.handler_id, desc_key) == entry.blob
    handle.reclaim()
    with pytest.raises(AccessRevoked):
        nodes[0].seed_blob(handle.handler_id, desc_key)
    # even a stale-keyed request against a live re-prepared seed is refused
    handle2 = nodes[0].prepare_fork(parent)
    with pytest.raises(AccessRevoked):
        nodes[0].seed_blob(handle2.handler_id, desc_key)


def test_coordinator_revoke_seed_handles_stale_store(platform):
    net, nodes, coord, clock = platform
    assert coord.revoke_seed("missing") is None     # nothing registered
    coord.invoke("f")
    coord.seed_store["f"].reclaim()                 # reclaimed underneath
    assert coord.revoke_seed("f") is None
    assert "f" not in coord.seed_store
    # deliberate reclamation is telemetered as "reclaimed", never "expiries"
    assert coord.lease_telemetry["f"]["reclaimed"] == 1
    assert coord.lease_telemetry["f"]["expiries"] == 0
    coord.deploy_seed("f", nodes[0])
    fresh = coord.revoke_seed("f")
    assert fresh is coord.seed_store["f"] and fresh.generation == 1


def test_gc_cache_expiry_never_frees_pinned_seed(platform, hello_params):
    """A cached container that doubles as the platform seed survives the
    cache-expiry GC (only the seed-lease expiry may free it), so later
    forks never materialize reused-frame garbage."""
    import jax
    net, nodes, coord, clock = platform
    out, inst = coord.invoke("f", policy="cache", node=nodes[0])
    coord.release("f", inst, policy="cache")    # pinned seed, also cached
    handle = coord.seed_store["f"]
    clock.t = 31.0                              # past cache keepalive
    freed = coord.gc()
    assert freed["cached"] == 1 and inst.aspace, "cache GC freed the seed"
    out2, child = coord.invoke("f", policy="fork")
    assert child.ancestry
    got = child.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    coord.release("f", child, policy="fork")
    clock.t = handle.lease_deadline + 1         # now the lease path frees it
    coord.gc()
    assert not inst.aspace


def test_cache_acquire_drops_husks(platform):
    """An instance freed underneath the cached pool (seed reclaim with
    free_instance=True) is dropped, never handed out."""
    net, nodes, coord, clock = platform
    out, inst = coord.invoke("f", policy="cache", node=nodes[0])
    coord.release("f", inst, policy="cache")
    coord.seed_store["f"].reclaim(free_instance=True)   # husks the pool entry
    assert not inst.aspace
    out2, inst2 = coord.invoke("f", policy="cache", node=nodes[0])
    assert inst2 is not inst and inst2.aspace
    assert out2["ok"]


def test_revoke_rotates_descriptor_dc_key(hello_cfg, hello_params):
    """A revoked handle holder who learned the descriptor's DC key at an
    earlier auth cannot keep reading the blob (and the VMA keys inside):
    revoke rotates the key, and only the fresh generation re-learns it."""
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    info = nodes[0].auth_seed(handle.handler_id, handle.auth_key, 0)
    leaked_key = info["desc_key"]
    fresh = handle.revoke()
    with pytest.raises(AccessRevoked):
        net.read_blob("node1", "node0", info["nbytes"], leaked_key)
    with pytest.raises(AccessRevoked):
        nodes[0].seed_blob(handle.handler_id, leaked_key)
    # the fresh-generation handle resumes fine with the rotated key
    child = fresh.resume_on(nodes[1])
    assert child.arch == hello_cfg.name


def test_resume_descriptor_fetch_works_on_every_backend(hello_cfg,
                                                        hello_params):
    for tname in BUILTIN:
        net = Network()
        nodes = [NodeRuntime(f"node{i}", net, page_elems=1024)
                 for i in range(2)]
        parent = _mk_parent(nodes[0], hello_cfg, hello_params)
        handle = nodes[0].prepare_fork(parent)
        child = handle.resume_on(nodes[1], ForkPolicy(
            lazy=True, descriptor_fetch=tname, page_fetch=tname))
        got = child.materialize_pytree()
        import jax
        for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# per-backend metering
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tname", BUILTIN)
def test_per_backend_meter_keys_in_snapshot(tname, hello_cfg, hello_params):
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(
        lazy=True, page_fetch=tname, descriptor_fetch=tname))
    child.ensure_all()
    snap = net.snapshot()
    assert snap[f"{tname}.bytes"] > 0
    assert snap[f"{tname}.ops"] > 0
    assert snap["sim_time"] > 0
    pb = net.per_backend()
    assert pb[tname]["bytes"] == snap[f"{tname}.bytes"]


def test_connection_setup_costs_and_meters():
    model = NetModel()
    for tname, setup, n_setups in (("dct", model.dct_setup, 1),
                                   ("rc", model.rc_setup, 1),
                                   ("rpc", 0.0, 0),
                                   ("tpu_ici", 0.0, 0),
                                   ("shared_fs", 0.0, 0)):
        net = Network(model=NetModel())
        node = NodeRuntime("n0", net, page_elems=64)
        key = net.create_dc_target("n0")
        frames = node.pool.alloc("float32", 1)
        t0 = net.sim_time
        net.read_pages("n1", "n0", "float32", frames, key, transport=tname)
        first = net.sim_time - t0
        t1 = net.sim_time
        net.read_pages("n1", "n0", "float32", frames, key, transport=tname)
        second = net.sim_time - t1
        # setup paid exactly once per (src, dst) pair
        assert first - second == pytest.approx(setup)
        assert net.meter.get(f"{tname}.setups", 0) == n_setups


def test_legacy_category_aggregates_preserved(hello_cfg, hello_params):
    """Default (dct) forks still report rdma_* / rpc_* aggregates that the
    benchmarks and examples consume."""
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    handle.resume_on(nodes[1]).ensure_all()
    snap = net.snapshot()
    assert snap["rdma_bytes"] > 0 and snap["rdma_ops"] > 0
    assert snap["rpc_ops"] > 0          # the auth RPC
    # the backend key carries everything the fabric moved: one-sided reads
    # (rdma_*) plus the control-plane RPCs that rode the same NIC (rpc_*)
    assert snap["dct.bytes"] == snap["rdma_bytes"] + snap["rpc_bytes"]


def test_cost_model_orders_backends():
    """Same bytes, very different fabrics: ici < rdma < dfs sim time."""
    times = {}
    for tname in ("tpu_ici", "dct", "shared_fs"):
        net = Network()
        node = NodeRuntime("n0", net, page_elems=4096)
        key = net.create_dc_target("n0")
        frames = node.pool.alloc("float32", 64)
        net.read_pages("n1", "n0", "float32", frames, key, transport=tname)
        times[tname] = net.sim_time
    assert times["tpu_ici"] < times["dct"] < times["shared_fs"]


# ---------------------------------------------------------------------------
# lease telemetry (coordinator + node counters in gc())
# ---------------------------------------------------------------------------


def test_lease_telemetry_in_gc(platform):
    net, nodes, coord, clock = platform
    coord.invoke("f")                       # coldstart -> deploys the seed
    coord.renew_seed("f")
    coord.renew_seed("f")
    coord.revoke_seed("f")
    clock.t = coord.seed_store["f"].lease_deadline + 1
    freed = coord.gc()
    tele = freed["lease"]["f"]
    assert tele["renewals"] == 2
    assert tele["revocations"] == 1
    assert tele["expiries"] == 1
    node_stats = freed["lease_nodes"]
    assert sum(s.get("renewals", 0) for s in node_stats.values()) == 2
    assert sum(s.get("revocations", 0) for s in node_stats.values()) == 1


def test_node_counts_expiry_at_auth(hello_cfg, hello_params):
    from repro.fork import LeaseExpired
    net = Network()
    clock = FakeClock()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, clock=clock)
             for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent, lease=5.0)
    clock.t = 6.0
    with pytest.raises(LeaseExpired):
        handle.resume_on(nodes[1])
    assert nodes[0].lease_stats["expiries"] == 1


# ---------------------------------------------------------------------------
# byte-based sibling page-cache budget
# ---------------------------------------------------------------------------


def test_page_cache_byte_budget_trips_before_entry_cap():
    net = Network()
    node = NodeRuntime("n0", net, page_elems=1024, cache_enabled=True,
                       page_cache_cap=1000,
                       page_cache_cap_bytes=4 * 1024 * 4)   # 4 float32 pages
    for frame in range(6):
        node.page_cache_put("owner", "float32", frame, frame + 100)
    assert len(node._page_cache) == 4                # byte cap, not entry cap
    assert node.page_cache_bytes() == 4 * 1024 * 4
    assert node.page_cache_stats["evictions"] == 2
    assert node.page_cache_get("owner", "float32", 0) is None
    assert node.page_cache_get("owner", "float32", 5) == 105


def test_page_cache_byte_budget_multi_dtype():
    """A float64 page costs twice a float32 page: the byte budget sees that,
    the entry cap wouldn't."""
    net = Network()
    node = NodeRuntime("n0", net, page_elems=1024, cache_enabled=True,
                       page_cache_cap=1000,
                       page_cache_cap_bytes=16 * 1024)      # 16 KiB
    node.page_cache_put("o", "float32", 0, 100)             # 4 KiB
    node.page_cache_put("o", "float64", 1, 101)             # 8 KiB
    assert node.page_cache_bytes() == 12 * 1024
    node.page_cache_put("o", "float64", 2, 102)             # would be 20 KiB
    assert node.page_cache_bytes() <= 16 * 1024
    assert node.page_cache_stats["evictions"] == 1
    assert node.page_cache_get("o", "float32", 0) is None   # LRU victim
    node.clear_page_cache()
    assert node.page_cache_bytes() == 0


def test_page_cache_invalidated_when_fetching_instance_freed(hello_cfg,
                                                             hello_params):
    """Freeing the instance that populated the sibling cache must drop its
    entries: the pool reuses freed frame indices, so a hit afterwards would
    serve unrelated data."""
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, cache_enabled=True)
             for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    c1 = handle.resume_on(nodes[1])
    c1.ensure_all()
    assert len(nodes[1]._page_cache) > 0
    c1.free()                               # frames return to the pool
    assert len(nodes[1]._page_cache) == 0
    assert nodes[1].page_cache_bytes() == 0
    # a sibling forked after the free refetches instead of hitting stale frames
    c2 = handle.resume_on(nodes[1])
    c2.ensure_all()
    assert c2.stats["pages_cached"] == 0 and c2.stats["pages_rdma"] > 0
    got = c2.materialize_pytree()
    import jax
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cache_hit_survives_fetcher_free_and_frame_reuse(hello_cfg,
                                                         hello_params):
    """A sibling that resumed via cache hits owns copies, not the fetcher's
    frames: freeing the fetcher and recycling its frames through a new
    instance must not corrupt the sibling's tensors."""
    import jax
    import jax.numpy as jnp
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, cache_enabled=True)
             for i in range(2)]
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    c1 = handle.resume_on(nodes[1])
    c1.ensure_all()                         # c1 populates the cache
    c2 = handle.resume_on(nodes[1])
    c2.ensure_all()                         # c2 resumes via cache hits
    assert c2.stats["pages_cached"] > 0
    c1.free()                               # c1's frames return to the pool
    # recycle the freed frames with unrelated data
    junk = ModelInstance.create(nodes[1], "junk",
                                {"x": jnp.full((2048,), 7.0, jnp.float32)})
    c2._tensors.clear()                     # force re-read from frames
    got = c2.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    junk.free()


def test_policy_prefetch_applies_to_implicit_fetches(hello_cfg, hello_params):
    """ForkPolicy.prefetch drives the fault handler even when callers don't
    pass an explicit prefetch (touch_pages/ensure_tensor defaults)."""
    counts = {}
    for pf in (0, 4):
        net = Network()
        nodes = [NodeRuntime(f"node{i}", net, page_elems=1024)
                 for i in range(2)]
        parent = _mk_parent(nodes[0], hello_cfg, hello_params)
        handle = nodes[0].prepare_fork(parent)
        child = handle.resume_on(nodes[1], ForkPolicy(lazy=True, prefetch=pf))
        name = max(child.leaf_names, key=lambda n: child.aspace[n].npages)
        for p in range(child.aspace[name].npages):
            child.touch_pages(name, [p])        # no explicit prefetch arg
        counts[pf] = child.stats["faults"]
    assert counts[4] < counts[0]


def test_cache_dropped_when_owner_frames_freed(hello_cfg, hello_params):
    """Owner-side coherence: freeing the seed instance broadcasts an
    invalidation, so children of a NEW seed whose frames reuse the old
    indices never hit stale (owner, dtype, frame) cache entries."""
    import jax
    import jax.numpy as jnp
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, cache_enabled=True)
             for i in range(2)]
    parent_a = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle_a = nodes[0].prepare_fork(parent_a)
    c1 = handle_a.resume_on(nodes[1])
    c1.ensure_all()                         # node1 caches owner=node0 frames
    assert len(nodes[1]._page_cache) > 0
    handle_a.reclaim(free_instance=True)    # node0 frames return to its pool
    assert len(nodes[1]._page_cache) == 0   # broadcast invalidation
    # a new seed on node0 reuses the freed frame indices with new data
    new_params = jax.tree.map(lambda a: jnp.asarray(a) + 1.0, hello_params)
    parent_b = _mk_parent(nodes[0], hello_cfg, new_params)
    handle_b = nodes[0].prepare_fork(parent_b)
    c2 = handle_b.resume_on(nodes[1])
    got = c2.materialize_pytree()
    assert c2.stats["pages_cached"] == 0    # no stale hits
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_page_cache_rev_index_evicts_shadowed_entry():
    """Two cache entries must never share a local frame: the later put
    evicts the shadowed entry so frame invalidation can't miss it."""
    net = Network()
    node = NodeRuntime("n0", net, page_elems=1024, cache_enabled=True)
    node.page_cache_put("o1", "float32", 7, 500)
    node.page_cache_put("o2", "float32", 9, 500)    # same local frame
    assert node.page_cache_get("o1", "float32", 7) is None   # evicted
    assert node.page_cache_get("o2", "float32", 9) == 500
    assert node.page_cache_bytes() == 4 * 1024
    node.page_cache_invalidate_frames("float32", [500])
    assert len(node._page_cache) == 0 and node.page_cache_bytes() == 0


def test_entry_cap_still_enforced_with_byte_budget():
    net = Network()
    node = NodeRuntime("n0", net, page_elems=1024, cache_enabled=True,
                       page_cache_cap=3, page_cache_cap_bytes=1 << 30)
    for frame in range(5):
        node.page_cache_put("o", "float32", frame, frame)
    assert len(node._page_cache) == 3                # entry cap trips first
    assert node.page_cache_stats["evictions"] == 2
