"""Property-based tests (hypothesis) over system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.descriptor import flatten_with_names, unflatten_from_paths
from repro.core.pagetable import MAX_HOPS, VMA
from repro.memory import paging
from repro.memory.pool import PagePool

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 6)), min_size=1,
                max_size=40))
def test_pool_never_double_allocates(ops):
    """Random alloc/free interleavings: live frames are always disjoint."""
    pool = PagePool(page_elems=64, grow_frames=4)
    live = []
    for is_alloc, n in ops:
        if is_alloc or not live:
            frames = pool.alloc(jnp.float32, n)
            flat = [f for fs in live for f in fs]
            assert set(frames.tolist()).isdisjoint(flat)
            live.append(frames.tolist())
        else:
            pool.free(jnp.float32, live.pop())
    assert pool.num_allocated(jnp.float32) == sum(len(f) for f in live)


@settings(**SETTINGS)
@given(st.integers(1, 64), st.integers(16, 257))
def test_paging_roundtrip_any_shape(n, page_elems):
    page_elems = (page_elems // 16) * 16 or 16
    x = jnp.arange(n, dtype=jnp.float32)
    pages = paging.to_pages(x, page_elems)
    y = paging.from_pages(pages, (n,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(**SETTINGS)
@given(st.integers(1, MAX_HOPS))
def test_hop_chain_keys_consistent(depth):
    """After d forks, hop h's key is the key minted at ancestor h."""
    v = VMA.new_local("w", (4,), "float32", np.arange(2, dtype=np.int32))
    keys = []
    for d in range(depth):
        key = 1000 + d
        keys.append(key)
        v = v.child_view(key)
    assert (v.owner_hop == depth).all()
    # hop h (1=nearest parent) was minted at fork (depth - h)
    for h in range(1, depth + 1):
        assert v.dc_keys[h] == keys[depth - h]


@settings(**SETTINGS)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=8, unique=True))
def test_cow_never_touches_parent_frames(pages_to_write):
    """Child page writes allocate fresh frames, never the parent's."""
    pool = PagePool(page_elems=32)
    parent_frames = pool.alloc(jnp.float32, 8)
    v = VMA.new_local("w", (256,), "float32", parent_frames)
    c = v.child_view(1)
    child_frames = pool.alloc(jnp.float32, len(pages_to_write))
    c.mark_resident(pages_to_write, child_frames)
    c.mark_dirty(pages_to_write)
    assert set(c.frames[pages_to_write].tolist()).isdisjoint(
        set(parent_frames.tolist()))
    untouched = [p for p in range(8) if p not in pages_to_write]
    assert (c.frames[untouched] == v.frames[untouched]).all()
    assert (c.owner_hop[untouched] == 1).all()


_tree_strategy = st.recursive(
    st.integers(0, 3).map(lambda n: jnp.arange(n + 1, dtype=jnp.float32)),
    lambda children: st.one_of(
        st.lists(children, min_size=1, max_size=3),
        st.dictionaries(st.sampled_from(list("abcd")), children, min_size=1,
                        max_size=3)),
    max_leaves=8)


@settings(**SETTINGS)
@given(_tree_strategy)
def test_flatten_unflatten_roundtrip(tree):
    names, paths, leaves = flatten_with_names(tree)
    rebuilt = unflatten_from_paths(paths, leaves)
    a, b = jax.tree.leaves(tree), jax.tree.leaves(rebuilt)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(**SETTINGS)
@given(st.integers(0, 10000), st.integers(1, 30), st.integers(0, 3))
def test_data_stream_is_pure(seed, step, host):
    from repro.training.data import TokenStream
    s = TokenStream(512, 8, 16, seed=seed, num_hosts=4, host_id=host)
    a, _ = s.batch_at(step)
    b, _ = s.batch_at(step)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 512


# ---------------------------------------------------------------------------
# per-node link clock (NetModel.node_links): fan-in serialization invariants
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(1, 48), st.booleans()),
                min_size=1, max_size=8),
       st.sampled_from(["dct", "rc", "tpu_ici", "rpc"]))
def test_fan_in_finishes_no_earlier_than_link_serialization(reads, tname):
    """K children of one owner, any sync/async mix, any fabric: the owner's
    single link serializes every transfer, so the last link stamp is never
    earlier than the total wire time the owner served."""
    from repro.net import Network
    from repro.platform.node import NodeRuntime
    net = Network()
    owner = NodeRuntime("owner", net, page_elems=64)
    key = net.create_dc_target("owner")
    t0 = net.sim_time
    for i, (n, async_read) in enumerate(reads):
        frames = owner.pool.alloc("float32", n)
        net.read_pages(f"child{i}", "owner", "float32", frames, key,
                       async_read=async_read, transport=tname)
    assert net.link_busy_until("owner") - t0 \
        >= net.node_busy("owner") - 1e-12


@settings(**SETTINGS)
@given(st.lists(st.integers(1, 48), min_size=1, max_size=8),
       st.sampled_from(["dct", "rc", "tpu_ici"]))
def test_sync_fan_in_clock_decomposes_into_wire_setup_and_stalls(sizes,
                                                                 tname):
    """All-sync fan-in: elapsed sim time is exactly the served wire time
    plus connection setups plus metered channel_wait_s — stalls are
    metered, never silently absorbed (and never double-counted)."""
    from repro.net import Network
    from repro.platform.node import NodeRuntime
    net = Network()
    owner = NodeRuntime("owner", net, page_elems=64)
    key = net.create_dc_target("owner")
    t0 = net.sim_time
    for i, n in enumerate(sizes):
        frames = owner.pool.alloc("float32", n)
        net.read_pages(f"child{i}", "owner", "float32", frames, key,
                       transport=tname)
    elapsed = net.sim_time - t0
    parts = (net.node_busy("owner") + net.meter["channel_wait_s"]
             + net.meter[f"{tname}.setup_s"])
    assert elapsed == pytest.approx(parts, rel=1e-9)
