"""Autoscaler policy units: KeepWarm TTL/LIFO/budget, Hybrid spill order,
reclaim-under-spike — driven against a real (unstarted) ReplayEngine."""
import pytest

from repro.sim import (ForkOnDemand, Hybrid, Invocation, KeepWarm,
                       ReplayEngine, SimFunction, Trace)

FN = "f"


def make_engine(policy, minutes=(1,), **fn_kw):
    fn_kw.setdefault("state_bytes", 16 * 1024)
    fn_kw.setdefault("touch_frac", 0.25)
    eng = ReplayEngine(Trace("unit", {FN: tuple(minutes)}), policy,
                       [SimFunction(FN, **fn_kw)], n_nodes=4, seed=0,
                       page_elems=1024)
    policy.on_start(eng)
    return eng


def inv(i=0, t=0.0):
    return Invocation(t, FN, i)


def pool_of(eng):
    return eng.coord.cached.get(FN, [])


# -- KeepWarm ----------------------------------------------------------------

def test_keepwarm_prewarm_then_warm_hits():
    policy = KeepWarm(ttl=60.0, prewarm=2)
    eng = make_engine(policy)
    assert len(pool_of(eng)) == 2
    kind, inst = policy.acquire(eng, inv())
    assert kind == "warm" and inst.aspace
    assert len(pool_of(eng)) == 1


def test_keepwarm_ttl_expiry_via_platform_gc():
    policy = KeepWarm(ttl=60.0, prewarm=2)
    eng = make_engine(policy)
    assert eng.coord.cache_keepalive == 60.0
    eng.net.sim_time = 61.0              # sim clock, not wall clock
    freed = eng.coord.gc()
    assert freed["cached"] == 2
    assert pool_of(eng) == []
    kind, _ = policy.acquire(eng, inv())
    assert kind == "cold"                # nothing warm survived the TTL


def test_keepwarm_reuse_is_lifo():
    policy = KeepWarm(ttl=300.0)
    eng = make_engine(policy)
    k1, first = policy.acquire(eng, inv(0))
    k2, second = policy.acquire(eng, inv(1))
    assert (k1, k2) == ("cold", "cold")
    policy.release(eng, inv(0), first)       # parked first (oldest)
    eng.net.sim_time = 1.0
    policy.release(eng, inv(1), second)      # parked last (most recent)
    kind, got = policy.acquire(eng, inv(2))
    assert kind == "warm"
    assert got.instance_id == second.instance_id   # LIFO: newest serves


def test_keepwarm_budget_evicts_oldest_first():
    policy = KeepWarm(ttl=300.0, budget=1)
    eng = make_engine(policy)
    _, a = policy.acquire(eng, inv(0))
    _, b = policy.acquire(eng, inv(1))
    policy.release(eng, inv(0), a)
    eng.net.sim_time = 1.0
    policy.release(eng, inv(1), b)           # pool over budget -> evict a
    pool = pool_of(eng)
    assert len(pool) == 1
    assert pool[0][0].instance_id == b.instance_id
    assert not a.aspace                      # the evicted container was freed
    evicted = eng.telemetry.of_kind("evicted")
    assert evicted and evicted[0]["count"] == 1


def test_keepwarm_reclaim_under_spike_pool_drains_then_refills():
    """A burst checks out every warm container (occupancy!), forcing colds;
    completions re-park them and the pool recovers."""
    policy = KeepWarm(ttl=300.0, prewarm=2)
    eng = make_engine(policy)
    served = [policy.acquire(eng, inv(i)) for i in range(4)]
    kinds = [k for k, _ in served]
    assert kinds == ["warm", "warm", "cold", "cold"]
    assert pool_of(eng) == []                # drained under the spike
    for i, (_k, inst) in enumerate(served):
        policy.release(eng, inv(i), inst)
    assert len(pool_of(eng)) == 4            # all re-parked after completion


# -- Hybrid ------------------------------------------------------------------

def test_hybrid_spill_ordering_warm_then_fork_then_release_paths():
    policy = Hybrid(pool=1, ttl=300.0, prefetch=0)
    eng = make_engine(policy)
    k1, warm_inst = policy.acquire(eng, inv(0))
    assert k1 == "warm" and not warm_inst.ancestry
    k2, fork_inst = policy.acquire(eng, inv(1))
    assert k2 == "fork" and fork_inst.ancestry   # pool empty -> real fork
    # fork children are freed on release, never cached (§6.2)
    policy.release(eng, inv(1), fork_inst)
    assert pool_of(eng) == []
    assert not fork_inst.aspace
    # warm containers go back to the (bounded) pool
    policy.release(eng, inv(0), warm_inst)
    assert len(pool_of(eng)) == 1


def test_hybrid_without_spill_falls_to_cold():
    policy = Hybrid(pool=1, ttl=300.0, spill_to_fork=False)
    eng = make_engine(policy)
    policy.acquire(eng, inv(0))              # drains the pool
    kind, inst = policy.acquire(eng, inv(1))
    assert kind == "cold" and not inst.ancestry


# -- ForkOnDemand ------------------------------------------------------------

def test_fork_on_demand_deploys_replicas_and_renews():
    policy = ForkOnDemand(replicas=2, lease=600.0, renew_every=60.0,
                          prefetch=0)
    eng = make_engine(policy)
    seed = eng.coord.seed_store[FN]
    assert len(list(seed.parent_nodes)) == 2
    kind, inst = policy.acquire(eng, inv(0))
    assert kind == "fork" and inst.ancestry
    eng.net.sim_time = 61.0
    policy.acquire(eng, inv(1))              # traffic-driven renewal fires
    assert eng.coord.lease_telemetry[FN]["renewals"] >= 1
