"""Straggler detection + backup-fork mitigation."""
import jax
import numpy as np

from repro.core.instance import ModelInstance
from repro.net import Network
from repro.models import lm
from repro.platform.node import NodeRuntime
from repro.platform.straggler import StragglerMonitor


def test_detect_and_backup_fork(hello_cfg, hello_params):
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024) for i in range(4)]
    mon = StragglerMonitor(net, threshold=2.0)

    # healthy workers at ~100 ms/step, node2 degrades to 400 ms
    for step in range(5):
        mon.report("node0", 0.1)
        mon.report("node1", 0.1)
        mon.report("node2", 0.4)
    assert mon.stragglers() == ["node2"]

    # worker state lives on node2; its seed was prepared at deploy time
    worker = ModelInstance.create(nodes[2], hello_cfg.name, hello_params,
                                  registers={"step": 17})
    handle = nodes[2].prepare_fork(worker)
    backup = mon.mitigate("node2", handle, nodes[3])
    assert backup.registers["step"] == 17
    got = backup.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # no double-mitigation while a backup is in flight
    assert mon.stragglers() == []
    mon.resolve("node2", winner="node3")
    assert "node2" not in mon.backups


def test_no_false_positives_balanced():
    mon = StragglerMonitor(None, threshold=2.0)
    for step in range(5):
        for n in ("a", "b", "c"):
            mon.report(n, 0.1 + 0.01 * step)
    assert mon.stragglers() == []
