"""Serving engine + paged KV: decode parity, COW fork, refcounts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import lm
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedKV


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_arch("micro-hello"), compute_dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reference_greedy(cfg, params, prompt, n):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = lm.prefill(params, cfg, toks, cache_len=64)
    out = [int(jnp.argmax(logits[0]))]
    for t in range(n - 1):
        pos = jnp.asarray([len(prompt) + t], jnp.int32)
        logits, caches = lm.decode_step(
            params, cfg, caches, jnp.asarray([out[-1]], jnp.int32), pos)
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.mark.parametrize("backend", ["ref", "kernel"])
def test_engine_matches_model(setup, backend):
    cfg, params = setup
    prompt = [5, 9, 2, 77, 31]
    ref = _reference_greedy(cfg, params, prompt, 6)
    eng = ServingEngine(cfg, params, page_tokens=4, backend=backend)
    rid = eng.submit(prompt, max_tokens=6)
    assert eng.run_to_completion()[rid] == ref


def test_engine_continuous_batching(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, page_tokens=4, backend="ref")
    prompts = [[1, 2, 3], [9, 8, 7, 6], [42]]
    refs = [_reference_greedy(cfg, params, p, 4) for p in prompts]
    rids = [eng.submit(p, max_tokens=4) for p in prompts]
    res = eng.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert res[rid] == ref


def test_fork_request_zero_copy_and_divergence(setup):
    cfg, params = setup
    prompt = [3, 1, 4, 1, 5]
    # reference: parent alone
    eng0 = ServingEngine(cfg, params, page_tokens=4, backend="ref")
    r_ref = eng0.submit(prompt, max_tokens=8)
    ref = eng0.run_to_completion()[r_ref]

    eng = ServingEngine(cfg, params, page_tokens=4, backend="ref")
    r0 = eng.submit(prompt, max_tokens=8)
    eng.step()
    eng.step()
    b0 = eng.kv.bytes_in_use()
    k1 = eng.fork_request(r0, max_tokens=6)
    assert eng.kv.bytes_in_use() == b0          # COW: no page copied at fork
    # diverge the child: force a different continuation token
    eng.requests[k1].prompt[-1] = 123
    res = eng.run_to_completion()
    # a divergent child must never corrupt the parent (COW isolation)
    assert res[r0] == ref
    assert res[k1] != ref[3:3 + 6]


def test_paged_kv_refcount_free(setup):
    cfg, params = setup
    kv = PagedKV(2, 2, 16, page_tokens=4, dtype=jnp.float32)
    s0 = kv.new_seq()
    k = jnp.ones((2, 6, 2, 16))
    kv.write_prefill(s0, k, k)
    used0 = kv.pool.num_allocated(jnp.float32)
    s1 = kv.fork_sequence(s0)
    kv.free_seq(s0)
    assert kv.pool.num_allocated(jnp.float32) == used0  # child holds pages
    kv.free_seq(s1)
    assert kv.pool.num_allocated(jnp.float32) == 0


def test_cow_write_after_fork_isolates(setup):
    kv = PagedKV(1, 1, 8, page_tokens=4, dtype=jnp.float32)
    s0 = kv.new_seq()
    # 3 tokens: the first page column is only partially filled
    kv.write_prefill(s0, jnp.ones((1, 3, 1, 8)), jnp.ones((1, 3, 1, 8)))
    s1 = kv.fork_sequence(s0)
    # child appends into the shared partial column -> COW
    kv.append_token(s1, jnp.full((1, 1, 8), 9.0), jnp.full((1, 1, 8), 9.0))
    f = kv.frames_view()
    parent_page = kv.seqs[s0].k_pages[0, 0]
    child_page = kv.seqs[s1].k_pages[0, 0]
    assert parent_page != child_page
    np.testing.assert_array_equal(np.asarray(f[parent_page, :3]),
                                  np.ones((3, 1, 8), np.float32))
    np.testing.assert_array_equal(np.asarray(f[child_page, 3]),
                                  np.full((1, 8), 9.0, np.float32))


def test_windowed_arch_decode_in_engine():
    cfg = dataclasses.replace(get_arch("micro-hello"), compute_dtype="float32")
    # add a windowed layer variant
    from repro.configs.base import ArchConfig, AttnSpec, GroupSpec
    import dataclasses as dc
    cfg = dc.replace(cfg, groups=(GroupSpec(unit=(AttnSpec(window=8),), repeat=2),),
                     name="micro-win")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = [1, 2, 3, 4, 5, 6]
    eng = ServingEngine(cfg, params, page_tokens=4, backend="ref")
    rid = eng.submit(prompt, max_tokens=4)
    out = eng.run_to_completion()[rid]
    assert len(out) == 4
