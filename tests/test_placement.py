"""Placement plane (repro.placement): sharded multi-parent seeds, per-VMA
route plans, transport-/load-aware scheduling, and the coordinator
lifecycle fixes riding on them (parent-lost purge + telemetry,
exclusion-stable fallback order, shard re-replication)."""
import types

import jax
import numpy as np
import pytest

from repro.core.instance import ModelInstance
from repro.core.pagetable import VMA
from repro.fork import ForkPolicy
from repro.net import Network
from repro.placement import (HotColdPolicy, RoundRobinScheduler, RoutePlan,
                             ShardedSeed, SpreadPolicy,
                             TransportAwareScheduler, VMAInfo, VMARoute,
                             route_demand)
from repro.platform.coordinator import Coordinator, FunctionDef
from repro.platform.node import NodeRuntime

from conftest import FakeClock


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


@pytest.fixture()
def big_platform(hello_cfg, hello_params):
    """An 8-node coordinator cluster (enough for S=3 seeds + children)."""
    net = Network()
    clock = FakeClock()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=1024, clock=clock)
             for i in range(8)]
    coord = Coordinator(net, nodes, clock=clock)

    def behavior(inst, ctx):
        inst.ensure_tensor(inst.leaf_names[0])
        return {"ok": True}

    coord.register_function(FunctionDef(
        name="f", arch=hello_cfg.name,
        make_params=lambda: hello_params, behavior=behavior))
    return net, nodes, coord, clock


def _fake_nodes(*ids):
    return {i: types.SimpleNamespace(node_id=i, alive=True) for i in ids}


# ---------------------------------------------------------------------------
# schedulers (satellite: exclusion-stable, drift-free round robin)
# ---------------------------------------------------------------------------


def test_round_robin_rotates_deterministically():
    sched = RoundRobinScheduler()
    nodes = _fake_nodes("a", "b", "c")
    got = [sched.pick(nodes).node_id for _ in range(6)]
    assert got == ["a", "b", "c", "a", "b", "c"]


def test_round_robin_exclusion_does_not_drift():
    """The old `self._rr % len(filtered)` cursor re-indexed the filtered
    list, so an exclusion shifted every later pick and could hand out the
    same node back-to-back.  The scheduler skips excluded nodes IN PLACE."""
    sched = RoundRobinScheduler()
    nodes = _fake_nodes("a", "b", "c")
    assert sched.pick(nodes).node_id == "a"
    # old bug: cursor=1 over filtered [b, c] -> "c"; then "c" again
    assert sched.pick(nodes, exclude={"b"}).node_id == "c"
    assert sched.pick(nodes).node_id == "a"
    seq = [sched.pick(nodes, exclude={"b"}).node_id for _ in range(4)]
    assert seq == ["c", "a", "c", "a"], "exclusion must not skew rotation"


def test_round_robin_dead_node_skipped_in_place():
    sched = RoundRobinScheduler()
    nodes = _fake_nodes("a", "b", "c")
    nodes["b"].alive = False
    assert [sched.pick(nodes).node_id for _ in range(4)] == \
        ["a", "c", "a", "c"]
    nodes["b"].alive = True
    got = [sched.pick(nodes).node_id for _ in range(3)]
    assert set(got) == {"a", "b", "c"}, "revived node rejoins the rotation"


def test_round_robin_no_eligible_raises():
    sched = RoundRobinScheduler()
    with pytest.raises(RuntimeError, match="no live nodes"):
        sched.pick(_fake_nodes())
    nodes = _fake_nodes("a")
    with pytest.raises(RuntimeError, match="no live nodes"):
        sched.pick(nodes, exclude={"a"})


def test_transport_aware_prefers_paid_connection():
    """RC's 4 ms QP connect amortizes: a candidate that already holds the
    (child, owner) RC connection scores 0 setup and wins."""
    net = Network()
    for i in range(4):
        NodeRuntime(f"node{i}", net, page_elems=64)
    sched = TransportAwareScheduler(net)
    net.note_connection("rc", "node2", "node0")
    demand = route_demand(["node0"], ["rc"])
    nodes = {i: net.nodes[i] for i in net.nodes}
    assert sched.pick(nodes, exclude={"node0"}, demand=demand).node_id \
        == "node2"


def test_transport_aware_avoids_backlogged_channel():
    net = Network()
    for i in range(3):
        NodeRuntime(f"node{i}", net, page_elems=64)
    sched = TransportAwareScheduler(net)
    net.set_channel_busy("node1", "node0", 5.0)     # 5 s of queued transfer
    demand = route_demand(["node0"], [None])
    nodes = {i: net.nodes[i] for i in net.nodes}
    assert sched.pick(nodes, exclude={"node0"}, demand=demand).node_id \
        == "node2"


def test_transport_aware_falls_back_to_round_robin():
    net = Network()
    for i in range(3):
        NodeRuntime(f"node{i}", net, page_elems=64)
    sched = TransportAwareScheduler(net)
    nodes = {i: net.nodes[i] for i in net.nodes}
    got = [sched.pick(nodes).node_id for _ in range(4)]
    assert got == ["node0", "node1", "node2", "node0"]


# ---------------------------------------------------------------------------
# placement policies / route plans
# ---------------------------------------------------------------------------


def test_spread_policy_balances_bytes():
    vmas = [VMAInfo(f"v{i}", nb) for i, nb in
            enumerate([8000, 6000, 4000, 2000, 2000, 2000])]
    plan = SpreadPolicy().plan(vmas, ["p0", "p1"])
    load = {"p0": 0, "p1": 0}
    for v in vmas:
        load[plan[v.name].owner] += v.nbytes
    total = sum(load.values())
    assert max(load.values()) <= 0.6 * total, f"unbalanced: {load}"
    # deterministic: same inputs, same plan
    again = SpreadPolicy().plan(vmas, ["p0", "p1"])
    assert plan.to_dict() == again.to_dict()


def test_spread_policy_offset_rotates_assignment():
    vmas = [VMAInfo("a", 100), VMAInfo("b", 100)]
    p0 = SpreadPolicy().plan(vmas, ["p0", "p1"], offset=0)
    p1 = SpreadPolicy().plan(vmas, ["p0", "p1"], offset=1)
    assert p0["a"].owner != p1["a"].owner, "offset must rotate ties"


def test_hot_cold_policy_classifies_and_routes():
    pol = HotColdPolicy(hot="dct", cold="shared_fs")
    assert pol.is_cold("opt/m") and pol.is_cold("layers/0/adam/v")
    assert not pol.is_cold("wopt") and not pol.is_cold("tok")
    vmas = [VMAInfo("tok", 100), VMAInfo("opt/m", 100)]
    plan = pol.plan(vmas, ["p0"])
    assert plan["tok"].transport == "dct"
    assert plan["opt/m"].transport == "shared_fs"
    assert pol.transport_hints() == ["dct", "shared_fs"]


def test_policy_rejects_unknown_transport():
    with pytest.raises(ValueError, match="unknown transport"):
        SpreadPolicy(transport="bogus")
    with pytest.raises(ValueError, match="unknown transport"):
        HotColdPolicy(hot="bogus")


def test_route_plan_roundtrip_and_reroute():
    plan = RoutePlan(routes={"a": VMARoute("p0", "dct"),
                             "b": VMARoute("p1")})
    back = RoutePlan.from_dict(plan.to_dict())
    assert back["a"] == VMARoute("p0", "dct") and back["b"].transport is None
    assert plan.owners() == ["p0", "p1"]
    fallback = RoutePlan(routes={"a": VMARoute("p1", "dct"),
                                 "b": VMARoute("p1")})
    plan.reroute("p0", fallback)
    assert plan["a"].owner == "p1"


# ---------------------------------------------------------------------------
# VMA / descriptor route fields
# ---------------------------------------------------------------------------


def test_vma_route_fields_roundtrip():
    vma = VMA.new_local("w", (64,), "float32", np.arange(1, dtype=np.int32))
    vma.ancestry = ["p0", "origin"]
    vma.transport = "tpu_ici"
    back = VMA.from_table_dict(vma.table_dict())
    assert back.ancestry == ["p0", "origin"]
    assert back.transport == "tpu_ici"
    assert back.owner_at(2, ()) == "origin"
    # legacy table dicts (no route keys) still deserialize
    legacy = {k: v for k, v in vma.table_dict().items()
              if k not in ("ancestry", "transport")}
    old = VMA.from_table_dict(legacy)
    assert old.ancestry == [] and old.transport is None
    assert old.owner_at(1, ["inst-parent"]) == "inst-parent"


def test_child_view_builds_owner_chain():
    vma = VMA.new_local("w", (64,), "float32", np.arange(1, dtype=np.int32))
    child = vma.child_view(7, parent_node="p0", default_ancestry=["origin"])
    # parent's pages were all local, so its (empty) chain defers to the
    # descriptor-level default for the deeper hops
    assert child.ancestry == ["p0", "origin"]
    grand = child.child_view(8, parent_node="p1",
                             default_ancestry=["ignored"])
    assert grand.ancestry == ["p1"] + child.ancestry
    assert grand.transport == child.transport


def test_prepared_descriptor_carries_routes(cluster, hello_cfg, hello_params):
    from repro.core.descriptor import Descriptor
    net, nodes = cluster
    inst = ModelInstance.create(nodes[0], hello_cfg.name, hello_params)
    inst.aspace[inst.leaf_names[0]].transport = "shared_fs"
    handle = nodes[0].prepare_fork(inst)
    desc = Descriptor.from_bytes(nodes[0].seeds[handle.handler_id].blob)
    route = desc.route_for(inst.leaf_names[0])
    assert route["owner"] == "node0" and route["transport"] == "shared_fs"
    # unannotated VMAs fall back to the implicit single-parent route
    assert desc.route_for("no-such-vma")["owner"] == "node0"


# ---------------------------------------------------------------------------
# sharded seeds
# ---------------------------------------------------------------------------


def test_deploy_sharded_seed(big_platform):
    net, nodes, coord, clock = big_platform
    seed = coord.deploy_seed("f", nodes[0], replicas=3)
    assert isinstance(seed, ShardedSeed) and seed.replicas == 3
    assert len(set(seed.parent_nodes)) == 3, "replicas must span nodes"
    assert coord.seed_store["f"] is seed
    assert all(h.alive and not h.expired for h in seed.handles)
    # every replica holds a fully materialized copy
    for h in seed.handles[1:]:
        entry = coord.nodes[h.parent_node].seeds[h.handler_id]
        assert entry.instance.resident_fraction() == 1.0


def test_unsharded_deploy_still_returns_plain_handle(big_platform):
    from repro.fork import ForkHandle
    net, nodes, coord, clock = big_platform
    seed = coord.deploy_seed("f", nodes[0])
    assert isinstance(seed, ForkHandle)


def test_sharded_resume_routes_vmas_across_replicas(big_platform,
                                                    hello_params):
    net, nodes, coord, clock = big_platform
    seed = coord.deploy_seed("f", nodes[0], replicas=3)
    net.reset_meter()
    child = seed.resume_on(nodes[5])
    owners = {vma.ancestry[0] for vma in child.aspace.values()}
    assert owners <= set(seed.parent_nodes)
    assert len(owners) > 1, "VMAs must spread across the replica set"
    got = child.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the bytes actually moved from more than one parent NIC
    busy = [net.node_busy(p) for p in owners]
    assert sum(b > 0 for b in busy) > 1


def test_sharded_fan_out_rotates_primaries(big_platform):
    net, nodes, coord, clock = big_platform
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    children = seed.fan_out([nodes[4], nodes[5], nodes[6], nodes[7]])
    assert len(children) == 4
    assert len(seed.serve_counts) == 2, "both replicas must serve VMAs"


def test_sharded_seed_lease_surface(big_platform):
    net, nodes, coord, clock = big_platform
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    assert seed.alive and not seed.expired
    assert seed.lease_deadline == min(h.lease_deadline for h in seed.handles)
    seed.renew()
    seed.revoke()
    assert all(h.generation == 1 for h in seed.handles)
    child = seed.resume_on(nodes[5])            # fresh generation serves
    assert child.ancestry
    seed.reclaim(free_instance=False)
    assert not seed.alive


# ---------------------------------------------------------------------------
# degradation: crash a replica mid fan-out (satellite)
# ---------------------------------------------------------------------------


def test_shard_degradation_reroute_and_rereplicate(big_platform):
    net, nodes, coord, clock = big_platform
    seed = coord.deploy_seed("f", nodes[0], replicas=3)
    parents = list(seed.parent_nodes)

    out1, c1 = coord.invoke("f", node=nodes[5], policy="fork")
    assert out1["ok"] and c1.ancestry

    victim = parents[1]
    coord.nodes[victim].crash()

    # remaining shards keep serving: the resume purges the lost replica
    # and routes every VMA over the survivors
    out2, c2 = coord.invoke("f", node=nodes[6], policy="fork")
    assert out2["ok"] and c2.ancestry
    owners = {vma.ancestry[0] for vma in c2.aspace.values()}
    assert victim not in owners
    assert owners <= set(seed.parent_nodes)
    assert seed.replicas == 2

    # the loss is telemetered...
    assert coord.lease_telemetry["f"]["parent_lost"] == 1

    # ...and gc re-replicates back to the target on a spare node
    freed = coord.gc()
    assert freed["rereplicated"] == 1
    assert seed.replicas == 3 and victim not in seed.parent_nodes
    assert coord.lease_telemetry["f"]["rereplicated"] == 1
    out3, c3 = coord.invoke("f", node=nodes[7], policy="fork")
    assert out3["ok"] and c3.ancestry


def test_fully_lost_sharded_seed_falls_back_to_coldstart(big_platform):
    net, nodes, coord, clock = big_platform
    seed = coord.deploy_seed("f", nodes[0], replicas=2)
    for p in list(seed.parent_nodes):
        coord.nodes[p].crash()
    live = next(n for n in nodes if n.alive)
    out, inst = coord.invoke("f", node=live, policy="fork")
    assert out["ok"]
    assert coord.lease_telemetry["f"]["parent_lost"] == 2
    # coldstart re-seeded the platform on a live node
    assert coord.seed_store["f"].parent_node == live.node_id


def test_plain_seed_parent_loss_purged_on_sight(platform):
    """Satellite fix: a plain handle whose parent dropped out of
    network.nodes is purged (and telemetered) the moment it is seen, not
    left for gc to eventually notice."""
    net, nodes, coord, clock = platform
    coord.invoke("f")
    handle = coord.seed_store["f"]
    coord.nodes[handle.parent_node].crash()
    assert coord._fresh_seed("f") is None
    assert "f" not in coord.seed_store
    assert coord.lease_telemetry["f"]["parent_lost"] == 1
    # and the invoke path still serves via coldstart re-seeding
    live = next(n for n in nodes if n.alive)
    out, inst = coord.invoke("f", node=live, policy="fork")
    assert out["ok"]
    assert coord.seed_store["f"].parent_node != handle.parent_node


# ---------------------------------------------------------------------------
# per-VMA transport routing
# ---------------------------------------------------------------------------


def _hot_cold_parent(node, cfg, params):
    inst = ModelInstance.create(node, cfg.name, params, kind="weights")
    inst.add_tensor("opt/m", np.zeros(4096, np.float32))
    return inst


def test_single_parent_placement_routes_transports(cluster, hello_cfg,
                                                   hello_params):
    net, nodes = cluster
    parent = _hot_cold_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(),
                             placement=HotColdPolicy(hot="dct",
                                                     cold="shared_fs"))
    assert child.aspace["opt/m"].transport == "shared_fs"
    assert child.aspace[child.leaf_names[0]].transport == "dct"
    net.reset_meter()
    child.ensure_tensor("opt/m")
    assert net.meter["shared_fs.bytes"] > 0 and net.meter["dct.bytes"] == 0
    child.ensure_tensor(child.leaf_names[0])
    assert net.meter["dct.bytes"] > 0


def test_routed_transport_sticks_across_generations(cluster, hello_cfg,
                                                    hello_params):
    """A VMA pinned to a fabric keeps it when the child is re-prepared as
    a seed (fork trees): the route rides the descriptor."""
    net, nodes = cluster
    parent = _hot_cold_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(),
                             placement=HotColdPolicy(cold="shared_fs"))
    reseed = nodes[1].prepare_fork(child)
    grand = reseed.resume_on(nodes[2])
    assert grand.aspace["opt/m"].transport == "shared_fs"
    net.reset_meter()
    grand.ensure_tensor("opt/m")
    assert net.meter["shared_fs.bytes"] > 0


def test_async_prefetch_honors_vma_route(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _hot_cold_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(async_prefetch=4),
                             placement=HotColdPolicy(cold="shared_fs"))
    net.reset_meter()
    child.prefetch_engine.issue("opt/m", np.arange(4))
    assert net.meter["shared_fs.async_ops"] > 0
    assert net.meter["dct.bytes"] == 0
    child.prefetch_engine.drain("opt/m")


# ---------------------------------------------------------------------------
# node-busy ledger (parent NIC accounting behind the fan-out benchmark)
# ---------------------------------------------------------------------------


def test_node_busy_ledger_charges_both_endpoints(cluster, hello_cfg,
                                                 hello_params):
    net, nodes = cluster
    parent = ModelInstance.create(nodes[0], hello_cfg.name, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1])
    net.reset_meter()
    child.ensure_all()
    assert net.node_busy("node0") > 0
    assert net.node_busy("node0") == pytest.approx(net.node_busy("node1"))
    assert net.node_busy("node3") == 0.0
    net.reset_meter()
    assert net.node_busy("node0") == 0.0
