"""Per-arch smoke tests: reduced configs of the same family — one forward +
train step on CPU asserting shapes and no NaNs; prefill/decode agreement."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_arch, list_archs, reduce_for_smoke, shape_applicable
from repro.models import lm
from repro.models.flops import model_flops, param_counts
from repro.training.optimizer import init_opt_state
from repro.training.train_step import TrainConfig, make_train_step

ASSIGNED = ["stablelm-3b", "gemma3-1b", "granite-34b", "qwen2-7b",
            "zamba2-2.7b", "kimi-k2-1t-a32b", "moonshot-v1-16b-a3b",
            "musicgen-large", "xlstm-1.3b", "chameleon-34b"]


def _tokens(cfg, key, B, S):
    if cfg.num_codebooks > 1:
        return jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = reduce_for_smoke(get_arch(arch))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    B, S = 2, 32
    toks = _tokens(cfg, key, B, S)
    h = lm.forward(params, cfg, toks)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    step = make_train_step(cfg, TrainConfig(microbatches=1, q_chunk=S,
                                            xent_chunk=S, warmup=0))
    opt = init_opt_state(params)
    params2, opt2, m = step(params, opt, toks, toks)
    assert not bool(jnp.isnan(m["loss"])) and float(m["loss"]) > 0
    assert not bool(jnp.isnan(m["gnorm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_matches_forward(arch):
    cfg = reduce_for_smoke(get_arch(arch))
    key = jax.random.PRNGKey(1)
    params = lm.init_params(key, cfg)
    B, S, P = 2, 24, 20
    toks = _tokens(cfg, key, B, S)
    full = lm.logits_fn(params, cfg, toks)
    logits, caches = lm.prefill(params, cfg, toks[:, :P], cache_len=S)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1]),
                               rtol=2e-2, atol=5e-3)
    for t in range(P, S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, caches = lm.decode_step(params, cfg, caches, toks[:, t], pos)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=2e-2, atol=5e-3)


def test_microbatch_accum_equivalence():
    """mb=2 gradient accumulation must match mb=1 on the same global batch."""
    cfg = reduce_for_smoke(get_arch("stablelm-3b"))
    key = jax.random.PRNGKey(2)
    params = lm.init_params(key, cfg)
    toks = _tokens(cfg, key, 4, 32)
    outs = {}
    for mb in (1, 2):
        step = make_train_step(cfg, TrainConfig(microbatches=mb, q_chunk=32,
                                                xent_chunk=32, warmup=0,
                                                peak_lr=1e-2))
        p2, o2, m = step(params, init_opt_state(params), toks, toks)
        outs[mb] = (float(m["loss"]), p2)
    assert abs(outs[1][0] - outs[2][0]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[2][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_exact_causal_matches_chunked():
    cfg = reduce_for_smoke(get_arch("qwen2-7b"))
    params = lm.init_params(jax.random.PRNGKey(3), cfg)
    toks = _tokens(cfg, jax.random.PRNGKey(3), 2, 64)
    h1 = lm.forward(params, cfg, toks, q_chunk=16, exact_causal=False)
    h2 = lm.forward(params, cfg, toks, q_chunk=16, exact_causal=True)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-5)


def test_window_attention_masks_history():
    """A token beyond the window must not influence the output."""
    cfg = reduce_for_smoke(get_arch("gemma3-1b"))
    params = lm.init_params(jax.random.PRNGKey(4), cfg)
    S = 64
    toks = _tokens(cfg, jax.random.PRNGKey(4), 1, S)
    h1 = lm.logits_fn(params, cfg, toks)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    h2 = lm.logits_fn(params, cfg, toks2)
    # windows in the smoke config are 32: position 63 attends [32..63] in
    # local layers; global layers see everything, so just check sensitivity
    # pattern: early positions change, and the change at pos0 is bounded.
    assert float(jnp.abs(h1[0, 1] - h2[0, 1]).max()) > 0


def test_param_counts_match_alloc():
    for arch in ("stablelm-3b", "xlstm-1.3b", "zamba2-2.7b", "moonshot-v1-16b-a3b"):
        cfg = reduce_for_smoke(get_arch(arch))
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        n_alloc = sum(x.size for x in jax.tree.leaves(params))
        n_calc, _, _ = param_counts(cfg)
        assert n_alloc == n_calc, (arch, n_alloc, n_calc)


def test_model_flops_positive_all_cells():
    for arch in ASSIGNED:
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if ok:
                assert model_flops(cfg, shape) > 0
