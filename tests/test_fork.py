"""The remote-fork primitive: prepare/resume semantics, COW isolation,
multi-hop lineage, access control, fallback, caching, prefetch — driven
through the capability-style ForkHandle API (repro.fork)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.instance import ModelInstance
from repro.net import AccessRevoked
from repro.fork import ForkPolicy
from repro.models import lm


def _mk_parent(node, cfg, params):
    return ModelInstance.create(node, cfg.name, params, kind="weights")


def test_resume_lazy_then_equal(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(lazy=True))
    assert child.resident_fraction() == 0.0
    got = child.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert child.resident_fraction() == 1.0
    assert net.meter["rdma_bytes"] > 0


def test_bad_credentials_rejected(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    with pytest.raises(PermissionError):
        dataclasses.replace(handle, auth_key=handle.auth_key + 1) \
            .resume_on(nodes[1])
    with pytest.raises(PermissionError):
        dataclasses.replace(handle, handler_id=handle.handler_id + 99) \
            .resume_on(nodes[1])


def test_cow_isolation(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1])
    name = child.leaf_names[2]
    before = np.asarray(parent.ensure_tensor(name)).copy()
    child.write_tensor(name, jnp.ones(child.aspace[name].shape))
    np.testing.assert_array_equal(np.asarray(parent.ensure_tensor(name)), before)
    # and the child sees its own write
    np.testing.assert_array_equal(np.asarray(child.ensure_tensor(name)),
                                  np.ones(child.aspace[name].shape))


def test_page_granular_cow(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1])
    name = max(child.leaf_names, key=lambda n: child.aspace[n].npages)
    vma = child.aspace[name]
    assert vma.npages >= 2
    pe = nodes[1].pool.page_elems
    child.write_pages(name, [0], jnp.full((1, pe), 3.14))
    # page 0 dirty+local; other pages still remote
    assert vma.flags[0] & 2
    assert vma.owner_hop[0] == 0 and vma.owner_hop[1] == 1
    got = np.asarray(child.ensure_tensor(name)).ravel()
    want = np.asarray(parent.ensure_tensor(name)).ravel().copy()
    want[:pe] = 3.14
    np.testing.assert_allclose(got[:pe], want[:pe])
    np.testing.assert_array_equal(got[pe:], want[pe:])


def test_multihop_three_nodes(cluster, hello_cfg, hello_params):
    """grandchild reads hop-2 pages from the grandparent directly."""
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(lazy=True))
    # child materializes only one tensor, rest stay on the grandparent
    touched = child.leaf_names[0]
    child.ensure_tensor(touched)
    handle2 = nodes[1].prepare_fork(child)
    gchild = handle2.resume_on(nodes[2], ForkPolicy(lazy=True))
    hops = {n: set(np.unique(gchild.aspace[n].owner_hop).tolist())
            for n in gchild.leaf_names}
    assert hops[touched] == {1}          # owned by child
    untouched = [n for n in gchild.leaf_names if n != touched]
    assert any(2 in hops[n] for n in untouched)   # still on grandparent
    got = gchild.materialize_pytree()
    for a, b in zip(jax.tree.leaves(hello_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reclaim_revokes_remote_access(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(lazy=True))
    handle.reclaim()
    name = child.leaf_names[0]
    # DC target destroyed -> RNIC rejects; fallback daemon still serves
    # (pages are alive because the instance itself wasn't freed)
    child.ensure_tensor(name)
    assert child.stats["pages_rpc"] > 0 and child.stats["pages_rdma"] == 0


def test_swap_out_triggers_fallback(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(lazy=True))
    name = child.leaf_names[1]
    before = np.asarray(parent.ensure_tensor(name)).copy()
    nodes[0].swap_out_vma(parent, name)
    got = np.asarray(child.ensure_tensor(name))
    np.testing.assert_array_equal(got, before)
    assert child.stats["pages_rpc"] > 0


def test_sibling_page_cache(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    # sibling-cache participation travels in the policy now
    c1 = handle.resume_on(nodes[1], ForkPolicy(sibling_cache=True))
    c1.ensure_all()
    rdma_after_first = net.meter["rdma_bytes"]
    c2 = handle.resume_on(nodes[1])
    c2.ensure_all()
    assert c2.stats["pages_cached"] > 0 and c2.stats["pages_rdma"] == 0
    # only the descriptor fetch hit the wire the second time
    assert net.meter["rdma_bytes"] - rdma_after_first < 8192
    assert nodes[1].page_cache_stats["hits"] > 0


def test_prefetch_reduces_faults(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    name = max(parent.aspace, key=lambda n: parent.aspace[n].npages)
    npages = parent.aspace[name].npages

    c0 = handle.resume_on(nodes[1])
    for p in range(npages):
        c0.touch_pages(name, [p], prefetch=0)
    c1 = handle.resume_on(nodes[2])
    for p in range(npages):
        c1.touch_pages(name, [p], prefetch=2)
    assert c1.stats["faults"] < c0.stats["faults"]


def test_parent_crash_surfaces(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = _mk_parent(nodes[0], hello_cfg, hello_params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(lazy=True))
    nodes[0].crash()
    with pytest.raises(ConnectionError):
        child.ensure_all()
    # and a new fork from the dead parent fails up front
    with pytest.raises(ConnectionError):
        handle.resume_on(nodes[2])


def test_registers_travel_in_descriptor(cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = ModelInstance.create(nodes[0], hello_cfg.name, hello_params,
                                  registers={"step": 41, "temp": 0.7})
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1])
    assert child.registers["step"] == 41
    assert abs(child.registers["temp"] - 0.7) < 1e-9
