"""Property tests over the replay engine (skipped without hypothesis)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.sim import ForkOnDemand, ReplayEngine, SimFunction, Trace  # noqa: E402

PAGES = 8            # pages per container at page_elems=1024 (32 KiB fp32)
TOUCH = 0.5          # handler touches 4 of them, every invocation


def fork_replay(replicas, seed, n_nodes, counts):
    trace = Trace("prop", {"f": counts})
    fn = SimFunction("f", state_bytes=PAGES * 1024 * 4, touch_frac=TOUCH,
                     hold_s=60.0)
    eng = ReplayEngine(trace, ForkOnDemand(replicas=replicas, prefetch=0),
                       [fn], n_nodes=n_nodes, seed=seed, page_elems=1024)
    return eng, eng.run()


@settings(max_examples=12, deadline=None)
@given(replicas=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 2**16),
       n_nodes=st.sampled_from([4, 9, 16]),
       counts=st.lists(st.integers(0, 6), min_size=1, max_size=4)
       .filter(lambda c: sum(c) > 0).map(tuple))
def test_fork_bytes_moved_policy_invariant(replicas, seed, n_nodes, counts):
    """At a fixed touch ratio, ForkOnDemand moves exactly
    touched-pages-per-child * children payload pages — independent of the
    replica count, the arrival jitter seed and the cluster size.  Sharding
    and placement may change WHERE pages come from, never HOW MANY."""
    eng, res = fork_replay(replicas, seed, n_nodes, counts)
    touched = max(1, round(PAGES * TOUCH))
    wire = res.payload_pages["pages_rdma"] + res.payload_pages["pages_rpc"]
    assert res.decisions.get("fork", 0) == res.invocations
    assert wire + res.payload_pages["pages_cached"] \
        == touched * res.invocations
    # the meter agrees with the per-instance stats it aggregates
    page_bytes = 1024 * 4
    assert eng.net.meter["dct.bytes"] >= wire * page_bytes


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_arrival_schedule_reproducible_across_engines(seed):
    t1 = Trace("p", {"f": (3, 1)})
    import random
    assert t1.arrivals(random.Random(seed)) == t1.arrivals(random.Random(seed))
