"""The doorbell-batched, pipelined demand-paging path (PR 3): vectorized
fault handling vs a per-page reference, extent allocation, max_sge op
accounting, channel-overlap sim accounting, and the async PrefetchEngine."""
import math
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.instance import ModelInstance
from repro.core.pagetable import VMA
from repro.fork import ForkPolicy
from repro.memory.pool import PagePool
from repro.net import Network, contiguous_runs, resolve_transport
from repro.platform.node import NodeRuntime

TRANSPORTS = ("dct", "rc", "rpc", "tpu_ici", "shared_fs")
PAGE_ELEMS = 256


def _cluster(cache=False, n=2):
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=PAGE_ELEMS,
                         cache_enabled=cache) for i in range(n)]
    return net, nodes


def _params(rng_seed=0, npages=23):
    rng = np.random.default_rng(rng_seed)
    return {
        "w": jnp.asarray(rng.standard_normal(npages * PAGE_ELEMS - 37),
                         jnp.float32),
        "b": jnp.asarray(rng.standard_normal(3 * PAGE_ELEMS + 11),
                         jnp.float32),
    }


def _reference_child(params):
    """Per-page, prefetch-0, no-cache fetch — the scalar reference path."""
    net, nodes = _cluster(cache=False)
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(nodes[1])
    for name in child.leaf_names:
        for p in range(child.aspace[name].npages):
            child.touch_pages(name, [p])
    return child.materialize_pytree()


# ---------------------------------------------------------------------------
# property: batched/coalesced handler == per-page reference, everywhere
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tname", TRANSPORTS)
@pytest.mark.parametrize("cache", (False, True), ids=("nocache", "cache"))
@pytest.mark.parametrize("mode", ("pf0", "pf4", "async"))
def test_batched_fault_handler_matches_per_page_reference(tname, cache, mode):
    """Across every transport × cache setting × prefetch mode, the batched
    handler (random-subset batched touches, then full materialize) must
    produce byte-identical tensors to the scalar per-page reference."""
    params = _params()
    ref = _reference_child(params)
    policy = ForkPolicy(
        page_fetch=tname, descriptor_fetch=tname,
        prefetch=4 if mode == "pf4" else 0,
        async_prefetch=4 if mode == "async" else 0)
    net, nodes = _cluster(cache=cache)
    parent = ModelInstance.create(nodes[0], "t", params)
    handle = nodes[0].prepare_fork(parent)
    # crc32, not hash(): stable across processes so any failure reproduces
    rng = np.random.default_rng(zlib.crc32(f"{tname}/{cache}/{mode}".encode()))
    for trial in range(2):       # second child exercises the sibling cache
        child = handle.resume_on(nodes[1], policy)
        for name in child.leaf_names:
            npages = child.aspace[name].npages
            pages = rng.choice(npages, size=max(1, npages // 2),
                               replace=False)
            child.touch_pages(name, pages)
        got = child.materialize_pytree()
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(got[k]), np.asarray(ref[k]),
                err_msg=f"{tname}/cache={cache}/{mode}/{k}")
    if cache:
        assert nodes[1].page_cache_stats["hits"] > 0


def test_want_mask_matches_scalar_reference():
    """VMA.want_mask (mask-op prefetch expansion) reproduces the old
    per-page set-loop semantics on randomized residency patterns."""
    rng = np.random.default_rng(7)
    for _ in range(50):
        n = int(rng.integers(1, 64))
        v = VMA.new_local("w", (n * 4,), "float32",
                          np.arange(n, dtype=np.int32)).child_view(1)
        resident = rng.random(n) < 0.4
        if resident.any():
            v.mark_resident(np.nonzero(resident)[0],
                            np.nonzero(resident)[0] + 100)
        req = rng.choice(n, size=int(rng.integers(1, n + 1)), replace=False)
        prefetch = int(rng.integers(0, 9))
        # scalar reference: the pre-PR-3 loop
        missing = set(v.missing_pages().tolist())
        want = [p for p in req.tolist() if p in missing]
        extra = []
        for p in want:
            extra.extend(q for q in range(p + 1, p + 1 + prefetch)
                         if q in missing and q not in want)
        expect = sorted(set(want) | set(extra))
        got = np.nonzero(v.want_mask(req, prefetch))[0].tolist()
        assert got == expect, (n, req.tolist(), prefetch, resident.tolist())


# ---------------------------------------------------------------------------
# extent-aware allocation
# ---------------------------------------------------------------------------


def test_alloc_zero_is_a_noop():
    pool = PagePool(page_elems=64)
    assert pool.alloc("float32", 0).size == 0
    assert pool.num_allocated("float32") == 0


def test_alloc_returns_contiguous_extent():
    pool = PagePool(page_elems=64, grow_frames=256)
    a = pool.alloc("float32", 64)
    assert (np.diff(a) == 1).all()
    b = pool.alloc("float32", 32)
    assert (np.diff(b) == 1).all()
    assert set(a.tolist()).isdisjoint(b.tolist())


def test_alloc_best_fit_prefers_smallest_hole():
    pool = PagePool(page_elems=64, grow_frames=64)
    base = pool.alloc("float32", 64)              # frames 0..63
    pool.free("float32", base[10:14])             # 4-frame hole
    pool.free("float32", base[30:50])             # 20-frame hole
    got = pool.alloc("float32", 4)
    assert got.tolist() == base[10:14].tolist()   # best fit, not first fit
    assert (10, 4) not in pool.free_extents("float32")


def test_alloc_spans_runs_when_fragmented():
    pool = PagePool(page_elems=64, grow_frames=16)
    a = pool.alloc("float32", 16)
    # free every other pair: no run longer than 2 remains
    for s in range(0, 16, 4):
        pool.free("float32", a[s:s + 2])
    got = pool.alloc("float32", 6)
    assert len(set(got.tolist())) == 6
    assert contiguous_runs(got) == 3              # spans the largest runs


def test_free_coalesces_extents():
    pool = PagePool(page_elems=64, grow_frames=32)
    a = pool.alloc("float32", 32)
    pool.free("float32", a[8:16])
    pool.free("float32", a[16:24])
    assert (8, 16) in pool.free_extents("float32")


# ---------------------------------------------------------------------------
# doorbell / max_sge op accounting
# ---------------------------------------------------------------------------


def test_contiguous_fault_is_one_doorbell_op():
    """Acceptance: a contiguous 64-page fault records <= ceil(64/max_sge)
    ops (it is in fact ONE op = one SGE covering the whole extent)."""
    net, nodes = _cluster()
    params = {"w": jnp.zeros(64 * PAGE_ELEMS, jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(nodes[1])
    net.reset_meter()
    child.fetch_pages("w", np.arange(64))
    max_sge = resolve_transport("dct").max_sge
    assert net.meter["dct.ops"] <= math.ceil(64 / max_sge)
    assert net.meter["dct.ops"] == 1 and net.meter["dct.sges"] == 1


def test_scattered_read_pays_per_run_and_caps_at_max_sge():
    net = Network()
    node = NodeRuntime("n0", net, page_elems=64)
    key = net.create_dc_target("n0")
    frames = node.pool.alloc("float32", 128)
    max_sge = resolve_transport("dct").max_sge
    net.reset_meter()
    net.read_pages("n1", "n0", "float32", frames, key)          # 1 run
    contiguous = {"ops": net.meter["dct.ops"], "t": net.sim_time}
    assert contiguous["ops"] == 1 and net.meter["dct.sges"] == 1
    net.reset_meter()
    net.reset_connections()
    scattered = frames[::2]                                      # 64 runs
    net.read_pages("n1", "n0", "float32", scattered, key)
    assert net.meter["dct.sges"] == 64
    assert net.meter["dct.ops"] == math.ceil(64 / max_sge)
    # fragmentation is visible in sim time: more doorbells, same per-byte
    per_byte = 64 * 64 * 4 / net.model.rdma_bw
    assert net.sim_time - net.model.dct_setup == pytest.approx(
        math.ceil(64 / max_sge) * net.model.rdma_lat + per_byte)


def test_every_backend_meters_sges():
    for tname in TRANSPORTS:
        net = Network()
        node = NodeRuntime("n0", net, page_elems=64)
        key = net.create_dc_target("n0")
        frames = node.pool.alloc("float32", 8)
        net.read_pages("n1", "n0", "float32", frames[::2], key,
                       transport=tname)
        cls = resolve_transport(tname)
        assert net.meter[f"{tname}.sges"] == 4
        assert net.meter[f"{tname}.ops"] == math.ceil(4 / cls.max_sge)


def test_malformed_max_sge_rejected_at_registration():
    from repro.net import Transport, register_transport

    class BadSge(Transport):
        name = "_test_badsge"
        one_sided = True
        legacy_meter = "rdma"
        max_sge = 0

        def op_latency(self):
            return 0.0

        def bandwidth(self):
            return 1.0

    with pytest.raises(ValueError, match="max_sge"):
        register_transport(BadSge)


# ---------------------------------------------------------------------------
# channel-overlap sim accounting
# ---------------------------------------------------------------------------


def test_async_read_occupies_channel_not_clock():
    net = Network()
    node = NodeRuntime("n0", net, page_elems=64)
    key = net.create_dc_target("n0")
    frames = node.pool.alloc("float32", 16)
    t0 = net.sim_time
    net.read_pages("n1", "n0", "float32", frames, key, async_read=True)
    # NOTHING hit the clock — not even the cold-connection setup, which is
    # folded into the transfer's channel time on the async path
    assert net.sim_time == t0
    done = net.channel_busy("n1", "n0")
    assert done > t0 + net.model.dct_setup
    assert net.meter["dct.async_ops"] == 1
    assert net.meter["dct.setups"] == 1     # still metered, just off-clock
    # execution overlaps the transfer; waiting afterwards costs nothing
    net.advance(done - t0 + 1e-6)
    before = net.sim_time
    net.wait_until(done)
    assert net.sim_time == before


def test_async_transfers_serialize_on_their_channel():
    net = Network()
    node = NodeRuntime("n0", net, page_elems=64)
    key = net.create_dc_target("n0")
    f1 = node.pool.alloc("float32", 16)
    f2 = node.pool.alloc("float32", 16)
    net.read_pages("n1", "n0", "float32", f1, key, async_read=True)
    one = net.channel_busy("n1", "n0")
    net.read_pages("n1", "n0", "float32", f2, key, async_read=True)
    two = net.channel_busy("n1", "n0")
    assert two > one                               # queued behind the first
    # a different channel is free
    assert net.channel_busy("n2", "n0") == 0.0


def test_sync_read_queues_behind_async_in_flight():
    net = Network()
    node = NodeRuntime("n0", net, page_elems=64)
    key = net.create_dc_target("n0")
    f1 = node.pool.alloc("float32", 64)
    f2 = node.pool.alloc("float32", 1)
    net.read_pages("n1", "n0", "float32", f1, key, async_read=True)
    busy = net.channel_busy("n1", "n0")
    net.read_pages("n1", "n0", "float32", f2, key)
    assert net.sim_time > busy                     # waited for the channel


def test_reset_meter_clears_channels():
    net = Network()
    node = NodeRuntime("n0", net, page_elems=64)
    key = net.create_dc_target("n0")
    net.read_pages("n1", "n0", "float32", node.pool.alloc("float32", 4), key,
                   async_read=True)
    assert net.channel_busy("n1", "n0") > 0
    net.reset_meter()
    assert net.channel_busy("n1", "n0") == 0.0 and net.sim_time == 0.0


# ---------------------------------------------------------------------------
# the async PrefetchEngine
# ---------------------------------------------------------------------------


def _sweep_sim_time(policy, compute=2e-6, npages=128):
    net, nodes = _cluster()
    params = {"w": jnp.arange(npages * PAGE_ELEMS, dtype=jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(nodes[1], policy)
    net.reset_meter()
    for p in range(npages):
        child.touch_pages("w", [p])
        net.advance(compute)
    if child.prefetch_engine is not None:
        child.prefetch_engine.drain_all()
    return net.sim_time, int(net.meter["dct.bytes"]), child


def test_async_prefetch_strictly_beats_sync_at_equal_bytes():
    sync_t, sync_b, _ = _sweep_sim_time(ForkPolicy(prefetch=8))
    async_t, async_b, child = _sweep_sim_time(ForkPolicy(async_prefetch=8))
    assert async_b == sync_b                       # identical bytes moved
    assert async_t < sync_t                        # overlap pays
    assert child.stats["prefetch_used"] > 0
    assert child.stats["faults"] < 128 // 8        # window kept ahead


def test_async_child_tensors_identical():
    params = _params(rng_seed=3)
    ref = _reference_child(params)
    net, nodes = _cluster()
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(
        nodes[1], ForkPolicy(async_prefetch=6))
    got = child.materialize_pytree()
    for k in params:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(ref[k]))
    assert child.stats["prefetch_used"] > 0


def test_eager_resume_pipelines_through_engine():
    params = _params(rng_seed=4)
    net, nodes = _cluster()
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(
        nodes[1], ForkPolicy(lazy=False, async_prefetch=8))
    assert child.resident_fraction() == 1.0
    assert child.stats["prefetch_issued"] > 0
    got = child.materialize_pytree()
    for k in params:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(parent.ensure_tensor(k)))


def test_cow_write_wins_over_inflight_prefetch():
    """A page COW-written while its prefetch is in flight keeps the local
    write; the stale prefetched payload is dropped as wasted."""
    net, nodes = _cluster()
    params = {"w": jnp.zeros(16 * PAGE_ELEMS, jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(
        nodes[1], ForkPolicy(async_prefetch=8))
    child.touch_pages("w", [0])                   # issues lookahead 1..8
    assert child.prefetch_engine.pending_count() > 0
    ones = np.ones((1, PAGE_ELEMS), np.float32)
    child.write_pages("w", [3], ones)             # COW while in flight
    # touching the COW-won page must NOT block on its stale transfer
    t0 = net.sim_time
    child.touch_pages("w", [3])
    assert net.meter["async_wait_s"] == 0 and net.sim_time == t0
    child.prefetch_engine.drain_all()
    got = np.asarray(child.ensure_tensor("w")).reshape(16, PAGE_ELEMS)
    np.testing.assert_array_equal(got[3], ones[0])
    assert child.stats["prefetch_wasted"] >= 1


def test_window_bounds_inflight_depth():
    """async_prefetch=N bounds TOTAL pages in flight — across touches and
    across VMAs — not a per-touch or per-tensor issue quota."""
    net, nodes = _cluster()
    params = {"w": jnp.zeros(32 * PAGE_ELEMS, jnp.float32),
              "b": jnp.zeros(32 * PAGE_ELEMS, jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(
        nodes[1], ForkPolicy(async_prefetch=2))
    peak = 0
    for p in range(24):
        for name in ("w", "b"):                  # alternate between VMAs
            child.touch_pages(name, [p])
            peak = max(peak, child.prefetch_engine.pending_count())
        net.advance(2e-6)
    assert 0 < peak <= 2


def test_async_prefetched_pages_feed_sibling_cache():
    """Pages landed by the engine must be published to the sibling page
    cache exactly like sync fetches — a second child resumes on hits."""
    net, nodes = _cluster(cache=True)
    params = {"w": jnp.arange(32 * PAGE_ELEMS, dtype=jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    handle = nodes[0].prepare_fork(parent)
    c1 = handle.resume_on(nodes[1], ForkPolicy(async_prefetch=8))
    c1.ensure_all()
    assert c1.stats["prefetch_used"] > 0
    c2 = handle.resume_on(nodes[1])
    c2.ensure_all()
    assert c2.stats["pages_cached"] == 32          # every page from the cache
    np.testing.assert_array_equal(np.asarray(c2.ensure_tensor("w")),
                                  np.asarray(params["w"]))


def test_drain_after_reclaim_does_not_republish_cache():
    """A reclaim between issue and drain destroys the VMA's DC targets and
    broadcasts a cache drop; landing the in-flight payload afterwards must
    NOT re-insert (owner, frame) cache entries — a reused owner frame would
    serve another seed's bytes."""
    net, nodes = _cluster(cache=True)
    params = {"w": jnp.arange(32 * PAGE_ELEMS, dtype=jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], ForkPolicy(async_prefetch=8))
    child.touch_pages("w", [0])                   # window goes in flight
    assert child.prefetch_engine.pending_count() > 0
    cached_before = len(nodes[1]._page_cache)
    handle.reclaim()                              # DC keys die in flight
    child.prefetch_engine.drain_all()             # payload lands (data ok)
    assert len(nodes[1]._page_cache) == cached_before
    got = child.materialize_pytree()              # rest via RPC fallback
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(params["w"]))


def test_eager_window_bound_holds_across_tensors():
    """lazy=False + materialize pipelining must respect the total window:
    issue_window never puts a whole VMA in flight."""
    net, nodes = _cluster()
    params = {"w": jnp.zeros(24 * PAGE_ELEMS, jnp.float32),
              "b": jnp.zeros(24 * PAGE_ELEMS, jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(
        nodes[1], ForkPolicy(async_prefetch=3))
    peak = [0]
    eng = child.prefetch_engine
    orig = eng.issue

    def spying_issue(name, pages):
        n = orig(name, pages)
        peak[0] = max(peak[0], eng.pending_count())
        return n

    eng.issue = spying_issue
    child.ensure_all()
    assert 0 < peak[0] <= 3
    assert child.resident_fraction() == 1.0


def test_read_blob_does_not_meter_sges():
    net = Network()
    NodeRuntime("n0", net, page_elems=64)
    key = net.create_dc_target("n0")
    net.read_blob("n1", "n0", 4096, key)
    assert net.meter["dct.ops"] == 1
    assert net.meter["dct.sges"] == 0              # SGEs are page-read-only


def test_drain_all_never_waits_on_fully_stale_entry():
    """If every page of an in-flight transfer was COW-won, drain_all drops
    the payload without blocking the sim clock."""
    net, nodes = _cluster()
    params = {"w": jnp.zeros(16 * PAGE_ELEMS, jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(
        nodes[1], ForkPolicy(async_prefetch=4))
    child.touch_pages("w", [0])                   # pages 1..4 in flight
    pending = np.concatenate(
        [e.pages for e in child.prefetch_engine._pending["w"]])
    child.write_pages("w", pending,
                      np.ones((pending.size, PAGE_ELEMS), np.float32))
    t0, w0 = net.sim_time, net.meter["async_wait_s"]
    child.prefetch_engine.drain_all()
    assert net.sim_time == t0 and net.meter["async_wait_s"] == w0
    assert child.stats["prefetch_wasted"] == pending.size


def test_free_discards_inflight_prefetch():
    net, nodes = _cluster()
    params = {"w": jnp.zeros(32 * PAGE_ELEMS, jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(
        nodes[1], ForkPolicy(async_prefetch=8))
    child.touch_pages("w", [0])
    assert child.prefetch_engine.pending_count() > 0
    child.free()
    assert child.prefetch_engine is None


# ---------------------------------------------------------------------------
# batched fallback daemon + ensure_tensor reassembly gating
# ---------------------------------------------------------------------------


def test_fallback_serve_mixes_swapped_and_live_in_one_gather():
    net, nodes = _cluster()
    params = {"w": jnp.arange(8 * PAGE_ELEMS, dtype=jnp.float32),
              "b": jnp.arange(2 * PAGE_ELEMS, dtype=jnp.float32)}
    parent = ModelInstance.create(nodes[0], "t", params)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1])
    child.touch_pages("w", [0])                    # one page via RDMA
    nodes[0].swap_out_vma(parent, "w")             # rest must fall back
    got = child.materialize_pytree()
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(params["w"]))
    assert child.stats["pages_rpc"] == 7


def test_ensure_tensor_skips_reassembly_without_residency_change():
    net, nodes = _cluster()
    params = _params(rng_seed=5)
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(nodes[1])
    w = child.ensure_tensor("w")
    reads = []
    orig = nodes[1].pool.read_pages
    nodes[1].pool.read_pages = lambda *a, **k: (reads.append(a), orig(*a, **k))[1]
    try:
        # a fault on a DISJOINT VMA must not force w's reassembly
        child.ensure_tensor("b")
        assert child.ensure_tensor("w") is w
        gathers_for_w = [a for a in reads
                         if len(a[1]) == child.aspace["w"].npages]
        assert not gathers_for_w
        # an actual residency change does reassemble
        child.write_pages("w", [0], np.zeros((1, PAGE_ELEMS), np.float32))
        assert child.ensure_tensor("w") is not w
    finally:
        nodes[1].pool.read_pages = orig


def test_version_bumps_on_residency_and_dirty():
    v = VMA.new_local("w", (PAGE_ELEMS * 4,), "float32",
                      np.arange(4, dtype=np.int32))
    c = v.child_view(1)
    v0 = c.version
    c.mark_resident([0, 1], [7, 8])
    assert c.version > v0
    v1 = c.version
    c.mark_dirty([0])
    assert c.version > v1


# ---------------------------------------------------------------------------
# incremental reassembly (page_version) + the fused/device data plane
# ---------------------------------------------------------------------------


def test_incremental_reassembly_gathers_only_changed_pages():
    """After a cached assembly, a 1-page COW write must patch exactly that
    page into the cached tensor — not re-gather the whole VMA."""
    net, nodes = _cluster()
    params = _params(rng_seed=6)
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(nodes[1])
    w0 = np.asarray(child.ensure_tensor("w")).copy()
    assert child.stats["assemble_full"] >= 1
    reads = []
    orig = nodes[1].pool.read_pages
    nodes[1].pool.read_pages = \
        lambda *a, **k: (reads.append(len(np.atleast_1d(a[1]))),
                         orig(*a, **k))[1]
    try:
        child.write_pages("w", [2], np.full((1, PAGE_ELEMS), 9.0, np.float32))
        got = np.asarray(child.ensure_tensor("w"))
        assert reads == [1], reads          # one single-page gather
        assert child.stats["assemble_patch_pages"] == 1
    finally:
        nodes[1].pool.read_pages = orig
    want = w0.copy().reshape(-1)
    want[2 * PAGE_ELEMS:3 * PAGE_ELEMS] = 9.0
    np.testing.assert_array_equal(got, want.reshape(w0.shape))


def test_incremental_reassembly_random_write_sequences():
    """Randomized ensure/COW-write interleavings stay byte-identical to a
    plain numpy model of the tensor."""
    rng = np.random.default_rng(12)
    net, nodes = _cluster()
    params = _params(rng_seed=7)
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(nodes[1])
    vma = child.aspace["w"]
    model = np.zeros(vma.npages * PAGE_ELEMS, np.float32)
    model[:int(np.prod(vma.shape))] = np.asarray(params["w"]).reshape(-1)
    for _ in range(8):
        k = int(rng.integers(1, 4))
        pages = rng.choice(vma.npages, size=k, replace=False)
        data = rng.standard_normal((k, PAGE_ELEMS)).astype(np.float32)
        child.write_pages("w", pages, data)
        model.reshape(vma.npages, PAGE_ELEMS)[pages] = data
        got = np.asarray(child.ensure_tensor("w")).reshape(-1)
        np.testing.assert_array_equal(
            got, model[:int(np.prod(vma.shape))])
    # the sequence must have exercised the patch path, not full rebuilds
    assert child.stats["assemble_patch_pages"] >= 8


def test_page_version_stamps():
    v = VMA.new_local("w", (PAGE_ELEMS * 4,), "float32",
                      np.arange(4, dtype=np.int32))
    c = v.child_view(1)
    assert c.changed_since(c.version).size == 0
    v0 = c.version
    c.mark_resident([1, 3], [7, 8])
    assert c.changed_since(v0).tolist() == [1, 3]
    v1 = c.version
    c.mark_dirty([3])
    assert c.changed_since(v1).tolist() == [3]
    assert c.changed_since(v0).tolist() == [1, 3]


def _device_cluster(cache=False, n=2):
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, page_elems=PAGE_ELEMS,
                         cache_enabled=cache, device_pool=True)
             for i in range(n)]
    return net, nodes


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
def test_device_pool_fork_parity_and_kernel_meters():
    """A cluster whose pools hold frames on device (data plane routed
    through the page_gather/cow_scatter kernels) forks byte-identically to
    the host-pool reference, and the chosen kernel impl surfaces in the
    network meter."""
    params = _params(rng_seed=9)
    ref = _reference_child(params)
    net, nodes = _device_cluster()
    parent = ModelInstance.create(nodes[0], "t", params)
    child = nodes[0].prepare_fork(parent).resume_on(nodes[1])
    for name in child.leaf_names:
        child.touch_pages(name, np.arange(child.aspace[name].npages))
    got = child.materialize_pytree()
    for k in params:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(ref[k]),
                                      err_msg=k)
    kernel_keys = [k for k in net.meter if k.startswith("kernel.")]
    assert any(k.startswith("kernel.page_gather.") for k in kernel_keys), \
        dict(net.meter)
    assert any(k.startswith("kernel.cow_scatter.") for k in kernel_keys), \
        dict(net.meter)


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
def test_fusion_never_changes_wire_traffic():
    """The fused data plane (device pools + kernels) must move EXACTLY the
    bytes/ops/sges of the host path at equal touches: fusion changes how
    fast pages are assembled, never what is transferred."""
    params = _params(rng_seed=10)
    meters = {}
    for flavor, mk in (("host", _cluster), ("device", _device_cluster)):
        net, nodes = mk()
        parent = ModelInstance.create(nodes[0], "t", params)
        child = nodes[0].prepare_fork(parent).resume_on(nodes[1])
        rng = np.random.default_rng(3)
        for name in child.leaf_names:
            npages = child.aspace[name].npages
            child.touch_pages(name, rng.choice(npages, npages // 2 + 1,
                                               replace=False))
        child.write_pages("w", [0, 1],
                          np.zeros((2, PAGE_ELEMS), np.float32))
        child.materialize_pytree()
        meters[flavor] = net.meter
    for key in ("dct.bytes", "dct.ops", "dct.sges", "page_pages_moved"):
        assert meters["host"][key] == meters["device"][key], (
            key, meters["host"][key], meters["device"][key])


def test_pool_out_param_and_counters():
    pool = PagePool(page_elems=64, initial_frames=16)
    from collections import Counter
    pool.meter = Counter()
    pool._ensure_capacity("float32", 16)
    data = np.arange(16 * 64, dtype=np.float32).reshape(16, 64)
    pool.write_pages("float32", np.arange(16), data)
    out = np.empty((8, 64), np.float32)
    got = pool.read_pages_host("float32", np.arange(4, 12), out=out)
    assert got is out
    np.testing.assert_array_equal(out, data[4:12])
    assert pool.meter["pool.gather_pages"] == 8
    # contiguous 8-page gather runs as ONE slice copy
    assert pool.meter["pool.gather_runs"] == 1
