"""The §Perf shard_map MoE must be numerically equivalent to the GSPMD
path (same routing, same outputs) — verified on a real 8-device mesh in a
subprocess."""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_arch, reduce_for_smoke
from repro.distributed import ctx
from repro.distributed.sharding import make_axis_env
from repro.launch.mesh import make_test_mesh
from repro.models import lm, moe

cfg = reduce_for_smoke(get_arch("moonshot-v1-16b-a3b"))
# experts must divide the model axis for the shardmap path
import dataclasses
cfg = dataclasses.replace(cfg, moe_experts=8, moe_topk=2,
                          moe_capacity_factor=8.0)
key = jax.random.PRNGKey(0)
params = moe.init_moe(key, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

mesh = make_test_mesh(data=2, model=4)
ref = moe._moe_mlp_gspmd(params, x, cfg)

env = make_axis_env(mesh, moe_impl="shardmap")
with ctx.use_env(env):
    got = jax.jit(lambda p, xx: moe.moe_mlp_shardmap(p, xx, cfg, env))(params, x)

err = float(jnp.max(jnp.abs(ref - got)))
print(json.dumps({"err": err}))
"""


def test_shardmap_matches_gspmd():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SUBPROC],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-4, res
