"""Property matrix for the fused fault-path kernels.

Two layers over the same invariant — every backend of ``page_gather`` /
``cow_scatter`` (per-page, run-table, fused assemble/patch variants) is
bit-identical to the ``ref.py`` oracle across dtypes, extent-run shapes,
non-contiguous frame tables, and the empty-run / single-page edges:

* hypothesis properties (skipped when hypothesis is not installed);
* deterministic seeded mirrors of the same sweeps that always run, so the
  matrix never silently vanishes on a box without hypothesis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cow_scatter.ops import cow_scatter, cow_scatter_runs, \
    scatter_patch
from repro.kernels.page_gather.ops import gather_assemble, page_gather, \
    page_gather_runs
from repro.kernels.page_gather.ref import expand_runs

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:          # tier-1 must not require hypothesis
    HAVE_HYP = False

needs_hyp = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis not installed")

BACKENDS = ("auto", "kernel", "interpret", "jnp", "ref")
DTYPES = ("float32", "bfloat16", "int32")
E = 128                      # lane-aligned page size for the kernel paths
F = 48


def _frames(dt: str, seed: int):
    key = jax.random.PRNGKey(seed)
    if dt == "int32":
        return jax.random.randint(key, (F, E), -1000, 1000)
    return jax.random.normal(key, (F, E), jnp.dtype(dt))


def _runs_to_tables(runs):
    """[(start, len)] -> (starts, lens, expanded ids); zero lens allowed."""
    starts = np.array([s for s, _ in runs], np.int64)
    lens = np.array([l for _, l in runs], np.int64)
    keep = lens > 0
    ids = expand_runs(starts[keep], lens[keep]) if keep.any() \
        else np.zeros(0, np.int32)
    return starts, lens, ids


def _check_gather(dt, runs):
    frames = _frames(dt, 11)
    starts, lens, ids = _runs_to_tables(runs)
    want = np.asarray(frames)[ids]
    for backend in BACKENDS:
        got = page_gather_runs(frames, starts, lens, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"{dt}/{backend}/{runs}")
        got = page_gather(frames, ids, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"{dt}/{backend}/per-page")


def _check_scatter(dt, runs):
    starts, lens, ids = _runs_to_tables(runs)
    uniq = np.unique(ids)
    if uniq.size != ids.size:       # scatter requires non-overlapping runs
        return
    pages = _frames(dt, 13)[:ids.size] if ids.size <= F else None
    if pages is None:
        return
    want = None
    for backend in BACKENDS:
        frames = _frames(dt, 17)
        got = np.asarray(cow_scatter_runs(frames, starts, lens, pages,
                                          backend=backend))
        if want is None:
            want = np.asarray(frames).copy()
            want[ids] = np.asarray(pages)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{dt}/{backend}/{runs}")


# -- run-shape generators ----------------------------------------------------

def _random_runs(rng, max_runs=6, max_len=5, frame_cap=F):
    """Non-overlapping, non-adjacent runs in random order (non-contiguous
    frame table): gaps >= 1 keep each (start, len) a maximal extent."""
    k = int(rng.integers(0, max_runs + 1))
    runs, cursor = [], 0
    for _ in range(k):
        gap = int(rng.integers(1, 4))
        length = int(rng.integers(0, max_len + 1))    # zero-length included
        start = cursor + gap
        if start + max(length, 1) > frame_cap:
            break
        runs.append((start, length))
        cursor = start + max(length, 1)
    rng.shuffle(runs)
    return runs


# -- deterministic mirrors (always run) --------------------------------------

@pytest.mark.parametrize("dt", DTYPES)
def test_gather_matrix_seeded(dt):
    rng = np.random.default_rng(42)
    cases = [[], [(0, 1)], [(F - 1, 1)], [(3, 0)], [(5, 3), (20, 1), (9, 4)]]
    cases += [_random_runs(rng) for _ in range(10)]
    for runs in cases:
        _check_gather(dt, runs)


@pytest.mark.parametrize("dt", DTYPES)
def test_scatter_matrix_seeded(dt):
    rng = np.random.default_rng(43)
    cases = [[], [(0, 1)], [(F - 1, 1)], [(2, 4), (12, 1), (30, 2)]]
    cases += [_random_runs(rng) for _ in range(10)]
    for runs in cases:
        _check_scatter(dt, runs)


@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_assemble_patch_roundtrip_seeded(dt):
    """gather_assemble then scatter_patch of any page subset equals
    reassembling from the patched frames — the incremental-reassembly
    contract ensure_tensor relies on."""
    rng = np.random.default_rng(44)
    for shape in [(E,), (E * 3 - 7,), (5, 77), (1,)]:
        size = int(np.prod(shape))
        n = -(-size // E)
        frames = _frames(dt, 19)
        ids = rng.choice(F, n, replace=False).astype(np.int32)
        t = gather_assemble(frames, ids, shape, backend="ref")
        changed = rng.choice(n, max(1, n // 2), replace=False) \
            .astype(np.int32)
        rows = _frames(dt, 23)[:changed.size]
        # patch the cached tensor vs rebuild from patched frames
        upd = np.asarray(frames).copy()
        upd[ids[changed]] = np.asarray(rows)
        want = gather_assemble(jnp.asarray(upd), ids, shape, backend="ref")
        for backend in BACKENDS:
            got = scatter_patch(t, changed, rows, page_elems=E,
                                backend=backend)
            np.testing.assert_array_equal(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                err_msg=f"{dt}/{backend}/{shape}")


# -- hypothesis properties (skipped without hypothesis) ----------------------

if HAVE_HYP:
    SETTINGS = dict(max_examples=25, deadline=None)

    @st.composite
    def extent_runs(draw):
        """Random non-overlapping run tables, shuffled (non-contiguous)."""
        k = draw(st.integers(0, 6))
        runs, cursor = [], 0
        for _ in range(k):
            gap = draw(st.integers(1, 3))
            length = draw(st.integers(0, 5))
            start = cursor + gap
            if start + max(length, 1) > F:
                break
            runs.append((start, length))
            cursor = start + max(length, 1)
        if len(runs) > 1 and draw(st.booleans()):
            runs = runs[::-1]
        return runs

    @needs_hyp
    @settings(**SETTINGS)
    @given(dt=st.sampled_from(DTYPES), runs=extent_runs())
    def test_gather_property(dt, runs):
        _check_gather(dt, runs)

    @needs_hyp
    @settings(**SETTINGS)
    @given(dt=st.sampled_from(DTYPES), runs=extent_runs())
    def test_scatter_property(dt, runs):
        _check_scatter(dt, runs)
