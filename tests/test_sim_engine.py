"""repro.sim: event loop, traces, and end-to-end replay determinism."""
import numpy as np
import pytest

from repro.sim import (ColdStart, EventLoop, ForkOnDemand, KeepWarm,
                       ReplayEngine, SimClock, SimFunction, Trace,
                       correlated_spikes, load_azure_csv, multi_function,
                       spike_660323)
from repro.net import Network

SEED = 7


def spike(scale=1):
    return spike_660323(scale=scale, func="f")


def small_fn(**kw):
    kw.setdefault("state_bytes", 16 * 1024)
    kw.setdefault("touch_frac", 0.25)
    kw.setdefault("hold_s", 60.0)
    return SimFunction("f", **kw)


def replay(policy, trace, seed=SEED, n_nodes=8, fn=None, **kw):
    eng = ReplayEngine(trace, policy, [fn or small_fn()], n_nodes=n_nodes,
                       seed=seed, page_elems=1024, **kw)
    return eng, eng.run()


# -- event loop --------------------------------------------------------------

def test_event_loop_orders_by_time_then_schedule():
    loop = EventLoop(seed=0)
    seen = []
    loop.at(2.0, seen.append, "late")
    loop.at(1.0, seen.append, "a")
    loop.at(1.0, seen.append, "b")       # same time: schedule order wins
    loop.run()
    assert seen == ["a", "b", "late"]
    assert loop.events_run == 3


def test_event_loop_rejects_negative_time_and_bad_interval():
    loop = EventLoop(seed=0)
    with pytest.raises(ValueError):
        loop.at(-1.0, lambda: None)
    with pytest.raises(ValueError):
        loop.every(0.0, lambda: None, until=10.0)


def test_every_is_bounded_by_until():
    loop = EventLoop(seed=0)
    ticks = []
    loop.every(10.0, lambda: ticks.append(loop.now), until=35.0)
    loop.run()
    assert ticks == [10.0, 20.0, 30.0]
    assert loop.pending() == 0           # housekeeping cannot run forever


def test_loop_synchronizes_network_clock():
    net = Network()
    loop = EventLoop(net, seed=0)
    times = []
    loop.at(5.0, lambda: times.append(net.sim_time))
    loop.at(3.0, lambda: times.append(net.sim_time))
    loop.run()
    assert times == [3.0, 5.0]
    assert SimClock(net)() == net.sim_time


# -- traces ------------------------------------------------------------------

def test_spike_trace_shape_and_scaling():
    tr = spike_660323()
    assert tr.total_invocations() == 201
    assert tr.peak_per_minute() == 120
    assert tr.minutes == 12 and tr.duration_s == 720.0
    assert spike_660323(scale=3).total_invocations() == 603


def test_arrivals_are_deterministic_sorted_and_jittered():
    import random
    tr = multi_function([spike_660323(func="a"), spike_660323(func="b")])
    a1 = tr.arrivals(random.Random(5))
    a2 = tr.arrivals(random.Random(5))
    assert a1 == a2
    assert a1 != tr.arrivals(random.Random(6))
    ts = [inv.t for inv in a1]
    assert ts == sorted(ts)
    assert [inv.idx for inv in a1] == list(range(len(a1)))
    # jitter stays inside each arrival's minute
    for inv in a1:
        assert 0.0 <= inv.t <= tr.duration_s


def test_multi_function_rejects_duplicates():
    with pytest.raises(ValueError, match="duplicate"):
        multi_function([spike_660323(func="a"), spike_660323(func="a")])


def test_correlated_spikes_stagger():
    tr = correlated_spikes(n_functions=3, stagger_minutes=2)
    assert tr.functions == ["fn000", "fn001", "fn002"]
    peaks = {f: tr.per_minute[f].index(120) for f in tr.functions}
    assert peaks["fn001"] - peaks["fn000"] == 2
    assert peaks["fn002"] - peaks["fn001"] == 2


def test_load_azure_csv(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("HashFunction,1,2,3\n"
                 "aaaaaaaabbbbbbbb,1,40,2\n"
                 "ccccccccdddddddd,0,1,0\n")
    tr = load_azure_csv(str(p))
    assert tr.functions == ["aaaaaaaa", "cccccccc"]
    assert tr.per_minute["aaaaaaaa"] == (1, 40, 2)
    assert load_azure_csv(str(p), top=1).functions == ["aaaaaaaa"]
    assert load_azure_csv(str(p), minutes=2).minutes == 2
    with pytest.raises(ValueError, match="not found"):
        load_azure_csv(str(p), functions=["nope"])


# -- determinism -------------------------------------------------------------

def test_replay_is_deterministic_under_fixed_seed():
    tr = spike(scale=2)
    _, r1 = replay(ForkOnDemand(replicas=2, prefetch=0), tr)
    _, r2 = replay(ForkOnDemand(replicas=2, prefetch=0), tr)
    assert r1.event_log_digest == r2.event_log_digest
    assert r1.summary() == r2.summary()
    assert r1.digest() == r2.digest()


def test_replay_digest_changes_with_seed():
    tr = spike(scale=2)
    _, r1 = replay(ForkOnDemand(prefetch=0), tr, seed=1)
    _, r2 = replay(ForkOnDemand(prefetch=0), tr, seed=2)
    assert r1.event_log_digest != r2.event_log_digest


def test_fork_path_moves_real_pages():
    """No analytical shortcut: the fork rows' latency comes from actual
    wire traffic charged by the data plane."""
    eng, res = replay(ForkOnDemand(prefetch=0), spike())
    assert res.decisions.get("fork", 0) == res.invocations
    assert res.payload_pages["pages_rdma"] >= res.invocations
    assert eng.net.meter["dct.bytes"] > 0
    # end-to-end latency >= startup latency >= 0 for every invocation
    assert res.latency["all"]["p99_us"] >= res.startup["all"]["p99_us"] >= 0


# -- leases, GC, memory ------------------------------------------------------

def idle_gap_trace(gap_minutes=11):
    return Trace("gap", {"f": (2,) + (0,) * gap_minutes + (3,)})


def test_seed_lease_expires_end_to_end():
    """An idle function stops renewing; its seed ages out via the replay's
    GC events on the sim clock, and the next arrival cold-boots (and
    re-seeds) — all surfaced in telemetry."""
    policy = ForkOnDemand(replicas=1, lease=120.0, renew_every=60.0)
    eng, res = replay(policy, idle_gap_trace())
    assert res.decisions["fork"] >= 2        # minute-0 traffic forks
    assert res.decisions["cold"] >= 1        # post-gap arrival found no seed
    gc_sweeps = res.telemetry.of_kind("gc")
    assert sum(r["seeds"] for r in gc_sweeps) >= 1
    assert res.lease["f"]["expiries"] >= 1
    # after the cold-boot fallback the seed is live again
    assert "f" in eng.coord.seed_store


def test_expired_seed_found_at_acquire_is_refreshed():
    """With GC off and renewals rarer than the lease, the post-gap arrival
    itself discovers the expired seed: acquire falls back to a cold boot
    that re-seeds, and the policy telemeters the refresh."""
    policy = ForkOnDemand(replicas=1, lease=30.0, renew_every=1e6)
    eng, res = replay(policy, Trace("gap", {"f": (2, 0, 3)}), gc_every=1e6)
    assert res.decisions["cold"] >= 1
    assert res.telemetry.of_kind("seed_refresh")
    assert "f" in eng.coord.seed_store


def test_gc_is_idempotent_mid_replay():
    eng, res = replay(KeepWarm(ttl=30.0), spike())
    eng.net.sim_time = res.end_time + 1000.0
    first = eng.coord.gc()
    second = eng.coord.gc()
    assert second["seeds"] == 0 and second["cached"] == 0
    assert second["dangling"] == 0
    assert first["seeds"] >= 0               # first sweep may reclaim


def test_gc_telemetry_reaches_engine():
    _, res = replay(KeepWarm(ttl=60.0), spike())
    s = res.summary()
    assert s["gc"]["sweeps"] > 0
    assert s["gc"]["cached_expired"] > 0     # idle tail of the spike expired


def test_keepwarm_memory_dwarfs_fork_memory():
    tr = spike()
    _, fork = replay(ForkOnDemand(replicas=2, prefetch=0), tr)
    _, warm = replay(KeepWarm(ttl=60.0, prewarm=2), tr)
    assert warm.memory.peak_total() > 2 * fork.memory.peak_total()
    assert warm.memory.peak_node() > fork.memory.peak_node()
    # timelines are sampled in sim time, not wall time
    assert all(0.0 <= t <= warm.end_time + 1000.0
               for t, *_ in warm.memory.samples)


def test_coldstart_control_never_forks():
    _, res = replay(ColdStart(), spike())
    assert res.decisions == {"cold": 201}
    assert res.payload_pages.get("pages_rdma", 0) == 0
    assert res.latency["all"]["p50_us"] >= 167000


def test_unknown_trace_function_rejected():
    with pytest.raises(ValueError, match="unknown function"):
        ReplayEngine(Trace("t", {"ghost": (1,)}), ColdStart(), [small_fn()],
                     n_nodes=2)
