"""The connection control plane (repro.net.conn): bounded per-node QP/DC
pools, LRU eviction + re-establishment churn, sibling sharing via
instance refcounts, the RC-vs-DCT slot-footprint difference, rpc routed
through the pool, setup-aware placement from OBSERVED pool state, and
the replay engine's per-backend conn telemetry.

The load-bearing invariant (also a hypothesis property below): the pool
cap changes WHEN pairs pay establishment, never WHAT moves — total bytes
and ops are invariant under ``NetModel.conn_cap``; only setups and sim
time grow as the cap shrinks.
"""
import pytest

from repro.core.instance import ModelInstance
from repro.fork import ForkPolicy
from repro.net import NetModel, Network
from repro.placement import TransportAwareScheduler
from repro.platform.node import NodeRuntime
from repro.sim import (ForkOnDemand, ReplayEngine, SimFunction,
                       build_cluster, spike_660323)


def _net(cap=0, transport="rc"):
    net = Network(model=NetModel(conn_cap=cap), transport=transport)
    owner = NodeRuntime("owner", net, page_elems=64)
    key = net.create_dc_target("owner")
    return net, owner, key


def _read(net, owner, key, src, transport="rc", user=None, **kw):
    frames = owner.pool.alloc("float32", 4)
    net.read_pages(src, "owner", "float32", frames, key,
                   transport=transport, user=user, **kw)


# -- bounded pools: LRU eviction and re-establishment -------------------------


def test_rc_cap_bounds_pool_and_evicts_lru():
    net, owner, key = _net(cap=2)
    for c in ("c0", "c1", "c2"):
        _read(net, owner, key, c)
    # the owner's table holds 2 slots; c0 was least recently used
    assert not net.has_connection("rc", "c0", "owner")
    assert net.has_connection("rc", "c1", "owner")
    assert net.has_connection("rc", "c2", "owner")
    assert len(net.conns.pool("owner")) == 2
    assert net.meter["rc.conn_evicted"] == 1
    assert net.conns.live("rc") == 2


def test_reestablishment_pays_setup_again_and_meters_churn():
    net, owner, key = _net(cap=1)
    _read(net, owner, key, "c0")
    t0 = net.sim_time
    _read(net, owner, key, "c0")                # warm slot: no setup
    warm_cost = net.sim_time - t0
    _read(net, owner, key, "c1")                # evicts (c0, owner)
    t1 = net.sim_time
    _read(net, owner, key, "c0")                # cold again: full QP connect
    cold_cost = net.sim_time - t1
    assert cold_cost - warm_cost == pytest.approx(net.model.rc_setup)
    assert net.meter["rc.conn_reestablished"] == 1
    assert net.meter["rc.conn_evicted"] == 2
    assert net.meter["rc.setups"] == 3


def test_unbounded_cap_never_evicts():
    net, owner, key = _net(cap=0)
    for i in range(32):
        _read(net, owner, key, f"c{i}")
    assert net.meter["rc.conn_evicted"] == 0
    assert net.conns.live("rc") == 32
    assert len(net.conns.pool("owner")) == 32


def test_meter_reset_keeps_pools_warm():
    net, owner, key = _net()
    _read(net, owner, key, "c0")
    net.reset_meter()
    assert net.has_connection("rc", "c0", "owner")
    _read(net, owner, key, "c0")
    assert net.meter["rc.setups"] == 0          # still warm after reset


# -- sibling sharing (instance-scoped refcounts) ------------------------------


def test_unreferenced_connections_evicted_before_live_users():
    net, owner, key = _net(cap=2)
    _read(net, owner, key, "c1", user="c1/i0")  # referenced, becomes LRU
    _read(net, owner, key, "c0")                # unreferenced, MRU
    conn = net.conns.conns[("rc", "peer", "c1", "owner")]
    _read(net, owner, key, "c1", user="c1/i1")  # sibling shares the slot
    assert conn.users == {"c1/i0", "c1/i1"}
    assert net.meter["rc.setups"] == 2          # sharing: no third setup
    _read(net, owner, key, "c2")                # overflow at the owner
    # c1's QP is older but referenced: the unreferenced c0 slot goes first
    assert net.has_connection("rc", "c1", "owner")
    assert not net.has_connection("rc", "c0", "owner")
    # releasing both refs keeps the slot warm but first in line
    net.conn_release_user("c1/i0")
    net.conn_release_user("c1/i1")
    assert conn.users == set()
    assert net.has_connection("rc", "c1", "owner")
    _read(net, owner, key, "c3")
    assert not net.has_connection("rc", "c1", "owner")


def test_forced_eviction_when_every_slot_is_referenced():
    # the QP table is a hard hardware bound: under full referenced
    # pressure the LRU slot is torn out from under its user anyway
    net, owner, key = _net(cap=1)
    _read(net, owner, key, "c0", user="u0")
    _read(net, owner, key, "c1", user="u1")
    assert not net.has_connection("rc", "c0", "owner")
    assert net.has_connection("rc", "c1", "owner")
    assert net.meter["rc.conn_evicted"] == 1


def test_fork_children_share_and_release_connection_refs(
        cluster, hello_cfg, hello_params):
    net, nodes = cluster
    parent = ModelInstance.create(nodes[0], hello_cfg.name, hello_params)
    handle = nodes[0].prepare_fork(parent)
    pol = ForkPolicy(lazy=True, page_fetch="rc", descriptor_fetch="rc")
    c1 = handle.resume_on(nodes[1], pol)
    c2 = handle.resume_on(nodes[1], pol)
    c1.touch_pages(c1.leaf_names[0], [0])
    c2.touch_pages(c2.leaf_names[0], [0])
    conn = net.conns.conns[("rc", "peer", "node1", "node0")]
    assert {c1._conn_user, c2._conn_user} <= conn.users
    c1.free()
    assert c1._conn_user not in conn.users
    assert c2._conn_user in conn.users
    assert net.has_connection("rc", "node1", "node0")


# -- RC vs DCT, structurally --------------------------------------------------


def test_dct_slot_footprint_beats_rc_under_cap():
    """Fanning one source out to 3 owners twice: per-peer RC churns a
    2-slot table (3 QPs cannot fit), while DCT holds ONE initiator slot
    at the source regardless of fan-out degree — no churn, and each pair
    pays only its piggybacked handshake once."""
    for tname in ("rc", "dct"):
        net = Network(model=NetModel(conn_cap=2), transport=tname)
        owners = [NodeRuntime(f"o{i}", net, page_elems=64) for i in range(3)]
        keys = [net.create_dc_target(o.node_id) for o in owners]
        for _ in range(2):
            for o, k in zip(owners, keys):
                frames = o.pool.alloc("float32", 4)
                net.read_pages("src", o.node_id, "float32", frames, k,
                               transport=tname)
        if tname == "rc":
            assert net.meter["rc.conn_evicted"] > 0
            assert net.meter["rc.conn_reestablished"] > 0
        else:
            assert net.meter["dct.conn_evicted"] == 0
            assert net.meter["dct.conn_reestablished"] == 0
            assert net.meter["dct.setups"] == 3     # one piggyback per pair
            assert len(net.conns.pool("src")) == 1  # one DC initiator slot


def test_dct_target_eviction_invalidates_initiator_handshakes():
    net, owner, key = _net(transport="dct")
    _read(net, owner, key, "c0", transport="dct")
    assert net.has_connection("dct", "c0", "owner")
    tgt = net.conns.conns[("dct", "tgt", "owner")]
    net.conns.evict(tgt)
    # the initiator context survives but its handshake to the owner died
    assert ("dct", "dci", "c0") in net.conns.conns
    assert not net.has_connection("dct", "c0", "owner")
    _read(net, owner, key, "c0", transport="dct")
    assert net.meter["dct.conn_reestablished"] == 1


# -- every data-plane verb rides the pool -------------------------------------


def test_rpc_pays_and_reuses_connection_setup():
    """``Transport.rpc`` used to skip ``_setup`` entirely — an RPC-only
    workload never paid (or recorded) connection establishment."""
    net, owner, key = _net()
    t0 = net.sim_time
    net.rpc("c0", "owner", 256, lambda: None, transport="rc")
    first = net.sim_time - t0
    t1 = net.sim_time
    net.rpc("c0", "owner", 256, lambda: None, transport="rc")
    second = net.sim_time - t1
    assert first - second == pytest.approx(net.model.rc_setup)
    assert net.has_connection("rc", "c0", "owner")
    assert net.meter["rc.setups"] == 1
    # and the QP is shared with the one-sided verbs: reads are warm too
    _read(net, owner, key, "c0")
    assert net.meter["rc.setups"] == 1


# -- observed state feeds placement -------------------------------------------


def test_scheduler_prefers_observed_warm_path():
    net = Network(transport="rc")
    owner = NodeRuntime("owner", net, page_elems=64)
    workers = {f"w{i}": NodeRuntime(f"w{i}", net, page_elems=64)
               for i in range(4)}
    key = net.create_dc_target("owner")
    frames = owner.pool.alloc("float32", 4)
    net.read_pages("w2", "owner", "float32", frames, key, transport="rc")
    # round-robin fallback would say w0; the warm QP at w2 must win
    sched = TransportAwareScheduler(net)
    pick = sched.pick(workers, demand=[("owner", "rc")])
    assert pick.node_id == "w2"
    assert net.setup_owed("rc", "w2", "owner") == 0.0
    assert net.setup_owed("rc", "w0", "owner") == net.model.rc_setup


def test_async_cold_setup_shows_as_conn_backlog():
    net, owner, key = _net()
    frames = owner.pool.alloc("float32", 4)
    net.read_pages("c0", "owner", "float32", frames, key, transport="rc",
                   async_read=True)
    # async issue leaves the clock untouched; the handshake-in-flight is
    # visible as control-plane backlog at both endpoints instead
    assert net.sim_time == 0.0
    assert net.conn_backlog("c0") >= net.model.rc_setup - 1e-12
    assert net.conn_backlog("owner") >= net.model.rc_setup - 1e-12
    sched = TransportAwareScheduler(net)
    assert sched.score("c0", []) >= net.model.rc_setup


# -- telemetry ----------------------------------------------------------------


def test_per_backend_exports_conn_counters():
    net, owner, key = _net(cap=1)
    for c in ("c0", "c1", "c0"):
        _read(net, owner, key, c)
    pb = net.per_backend()["rc"]
    assert pb["setups"] == 3
    assert pb["conn_evicted"] == 2
    assert pb["conn_reestablished"] == 1
    assert pb["conn_live"] == net.conns.live("rc") == 1


def test_unregister_tears_down_node_connections():
    net, owner, key = _net()
    _read(net, owner, key, "c0")
    assert net.has_connection("rc", "c0", "owner")
    net.unregister("owner")
    assert not net.has_connection("rc", "c0", "owner")
    assert net.conns.live("rc") == 0


def test_replay_surfaces_conn_counters_and_stays_deterministic():
    def run_once():
        net, nodes = build_cluster(8, transport="rc", page_elems=1024,
                                   model=NetModel(conn_cap=2))
        eng = ReplayEngine(
            spike_660323(func="f"), ForkOnDemand(prefetch=0),
            [SimFunction("f", state_bytes=16 * 1024, touch_frac=0.25,
                         hold_s=60.0)],
            seed=7, network=net, nodes=nodes)
        return eng.run()

    r1, r2 = run_once(), run_once()
    conn = r1.summary()["conn"]
    assert "rc" in conn
    assert conn["rc"]["setups"] > 0
    assert conn["rc"]["live"] >= 1
    # 201 invocations over 8 nodes with 2 slots per table must churn
    assert conn["rc"]["evicted"] > 0
    assert conn["rc"]["reestablished"] > 0
    assert r1.digest() == r2.digest()


# -- the invariant: the cap moves time, never bytes ---------------------------


def test_bytes_invariant_under_conn_cap_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None)
    @given(caps=st.lists(st.integers(min_value=1, max_value=6),
                         min_size=2, max_size=3, unique=True),
           seq=st.lists(st.integers(min_value=0, max_value=5),
                        min_size=1, max_size=24))
    def prop(caps, seq):
        stats = []
        for cap in [0] + caps:
            net, owner, key = _net(cap=cap)
            frames = owner.pool.alloc("float32", 4)
            for c in seq:
                net.read_pages(f"c{c}", "owner", "float32", frames, key,
                               transport="rc")
            stats.append((net.meter["rc.bytes"], net.meter["rc.ops"],
                          net.meter["rc.setups"], net.sim_time))
        assert len({s[0] for s in stats}) == 1   # bytes invariant
        assert len({s[1] for s in stats}) == 1   # ops invariant
        # the unbounded pool pays the fewest setups and finishes first
        assert all(s[2] >= stats[0][2] for s in stats[1:])
        assert all(s[3] >= stats[0][3] - 1e-12 for s in stats[1:])

    prop()
