"""Training substrate: optimizer, schedule, data determinism, checkpoint
restart, loss-goes-down end-to-end."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, reduce_for_smoke
from repro.models import lm
from repro.training import checkpoint as ckpt
from repro.training.data import Prefetcher, TokenStream
from repro.training.optimizer import (AdamWConfig, adamw_update, global_norm,
                                      init_opt_state)
from repro.training.schedule import warmup_cosine
from repro.training.train_step import TrainConfig, make_train_step


def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, gn = adamw_update(params, grads, opt, 0.1, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    grads = {"w": jnp.full(3, 1e6)}
    p2, opt, gn = adamw_update(params, grads, opt, 1e-3,
                               AdamWConfig(clip_norm=1.0, weight_decay=0.0))
    assert float(gn) > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 1e-2


def test_schedule_shape():
    assert float(warmup_cosine(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert abs(float(warmup_cosine(10, peak_lr=1.0, warmup=10, total=100)) - 1.0) < 1e-6
    end = float(warmup_cosine(100, peak_lr=1.0, warmup=10, total=100))
    assert end < 0.11


def test_data_deterministic_and_sharded():
    s1 = TokenStream(1000, 8, 64, seed=3)
    s2 = TokenStream(1000, 8, 64, seed=3)
    a, la = s1.batch_at(5)
    b, lb = s2.batch_at(5)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[:, 1:], la[:, :-1])
    h0 = TokenStream(1000, 8, 64, seed=3, num_hosts=2, host_id=0).batch_at(0)[0]
    h1 = TokenStream(1000, 8, 64, seed=3, num_hosts=2, host_id=1).batch_at(0)[0]
    assert h0.shape == (4, 64)
    assert not np.array_equal(h0, h1)


def test_prefetcher_matches_stream():
    s = TokenStream(500, 4, 32, seed=1)
    pf = Prefetcher(s, start_step=0)
    try:
        for i in range(3):
            tok, lab = pf.next()
            want_tok, want_lab = s.batch_at(i)
            np.testing.assert_array_equal(tok, want_tok)
    finally:
        pf.close()


def test_checkpoint_roundtrip_and_keep(tmp_path):
    cfg = reduce_for_smoke(get_arch("stablelm-3b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    for step in (10, 20, 30, 40):
        ckpt.save_checkpoint(str(tmp_path), step, params, opt, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 40
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step-00000030", "step-00000040"]
    step, p2, o2, extra = ckpt.load_checkpoint(str(tmp_path))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert step == 40


def test_restart_continues_identically(tmp_path):
    """Train 4 steps; vs train 2, checkpoint, restore, train 2 more."""
    cfg = reduce_for_smoke(get_arch("stablelm-3b"))
    tcfg = TrainConfig(microbatches=1, q_chunk=32, xent_chunk=32, warmup=0,
                       peak_lr=1e-3)
    step_fn = make_train_step(cfg, tcfg)
    stream = TokenStream(cfg.vocab_size, 4, 32, seed=0)

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            tok, lab = stream.batch_at(s)
            params, opt, m = step_fn(params, opt, jnp.asarray(tok),
                                     jnp.asarray(lab))
        return params, opt, float(m["loss"])

    p0 = lm.init_params(jax.random.PRNGKey(0), cfg)
    o0 = init_opt_state(p0)
    pA, oA, lossA = run(p0, o0, 0, 4)

    p1 = lm.init_params(jax.random.PRNGKey(0), cfg)
    o1 = init_opt_state(p1)
    p1, o1, _ = run(p1, o1, 0, 2)
    ckpt.save_checkpoint(str(tmp_path), 2, p1, o1)
    _, p2, o2, _ = ckpt.load_checkpoint(str(tmp_path))
    p2 = jax.tree.map(jnp.asarray, p2)
    o2 = jax.tree.map(jnp.asarray, o2)
    pB, oB, lossB = run(p2, o2, 2, 4)
    assert abs(lossA - lossB) < 1e-5
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_loss_decreases_end_to_end():
    from repro.launch.train import main as train_main
    losses = train_main(["--arch", "micro-hello", "--steps", "40",
                         "--batch", "4", "--seq", "64", "--log-every", "40",
                         "--warmup", "2", "--lr", "1e-3"])
    assert losses[-1] < losses[0] - 0.05


def test_grad_compression_bf16_trains():
    cfg = reduce_for_smoke(get_arch("stablelm-3b"))
    tcfg = TrainConfig(microbatches=2, grad_dtype="bfloat16", q_chunk=32,
                       xent_chunk=32, warmup=0, peak_lr=1e-3)
    step_fn = make_train_step(cfg, tcfg)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    p2, o2, m = step_fn(params, opt, toks, toks)
    assert not bool(jnp.isnan(m["loss"]))
