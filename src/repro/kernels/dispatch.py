"""Backend resolution + metering for the kernel layer.

Every ``ops.py`` wrapper routes its ``backend=`` argument through
:func:`resolve_backend` so the dispatch rules live in ONE place:

``auto``       the compiled Pallas kernel when the host platform can compile
               it (TPU), else the fused-XLA ``jnp`` fallback — the fastest
               *correct* path everywhere.  The fallback is announced once
               per kernel (`warnings.warn`), never silently.
``kernel``     force the Pallas kernel; off-TPU it runs in interpret mode
               (announced once — interpret is a validation tool, orders of
               magnitude slower than either real path).
``interpret``  force Pallas interpret mode (kernel-vs-ref parity tests).
``jnp``        force the fused-XLA fallback.
``ref``        the pure-jnp oracle (no jit contract, reference semantics).

The *chosen* implementation is counted in a module-level meter
(``kernel.{name}.{impl}``) so callers — the fault handler surfaces these
through ``Network.meter`` — can prove which data plane actually ran: a
deployment that thinks it is running compiled kernels but is interpreting
(or falling back) shows up in the meters, not just in wall time.
"""
from __future__ import annotations

import warnings
from collections import Counter
from typing import Optional, Set, Tuple

import jax

# impl names recorded in the meter / returned by resolve_backend
IMPL_KERNEL = "pallas"         # compiled Pallas (TPU)
IMPL_INTERPRET = "interpret"   # Pallas interpret mode (emulation)
IMPL_JNP = "jnp"               # fused XLA fallback (jit'd jnp)
IMPL_REF = "ref"               # pure-jnp oracle

BACKENDS = ("auto", "kernel", "interpret", "jnp", "ref")

_meter: Counter = Counter()
_warned: Set[Tuple[str, str]] = set()


def kernel_available() -> bool:
    """Can the Pallas TPU kernels actually *compile* here?"""
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str, *, kernel_name: str) -> Tuple[str, bool]:
    """Map a requested ``backend`` to ``(impl, interpret)``.

    ``impl`` is one of ``pallas | interpret | jnp | ref``; ``interpret`` is
    the flag to pass to the Pallas entry point when ``impl`` is a Pallas
    flavor.  Resolution is recorded in the kernel meter.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r} for {kernel_name}; "
            f"expected one of {BACKENDS}")
    if backend == "auto":
        if kernel_available():
            impl = IMPL_KERNEL
        else:
            impl = IMPL_JNP
            _warn_once(kernel_name, "auto",
                       f"{kernel_name}: compiled Pallas kernel unavailable on "
                       f"backend={jax.default_backend()!r}; using the fused "
                       f"XLA (jnp) fallback")
    elif backend == "kernel":
        if kernel_available():
            impl = IMPL_KERNEL
        else:
            impl = IMPL_INTERPRET
            _warn_once(kernel_name, "kernel",
                       f"{kernel_name}: backend='kernel' off-TPU runs the "
                       f"Pallas kernel in INTERPRET mode (validation only, "
                       f"not a performance path)")
    elif backend == "interpret":
        impl = IMPL_INTERPRET
    elif backend == "jnp":
        impl = IMPL_JNP
    else:
        impl = IMPL_REF
    _meter[f"kernel.{kernel_name}.{impl}"] += 1
    return impl, impl == IMPL_INTERPRET


def _warn_once(kernel_name: str, requested: str, msg: str) -> None:
    key = (kernel_name, requested)
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(msg, RuntimeWarning, stacklevel=4)


def record(kernel_name: str, event: str, n: int = 1) -> None:
    """Count a kernel-layer event (e.g. pages moved by an impl)."""
    _meter[f"kernel.{kernel_name}.{event}"] += n


def kernel_meters(prefix: Optional[str] = None) -> dict:
    """Snapshot of the kernel meter, optionally filtered by prefix."""
    if prefix is None:
        return dict(_meter)
    return {k: v for k, v in _meter.items() if k.startswith(prefix)}


def drain_meters_into(meter) -> None:
    """Fold (and clear) the kernel meter into a Counter-like ``meter`` —
    how the fault handler surfaces backend choices in ``Network.meter``."""
    for k, v in _meter.items():
        meter[k] += v
    _meter.clear()


def reset_meters() -> None:
    _meter.clear()
