"""Paged decode attention (GQA) Pallas TPU kernel.

The serving hot loop: one query token per sequence attends over a KV cache
stored in pool pages (the COW-shared pages that remote fork gives children).
Flash-style online softmax across the page grid dimension; the per-sequence
page table is scalar-prefetched so BlockSpec index_maps route each grid step
to its pool frame — the same PTE-walk structure as page_gather.

Grid: (B, K, P) — batch x kv-head x page.  VMEM scratch carries the running
max / sum / accumulator across the page dimension (TPU grids execute
sequentially over the trailing axis, so scratch accumulation is sound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(lengths_ref, starts_ref, kt_ref, vt_ref, q_ref, k_ref,
                       v_ref, out_ref, m_ref, l_ref, acc_ref, *, tp, scale):
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (Tp, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # (Tp, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # mask tokens outside [start, length) — start>0 implements sliding windows
    token_idx = p * tp + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where((token_idx < lengths_ref[b]) & (token_idx >= starts_ref[b]),
                  s, NEG_INF)

    m_prev = m_ref[...]                                  # (G, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)                            # (G, Tp)
    l_new = alpha * l_ref[...] + jnp.sum(pexp, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(p == n_pages - 1)
    def _finish():
        out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, kv_pages_k, kv_pages_v, page_table, lengths, *,
                    v_page_table=None, starts=None, interpret: bool = True):
    """q: (B, K, G, hd); kv pages: (F, Tp, K, hd); page_table: (B, P) int32
    (for K; V uses v_page_table if given, else the same table);
    lengths: (B,); starts: optional (B,) window lower bound.
    Returns (B, K, G, hd)."""
    B, K, G, hd = q.shape
    F, Tp, _, _ = kv_pages_k.shape
    P = page_table.shape[1]
    scale = hd ** -0.5
    if starts is None:
        starts = jnp.zeros_like(lengths)
    if v_page_table is None:
        v_page_table = page_table

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, K, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, k, p, ln, st, kt, vt: (b, k, 0, 0)),
            pl.BlockSpec((1, Tp, 1, hd),
                         lambda b, k, p, ln, st, kt, vt: (kt[b, p], 0, k, 0)),
            pl.BlockSpec((1, Tp, 1, hd),
                         lambda b, k, p, ln, st, kt, vt: (vt[b, p], 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, k, p, ln, st, kt, vt: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_attn_kernel, tp=Tp, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), starts.astype(jnp.int32),
      page_table.astype(jnp.int32), v_page_table.astype(jnp.int32),
      q, kv_pages_k, kv_pages_v)
    return out
