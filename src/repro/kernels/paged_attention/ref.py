"""Pure-jnp oracle for paged decode attention (GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, kv_pages_k, kv_pages_v, page_table, lengths,
                        starts=None, v_page_table=None):
    """q: (B, K, G, hd); kv pages: (F, Tp, K, hd); page_table: (B, P) int32;
    lengths: (B,) int32; starts: optional (B,) window lower bound.
    Returns (B, K, G, hd).

    Slot t of sequence b lives at page page_table[b, t // Tp], row t % Tp.
    """
    B, K, G, hd = q.shape
    F, Tp, _, _ = kv_pages_k.shape
    P = page_table.shape[1]
    if starts is None:
        starts = jnp.zeros_like(lengths)
    if v_page_table is None:
        v_page_table = page_table
    k = jnp.take(kv_pages_k, page_table, axis=0).reshape(B, P * Tp, K, hd)
    v = jnp.take(kv_pages_v, v_page_table, axis=0).reshape(B, P * Tp, K, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    t = jnp.arange(P * Tp)[None, :]
    mask = (t < lengths[:, None]) & (t >= starts[:, None])      # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32)).astype(q.dtype)
