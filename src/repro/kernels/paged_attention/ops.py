"""Public wrapper for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention as _kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


def paged_attention(q, kv_pages_k, kv_pages_v, page_table, lengths, *,
                    v_page_table=None, starts=None, backend: str = "auto"):
    """Decode attention over paged KV (GQA).

    q: (B, K, G, hd) — G = query heads per kv head.
    kv_pages_*: (F, Tp, K, hd) pool frames; page_table: (B, P); lengths: (B,);
    starts: optional (B,) lower bound (sliding windows).
    backend: "auto" | "kernel" | "ref".
    """
    q = jnp.asarray(q)
    if q.ndim != 4:
        raise ValueError(f"q must be (B,K,G,hd), got {q.shape}")
    if kv_pages_k.shape != kv_pages_v.shape:
        raise ValueError("k/v page pools must match")
    if backend == "ref":
        return paged_attention_ref(q, kv_pages_k, kv_pages_v, page_table,
                                   lengths, starts, v_page_table)
    on_tpu = jax.default_backend() == "tpu"
    if backend == "kernel" or (backend == "auto" and on_tpu):
        return _kernel(q, kv_pages_k, kv_pages_v, page_table, lengths,
                       v_page_table=v_page_table, starts=starts,
                       interpret=not on_tpu)
    return paged_attention_ref(q, kv_pages_k, kv_pages_v, page_table,
                               lengths, starts, v_page_table)
