"""Pure-jnp oracle for cow_scatter."""
from __future__ import annotations

import jax.numpy as jnp


def cow_scatter_ref(frames, page_ids, pages):
    """frames: (F, E); page_ids: (n,) unique int32; pages: (n, E).
    Returns frames with the given pages written (COW commit)."""
    return frames.at[page_ids].set(pages.astype(frames.dtype))
