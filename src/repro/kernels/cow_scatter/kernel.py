"""cow_scatter Pallas TPU kernel — the COW commit path.

Writes freshly-COW'd pages into their allocated pool frames in place
(input/output aliasing), with the frame ids scalar-prefetched so the output
BlockSpec index_map routes each page to its frame.  Inverse index map of
page_gather; frames not addressed by `page_ids` are untouched (aliased).

`page_ids` must be unique (each dirty page gets a fresh frame from the
allocator, so duplicates cannot occur in the fork runtime).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _scatter_kernel(pt_ref, pages_ref, frames_ref, out_ref):
    out_ref[...] = pages_ref[...]


def _scatter_runs_kernel(starts_ref, lens_ref, offs_ref, pages_ref,
                         frames_ref, out_ref):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j < lens_ref[i])
    def _():
        out_ref[...] = pages_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def cow_scatter(frames, page_ids, pages, *, interpret: bool = True):
    """frames: (F, E) pool; page_ids: (n,) int32 unique; pages: (n, E)."""
    F, E = frames.shape
    assert E % LANE == 0, f"page_elems must be lane-aligned, got {E}"
    R = E // LANE
    n = page_ids.shape[0]
    src = pages.reshape(n, R, LANE).astype(frames.dtype)
    dst = frames.reshape(F, R, LANE)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, R, LANE), lambda i, pt: (i, 0, 0)),      # pages
            pl.BlockSpec((1, R, LANE), lambda i, pt: (pt[i], 0, 0)),  # frames
        ],
        out_specs=pl.BlockSpec((1, R, LANE), lambda i, pt: (pt[i], 0, 0)),
    )
    out = pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((F, R, LANE), frames.dtype),
        input_output_aliases={2: 0},      # alias frames input -> output
        interpret=interpret,
    )(page_ids.astype(jnp.int32), src, dst)
    return out.reshape(F, E)


@functools.partial(jax.jit, static_argnames=("max_len", "interpret"),
                   donate_argnums=(0,))
def cow_scatter_runs(frames, starts, lens, offs, pages, *, max_len: int,
                     interpret: bool = True):
    """Run-table (doorbell-batched) COW commit: freshly-COW'd pages land in
    their allocated frame extents as one fused scatter per run table — the
    inverse of :func:`page_gather_runs`.

    frames: (F, E) pool; starts/lens/offs: (num_runs,) int32 describing
    contiguous destination extents (``lens >= 1``, runs must not overlap —
    each dirty page gets a fresh frame from the allocator); pages:
    (sum(lens), E) payload, run-major.  Grid step (i, j) writes payload row
    ``offs[i] + j`` into frame ``starts[i] + j``; steps past a run's end
    clamp to the run's last block (just written) and skip the store, so the
    aliased pool content outside the runs is untouched.
    """
    F, E = frames.shape
    assert E % LANE == 0, f"page_elems must be lane-aligned, got {E}"
    R = E // LANE
    num_runs = starts.shape[0]
    n = pages.shape[0]
    src = pages.reshape(n, R, LANE).astype(frames.dtype)
    dst = frames.reshape(F, R, LANE)

    def _clamp(i, j, lens):
        return jnp.minimum(j, lens[i] - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(num_runs, max_len),
        in_specs=[
            pl.BlockSpec((1, R, LANE),
                         lambda i, j, starts, lens, offs:
                         (offs[i] + _clamp(i, j, lens), 0, 0)),      # pages
            pl.BlockSpec((1, R, LANE),
                         lambda i, j, starts, lens, offs:
                         (starts[i] + _clamp(i, j, lens), 0, 0)),    # frames
        ],
        out_specs=pl.BlockSpec((1, R, LANE),
                               lambda i, j, starts, lens, offs:
                               (starts[i] + _clamp(i, j, lens), 0, 0)),
    )
    out = pl.pallas_call(
        _scatter_runs_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((F, R, LANE), frames.dtype),
        input_output_aliases={4: 0},      # alias frames input -> output
        interpret=interpret,
    )(starts.astype(jnp.int32), lens.astype(jnp.int32),
      offs.astype(jnp.int32), src, dst)
    return out.reshape(F, E)
