"""Public wrapper for cow_scatter."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cow_scatter.kernel import cow_scatter as _kernel
from repro.kernels.cow_scatter.ref import cow_scatter_ref


def cow_scatter(frames, page_ids, pages, *, backend: str = "auto"):
    """Commit COW pages into pool frames. page_ids must be unique."""
    page_ids = jnp.asarray(page_ids, jnp.int32)
    if backend == "ref":
        return cow_scatter_ref(frames, page_ids, pages)
    on_tpu = jax.default_backend() == "tpu"
    if backend == "kernel" or (backend == "auto" and on_tpu):
        return _kernel(frames, page_ids, pages, interpret=not on_tpu)
    return cow_scatter_ref(frames, page_ids, pages)
