"""Public wrappers for cow_scatter: backend dispatch (kernels/dispatch.py),
the run-table (extent-run) commit variant, and the fused tensor-patch path
used by incremental reassembly."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.cow_scatter.kernel import cow_scatter as _kernel
from repro.kernels.cow_scatter.kernel import cow_scatter_runs as _kernel_runs
from repro.kernels.cow_scatter.ref import cow_scatter_ref
from repro.kernels.page_gather.ref import expand_runs


@jax.jit
def _set_jit(frames, ids, pages):
    return frames.at[ids].set(pages.astype(frames.dtype))


@functools.partial(jax.jit, static_argnames=("npages", "page_elems"))
def _patch_jit(t, ids, rows, *, npages, page_elems):
    # one XLA fusion: flatten -> pad to the page grid -> scatter the
    # changed pages -> trim -> original layout
    size = t.size
    flat = t.reshape(-1).astype(rows.dtype)
    pad = npages * page_elems - size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, rows.dtype)])
    paged = flat.reshape(npages, page_elems).at[ids].set(rows)
    return (jax.lax.slice(paged.reshape(-1), (0,), (size,))
            .reshape(t.shape).astype(t.dtype))


def cow_scatter(frames, page_ids, pages, *, backend: str = "auto"):
    """Commit COW pages into pool frames: frames (F, E); page_ids (n,)
    unique int32; pages (n, E) -> updated frames."""
    page_ids = jnp.asarray(page_ids, jnp.int32)
    if page_ids.shape[0] == 0:
        return frames
    impl, interpret = dispatch.resolve_backend(backend,
                                               kernel_name="cow_scatter")
    if impl == dispatch.IMPL_REF:
        return cow_scatter_ref(frames, page_ids, pages)
    if impl == dispatch.IMPL_JNP:
        return _set_jit(frames, page_ids, pages)
    return _kernel(frames, page_ids, pages, interpret=interpret)


def cow_scatter_runs(frames, starts, lens, pages, *, backend: str = "auto"):
    """Run-table COW commit: each (start, len) pair is one contiguous
    destination extent; pages is the run-major payload (sum(lens), E).
    Runs must not overlap (fresh frames from the allocator)."""
    starts_np = np.atleast_1d(np.asarray(starts, np.int64)).ravel()
    lens_np = np.atleast_1d(np.asarray(lens, np.int64)).ravel()
    keep = lens_np > 0
    starts_np, lens_np = starts_np[keep], lens_np[keep]
    if starts_np.size == 0:
        return frames
    impl, interpret = dispatch.resolve_backend(backend,
                                               kernel_name="cow_scatter")
    if impl == dispatch.IMPL_REF:
        return cow_scatter_ref(frames,
                               jnp.asarray(expand_runs(starts_np, lens_np)),
                               pages)
    if impl == dispatch.IMPL_JNP:
        return _set_jit(frames, jnp.asarray(expand_runs(starts_np, lens_np)),
                        pages)
    offs = np.concatenate([[0], np.cumsum(lens_np)[:-1]])
    return _kernel_runs(frames, jnp.asarray(starts_np, jnp.int32),
                        jnp.asarray(lens_np, jnp.int32),
                        jnp.asarray(offs, jnp.int32), pages,
                        max_len=int(lens_np.max()), interpret=interpret)


def scatter_patch(t, page_ids, rows, *, page_elems: int,
                  backend: str = "auto"):
    """Patch changed pages into an already-assembled tensor ``t``: the
    incremental-reassembly path.  ``rows`` is (n, page_elems) page payload;
    page ``p`` covers flat elements ``[p*page_elems, (p+1)*page_elems)`` of
    ``t`` (the final page's padding is trimmed).  Fused on device; never
    re-gathers unchanged pages."""
    page_ids = jnp.asarray(page_ids, jnp.int32)
    if page_ids.shape[0] == 0:
        return t
    size = int(np.prod(t.shape)) if t.shape else 1
    npages = -(-size // page_elems)
    impl, interpret = dispatch.resolve_backend(backend,
                                               kernel_name="cow_scatter")
    if impl == dispatch.IMPL_REF:
        flat = np.asarray(t, jnp.dtype(rows.dtype)).reshape(-1)
        buf = np.zeros(npages * page_elems, flat.dtype)
        buf[:size] = flat
        buf.reshape(npages, page_elems)[np.asarray(page_ids)] = \
            np.asarray(rows)
        return jnp.asarray(buf[:size].reshape(t.shape).astype(t.dtype))
    if impl in (dispatch.IMPL_KERNEL, dispatch.IMPL_INTERPRET):
        flat = t.reshape(-1).astype(rows.dtype)
        pad = npages * page_elems - size
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros(pad, rows.dtype)])
        paged = _kernel(flat.reshape(npages, page_elems), page_ids, rows,
                        interpret=interpret)
        return (paged.reshape(-1)[:size].reshape(t.shape).astype(t.dtype))
    return _patch_jit(t, page_ids, rows, npages=npages,
                      page_elems=page_elems)
