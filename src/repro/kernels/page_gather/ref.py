"""Pure-jnp oracle for page_gather."""
from __future__ import annotations

import jax.numpy as jnp


def page_gather_ref(frames, page_ids):
    """frames: (F, page_elems); page_ids: (n,) int32 -> (n, page_elems)."""
    return jnp.take(frames, page_ids, axis=0)
