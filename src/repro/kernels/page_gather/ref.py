"""Pure-jnp oracles for page_gather and its run-table variant."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def page_gather_ref(frames, page_ids):
    """frames: (F, page_elems); page_ids: (n,) int32 -> (n, page_elems)."""
    return jnp.take(frames, page_ids, axis=0)


def expand_runs(starts, lens) -> np.ndarray:
    """(starts, lens) run table -> flat page-id list, run-major.  Host-side
    numpy (the table is fault-handler metadata, never payload); zero-length
    runs contribute nothing."""
    starts = np.atleast_1d(np.asarray(starts, np.int64)).ravel()
    lens = np.atleast_1d(np.asarray(lens, np.int64)).ravel()
    keep = lens > 0
    starts, lens = starts[keep], lens[keep]
    if starts.size == 0:
        return np.zeros(0, np.int32)
    total = int(lens.sum())
    # vectorized concatenate-of-aranges: boundary deltas + one cumsum
    deltas = np.ones(total, np.int64)
    offs = np.cumsum(lens)[:-1]              # start index of runs 1..R-1
    deltas[offs] = starts[1:] - (starts[:-1] + lens[:-1]) + 1
    deltas[0] = starts[0]
    return np.cumsum(deltas).astype(np.int32)


def page_gather_runs_ref(frames, starts, lens):
    """Run-table gather oracle: frames (F, E); starts/lens (num_runs,) with
    lens >= 0 -> (sum(lens), E), run-major."""
    return jnp.take(frames, expand_runs(starts, lens), axis=0)
