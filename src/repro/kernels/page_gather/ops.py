"""jit'd public wrapper for page_gather with shape/dtype checking and a
backend switch (TPU kernel / interpret-mode validation / jnp fallback)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.page_gather.kernel import page_gather as _kernel
from repro.kernels.page_gather.ref import page_gather_ref


def page_gather(frames, page_ids, *, backend: str = "auto"):
    """Gather pool frames by page id.

    backend: "auto" (kernel on TPU, jnp elsewhere), "kernel" (pallas,
    interpret off-TPU), "ref" (pure jnp oracle).
    """
    page_ids = jnp.asarray(page_ids, jnp.int32)
    if frames.ndim != 2:
        raise ValueError(f"frames must be (F, page_elems), got {frames.shape}")
    if backend == "ref":
        return page_gather_ref(frames, page_ids)
    on_tpu = jax.default_backend() == "tpu"
    if backend == "kernel" or (backend == "auto" and on_tpu):
        return _kernel(frames, page_ids, interpret=not on_tpu)
    return page_gather_ref(frames, page_ids)
