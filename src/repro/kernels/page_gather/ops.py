"""Public wrappers for page_gather: shape/dtype checking, the shared
backend dispatch (compiled Pallas when available, fused XLA otherwise —
see kernels/dispatch.py), the run-table (doorbell-shaped) variant, and the
fused gather->reassemble path the fault handler uses for tensor assembly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.page_gather.kernel import page_gather as _kernel
from repro.kernels.page_gather.kernel import page_gather_runs as _kernel_runs
from repro.kernels.page_gather.ref import (expand_runs, page_gather_ref,
                                           page_gather_runs_ref)


@jax.jit
def _take_jit(frames, ids):
    return jnp.take(frames, ids, axis=0)


@functools.partial(jax.jit, static_argnames=("size", "shape", "out_dtype"))
def _assemble_jit(frames, ids, *, size, shape, out_dtype):
    # one XLA fusion: gather -> flatten -> trim padding -> destination
    # layout; no intermediate page-list materialization
    flat = jnp.take(frames, ids, axis=0).reshape(-1)
    return jax.lax.slice(flat, (0,), (size,)).reshape(shape).astype(out_dtype)


def page_gather(frames, page_ids, *, backend: str = "auto"):
    """Gather pool frames by page id: frames (F, E); page_ids (n,) int32
    -> (n, E).  ``backend`` is resolved by ``kernels.dispatch`` (auto |
    kernel | interpret | jnp | ref)."""
    page_ids = jnp.asarray(page_ids, jnp.int32)
    if frames.ndim != 2:
        raise ValueError(f"frames must be (F, page_elems), got {frames.shape}")
    if page_ids.shape[0] == 0:
        return jnp.zeros((0, frames.shape[1]), frames.dtype)
    impl, interpret = dispatch.resolve_backend(backend,
                                               kernel_name="page_gather")
    if impl == dispatch.IMPL_REF:
        return page_gather_ref(frames, page_ids)
    if impl == dispatch.IMPL_JNP:
        return _take_jit(frames, page_ids)
    return _kernel(frames, page_ids, interpret=interpret)


def page_gather_runs(frames, starts, lens, *, backend: str = "auto"):
    """Run-table gather — the doorbell-batch shape: each (start, len) pair
    is one contiguous frame extent (one SGE).  Returns (sum(lens), E),
    run-major.  Zero-length runs are filtered here; the kernels require
    ``lens >= 1``."""
    if frames.ndim != 2:
        raise ValueError(f"frames must be (F, page_elems), got {frames.shape}")
    starts_np = np.atleast_1d(np.asarray(starts, np.int64)).ravel()
    lens_np = np.atleast_1d(np.asarray(lens, np.int64)).ravel()
    keep = lens_np > 0
    starts_np, lens_np = starts_np[keep], lens_np[keep]
    if starts_np.size == 0:
        return jnp.zeros((0, frames.shape[1]), frames.dtype)
    impl, interpret = dispatch.resolve_backend(backend,
                                               kernel_name="page_gather")
    if impl == dispatch.IMPL_REF:
        return page_gather_runs_ref(frames, starts_np, lens_np)
    if impl == dispatch.IMPL_JNP:
        return _take_jit(frames, jnp.asarray(expand_runs(starts_np, lens_np)))
    offs = np.concatenate([[0], np.cumsum(lens_np)[:-1]])
    return _kernel_runs(frames, jnp.asarray(starts_np, jnp.int32),
                        jnp.asarray(lens_np, jnp.int32),
                        jnp.asarray(offs, jnp.int32),
                        max_len=int(lens_np.max()), n_out=int(lens_np.sum()),
                        interpret=interpret)


def gather_assemble(frames, page_ids, shape, *, out_dtype=None,
                    backend: str = "auto"):
    """Fused gather->reassemble: fault pages land directly in the
    destination tensor layout (flatten, trim the last page's padding,
    reshape) with no intermediate page-list concatenate on the host."""
    page_ids = jnp.asarray(page_ids, jnp.int32)
    shape = tuple(int(s) for s in shape)
    size = int(np.prod(shape)) if shape else 1
    out_dtype = jnp.dtype(out_dtype or frames.dtype)
    impl, interpret = dispatch.resolve_backend(backend,
                                               kernel_name="page_gather")
    if impl in (dispatch.IMPL_KERNEL, dispatch.IMPL_INTERPRET):
        pages = _kernel(frames, page_ids, interpret=interpret)
        return pages.reshape(-1)[:size].reshape(shape).astype(out_dtype)
    if impl == dispatch.IMPL_REF:
        pages = page_gather_ref(frames, page_ids)
        return pages.reshape(-1)[:size].reshape(shape).astype(out_dtype)
    return _assemble_jit(frames, page_ids, size=size, shape=shape,
                         out_dtype=out_dtype)
