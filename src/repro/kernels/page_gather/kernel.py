"""page_gather Pallas TPU kernel — the MITOSIS fault handler's data plane.

The page table lives in SMEM via scalar prefetch (PrefetchScalarGridSpec),
so the BlockSpec index_map plays the role of the PTE walk: grid step i
copies pool frame pt[i] into output slot i, HBM->VMEM->HBM, one page per
grid step.  On real hardware the src pool can be a remote pod's HBM via
RDMA (`pltpu.make_async_remote_copy`); the on-chip structure is identical.

Pages are viewed as (rows, 128) tiles: 128-lane alignment is mandatory on
TPU, and page_elems is a multiple of 128 by construction (memory/pool.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _copy_kernel(pt_ref, src_ref, out_ref):
    out_ref[...] = src_ref[...]


def _copy_runs_kernel(starts_ref, lens_ref, offs_ref, src_ref, out_ref):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j < lens_ref[i])
    def _():
        out_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather(frames, page_ids, *, interpret: bool = True):
    """frames: (F, page_elems); page_ids: (n,) int32 -> (n, page_elems)."""
    F, E = frames.shape
    assert E % LANE == 0, f"page_elems must be lane-aligned, got {E}"
    R = E // LANE
    n = page_ids.shape[0]
    src = frames.reshape(F, R, LANE)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, R, LANE), lambda i, pt: (pt[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, LANE), lambda i, pt: (i, 0, 0)),
    )
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, R, LANE), frames.dtype),
        interpret=interpret,
    )(page_ids.astype(jnp.int32), src)
    return out.reshape(n, E)


@functools.partial(jax.jit, static_argnames=("max_len", "n_out", "interpret"))
def page_gather_runs(frames, starts, lens, offs, *, max_len: int, n_out: int,
                     interpret: bool = True):
    """Run-table (doorbell-batched) gather: the frame-id table arrives as
    maximal contiguous runs — exactly the SGE list PR 3's fault handler
    posts — instead of one id per page.

    frames: (F, page_elems); starts/lens/offs: (num_runs,) int32 with
    ``lens >= 1`` (empty runs are filtered at the ops layer) and
    ``offs = exclusive cumsum(lens)``; ``n_out = sum(lens)`` pages out.

    Grid is (runs, max_len): step (i, j) copies pool frame
    ``starts[i] + j`` into output slot ``offs[i] + j`` while ``j`` is
    inside run i, so one scalar-prefetched table drives the whole extent
    run HBM->VMEM->HBM with no per-page host dispatch.  Steps past a
    run's end clamp their index map to the run's last block (already
    written at step ``lens[i]-1``) and skip the store.
    """
    F, E = frames.shape
    assert E % LANE == 0, f"page_elems must be lane-aligned, got {E}"
    R = E // LANE
    num_runs = starts.shape[0]
    src = frames.reshape(F, R, LANE)

    def _clamp(i, j, lens):
        return jnp.minimum(j, lens[i] - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(num_runs, max_len),
        in_specs=[
            pl.BlockSpec((1, R, LANE),
                         lambda i, j, starts, lens, offs:
                         (starts[i] + _clamp(i, j, lens), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, LANE),
                               lambda i, j, starts, lens, offs:
                               (offs[i] + _clamp(i, j, lens), 0, 0)),
    )
    out = pl.pallas_call(
        _copy_runs_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, R, LANE), frames.dtype),
        interpret=interpret,
    )(starts.astype(jnp.int32), lens.astype(jnp.int32),
      offs.astype(jnp.int32), src)
    return out.reshape(n_out, E)
