# Kernel layer: the fault handler's fused data plane (page_gather /
# cow_scatter, per-page and run-table variants) plus serving decode's
# paged_attention.  Each kernel ships <name>/kernel.py (Pallas TPU),
# ref.py (pure-jnp oracle) and ops.py (public wrapper); backend selection
# and the chosen-impl meters live in kernels/dispatch.py — see
# docs/kernels.md for the contracts.
from repro.kernels import dispatch  # noqa: F401
