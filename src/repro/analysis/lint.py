"""Determinism linter — a stdlib-``ast`` pass over the sim-critical tree.

Every replay digest this repo pins assumes the simulation's inputs are
exactly (trace, seed): no wall clock, no process entropy, no
hash-randomized iteration order feeding event scheduling or digest
input.  This linter makes those assumptions checkable::

    PYTHONPATH=src python -m repro.analysis.lint src/repro
    PYTHONPATH=src python -m repro.analysis.lint --json src/repro

Rules (see ``docs/analysis.md`` for the full catalog):

``wall-clock``
    References to host clocks — ``time.time`` / ``time.monotonic`` /
    ``time.perf_counter`` / ``time.process_time`` (called *or* stored,
    e.g. as a ``clock=`` default) and argless ``datetime.now()`` /
    ``utcnow()`` / ``today()``.
``unseeded-random``
    Module-level ``random.*`` / ``np.random.*`` draws (a seeded
    ``random.Random(seed)`` / ``np.random.default_rng(seed)`` instance
    is fine — the *argless* constructors are not) and any ``secrets.*``
    call (process entropy by definition).
``set-iter``
    Iterating a ``set`` (literal, ``set(...)``/``frozenset(...)``,
    set-typed locals, or set-annotated attributes like ``conn.users``)
    without an explicit ``sorted(...)``: string-set order is
    hash-randomized per process, so any order-sensitive consumer
    diverges across runs.
``float-sum``
    ``sum(...)`` over a set (directly or via a generator): float
    addition is non-associative, so an unordered reduction can differ
    in the last ulp between processes.
``dict-iter`` (``--strict`` only)
    Iterating ``.keys()`` / ``.values()`` / ``.items()`` without
    ``sorted(...)``.  Dict views are insertion-ordered (deterministic
    within a run), so this is an advisory audit rule, not a default
    failure.

Any finding is suppressible in place with a ``# sim-ok: <rule>`` comment
on the same line or the line above, optionally with a reason after
``--``::

    clock=time.monotonic,   # sim-ok: wall-clock -- host default; replays pass SimClock

Only files under the sim-critical packages (``sim/``, ``net/``,
``placement/``, ``fork/``, ``platform/``, ``memory/``) are checked;
everything else (benchmarks, launch scripts, training loops) measures
wall time on purpose.  ``--all`` lints every given file regardless.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

SIM_CRITICAL = ("sim", "net", "placement", "fork", "platform", "memory")

RULES = ("wall-clock", "unseeded-random", "set-iter", "float-sum",
         "dict-iter")

_WALL_CLOCK_ATTRS = {"time", "monotonic", "perf_counter", "process_time",
                     "clock", "monotonic_ns", "perf_counter_ns", "time_ns"}
_DATETIME_NOW = {"now", "utcnow", "today"}
_SEEDABLE_RNG_CTORS = {"Random", "default_rng", "Generator", "RandomState",
                       "PCG64", "Philox", "SeedSequence", "seed", "SystemRandom"}
_DICT_VIEWS = {"keys", "values", "items"}

_SIM_OK_RE = re.compile(r"#\s*sim-ok:\s*([a-z\-,\s]+?)(?:--|$)")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule}: {self.message}{tag}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _suppressions(source: str) -> Dict[int, Set[str]]:
    """line number -> rules waived there by a ``# sim-ok:`` comment.  A
    waiver covers its own line plus the statement the comment block sits
    directly above, so multi-line reason comments work: the marker
    propagates down through contiguous comment-only lines."""
    lines = source.splitlines()
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _SIM_OK_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        out.setdefault(i, set()).update(rules)
        if not text.lstrip().startswith("#"):
            continue        # trailing comment: covers its own line only
        # comment-only line: extend through the comment block below
        # (continuation lines) to the first code line, which inherits it
        j = i + 1
        while j <= len(lines) and lines[j - 1].lstrip().startswith("#"):
            out.setdefault(j, set()).update(rules)
            j += 1
        if j <= len(lines):
            out.setdefault(j, set()).update(rules)
    return out


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_set_annotation(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Subscript):       # Set[str] / set[str] / frozenset[...]
        node = node.value
    name = _dotted(node)
    return name is not None and name.split(".")[-1] in (
        "Set", "set", "FrozenSet", "frozenset", "MutableSet", "AbstractSet")


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str, strict: bool = False,
                 extra_set_attrs: Optional[Set[str]] = None):
        self.path = path
        self.strict = strict
        self.findings: List[Finding] = []
        self.suppress = _suppressions(source)
        tree = ast.parse(source, filename=path)
        self.tree = tree
        # names `from time import ...` pulled into this module
        self.time_imports: Set[str] = set()
        # attribute names annotated/assigned as sets anywhere in the module
        # (e.g. ``self.users: Set[str] = set()``) — lets ``for u in conn.users``
        # resolve as set iteration without type inference.  ``extra_set_attrs``
        # carries the same knowledge collected across the whole lint run, so
        # an attribute annotated in types.py is recognized in pool.py.
        self.set_attrs: Set[str] = set(extra_set_attrs or ())
        self._prepass(tree)
        # per-scope set-typed local/global names (stack of scopes)
        self._set_names: List[Set[str]] = [set()]

    # -- prepass -------------------------------------------------------------

    def _prepass(self, tree: ast.AST) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in _WALL_CLOCK_ATTRS:
                        self.time_imports.add(alias.asname or alias.name)
            elif isinstance(node, ast.AnnAssign) and \
                    _is_set_annotation(node.annotation):
                if isinstance(node.target, ast.Attribute):
                    self.set_attrs.add(node.target.attr)
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute) and \
                            self._is_set_expr_shallow(node.value):
                        self.set_attrs.add(tgt.attr)

    # -- helpers -------------------------------------------------------------

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        line = getattr(node, "lineno", 0)
        # _suppressions already propagated comment-block waivers down to
        # their statement line; a trailing comment covers only its own line
        waived = self.suppress.get(line, set())
        self.findings.append(Finding(
            path=self.path, line=line, col=getattr(node, "col_offset", 0),
            rule=rule, message=message, suppressed=rule in waived))

    def _is_set_expr_shallow(self, node: ast.AST) -> bool:
        """Syntactically-a-set without scope lookups (used by the prepass)."""
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        return False

    def _is_set_expr(self, node: ast.AST) -> bool:
        if self._is_set_expr_shallow(node):
            return True
        if isinstance(node, ast.Name):
            return any(node.id in scope for scope in self._set_names)
        if isinstance(node, ast.Attribute):
            return node.attr in self.set_attrs
        if isinstance(node, ast.BinOp) and \
                isinstance(node.op, (ast.BitOr, ast.BitAnd, ast.Sub,
                                     ast.BitXor)):
            return self._is_set_expr(node.left) or \
                self._is_set_expr(node.right)
        return False

    def _is_dict_view(self, node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _DICT_VIEWS
                and not node.args and not node.keywords)

    # -- scopes --------------------------------------------------------------

    def _visit_scope(self, node) -> None:
        self._set_names.append(set())
        self.generic_visit(node)
        self._set_names.pop()

    visit_FunctionDef = _visit_scope
    visit_AsyncFunctionDef = _visit_scope
    visit_Lambda = _visit_scope

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                if self._is_set_expr(node.value):
                    self._set_names[-1].add(tgt.id)
                else:
                    self._set_names[-1].discard(tgt.id)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if isinstance(node.target, ast.Name) and \
                _is_set_annotation(node.annotation):
            self._set_names[-1].add(node.target.id)
        self.generic_visit(node)

    # -- wall-clock ----------------------------------------------------------

    def visit_Attribute(self, node: ast.Attribute) -> None:
        dotted = _dotted(node)
        if dotted is not None:
            head, _, _ = dotted.partition(".")
            leaf = dotted.rsplit(".", 1)[-1]
            if head == "time" and leaf in _WALL_CLOCK_ATTRS and \
                    dotted == f"time.{leaf}":
                self._emit(node, "wall-clock",
                           f"host clock `{dotted}` in sim-critical code "
                           "(use the network's sim clock / SimClock)")
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load) and node.id in self.time_imports:
            self._emit(node, "wall-clock",
                       f"host clock `{node.id}` (from time import ...) "
                       "in sim-critical code")
        self.generic_visit(node)

    # -- calls: datetime / random / secrets / sum ----------------------------

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func) or ""
        parts = dotted.split(".")
        # argless datetime.now()/utcnow()/today()
        if parts[-1] in _DATETIME_NOW and not node.args and not node.keywords \
                and any(p in ("datetime", "date") for p in parts[:-1]):
            self._emit(node, "wall-clock",
                       f"argless `{dotted}()` reads the host clock "
                       "(pass an explicit sim timestamp)")
        # module-level random draws
        if len(parts) == 2 and parts[0] == "random":
            if parts[1] not in _SEEDABLE_RNG_CTORS:
                self._emit(node, "unseeded-random",
                           f"module-level `{dotted}()` draws from the "
                           "process-global RNG (use a seeded "
                           "random.Random(seed))")
            elif not node.args and not node.keywords and \
                    parts[1] != "SystemRandom":
                self._emit(node, "unseeded-random",
                           f"argless `{dotted}()` is entropy-seeded "
                           "(pass an explicit seed)")
            if parts[1] == "SystemRandom":
                self._emit(node, "unseeded-random",
                           "`random.SystemRandom` is OS entropy by design")
        if len(parts) >= 2 and parts[-2] == "random" and \
                parts[0] in ("np", "numpy"):
            if parts[-1] not in _SEEDABLE_RNG_CTORS:
                self._emit(node, "unseeded-random",
                           f"module-level `{dotted}()` draws from numpy's "
                           "global RNG (use np.random.default_rng(seed))")
            elif not node.args and not node.keywords:
                self._emit(node, "unseeded-random",
                           f"argless `{dotted}()` is entropy-seeded "
                           "(pass an explicit seed)")
        if len(parts) == 2 and parts[0] == "secrets":
            self._emit(node, "unseeded-random",
                       f"`{dotted}()` is process entropy — nondeterministic "
                       "across runs by definition")
        # float accumulation over unordered collections
        if isinstance(node.func, ast.Name) and node.func.id == "sum" \
                and node.args:
            arg = node.args[0]
            unordered = self._is_set_expr(arg) or (
                self.strict and self._is_dict_view(arg))
            if isinstance(arg, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
                for gen in arg.generators:
                    if self._is_set_expr(gen.iter) or (
                            self.strict and self._is_dict_view(gen.iter)):
                        unordered = True
            if unordered:
                self._emit(node, "float-sum",
                           "sum() over an unordered collection — float "
                           "addition is order-sensitive; sort first")
        self.generic_visit(node)

    # -- iteration order -----------------------------------------------------

    def _check_iter(self, iter_node: ast.AST, where: ast.AST) -> None:
        # sorted(...) / min / max / len consume order-insensitively
        if self._is_set_expr(iter_node):
            self._emit(where, "set-iter",
                       "iterating a set — hash-randomized order; wrap in "
                       "sorted(...) or annotate why order cannot matter")
        elif self.strict and self._is_dict_view(iter_node):
            self._emit(where, "dict-iter",
                       "iterating a dict view — insertion-ordered but "
                       "audit-worthy when it feeds scheduling or digests")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter, node)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter, node)
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp


def is_sim_critical(path: Path) -> bool:
    parts = path.resolve().parts
    for i, p in enumerate(parts[:-1]):
        if p == "repro" and parts[i + 1] in SIM_CRITICAL:
            return True
    return False


def lint_source(source: str, path: str = "<string>", strict: bool = False,
                extra_set_attrs: Optional[Set[str]] = None) -> List[Finding]:
    """Lint one source string; returns every finding (suppressed included)."""
    linter = _Linter(path, source, strict=strict,
                     extra_set_attrs=extra_set_attrs)
    linter.visit(linter.tree)
    return sorted(linter.findings, key=lambda f: (f.line, f.col, f.rule))


def collect_set_attrs(sources: Iterable[Tuple[str, str]]) -> Set[str]:
    """Union of set-annotated/assigned attribute names across (path, source)
    pairs — the cross-module prepass that lets ``for u in conn.users`` in
    one file resolve against the ``users: Set[str]`` annotation in another."""
    attrs: Set[str] = set()
    for path, source in sources:
        try:
            linter = _Linter(path, source)
        except SyntaxError:
            continue
        attrs |= linter.set_attrs
    return attrs


def iter_py_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        else:
            out.append(path)
    return out


def lint_paths(paths: Iterable[str], strict: bool = False,
               everything: bool = False) -> Tuple[List[Finding], int]:
    """Lint ``paths`` (files or trees).  Returns (findings, files_checked);
    non-sim-critical files are skipped unless ``everything``."""
    findings: List[Finding] = []
    files = [(f, f.read_text()) for f in iter_py_files(paths)
             if everything or is_sim_critical(f)]
    set_attrs = collect_set_attrs((str(f), src) for f, src in files)
    for f, src in files:
        findings.extend(lint_source(src, path=str(f), strict=strict,
                                    extra_set_attrs=set_attrs))
    return findings, len(files)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Determinism linter for the sim-critical tree.")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to lint (default: src/repro)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output (one JSON document)")
    ap.add_argument("--strict", action="store_true",
                    help="enable the advisory dict-iter audit rule")
    ap.add_argument("--all", action="store_true", dest="everything",
                    help="lint every given file, not just sim-critical ones")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings waived by # sim-ok comments")
    args = ap.parse_args(argv)
    findings, checked = lint_paths(args.paths or ["src/repro"],
                                   strict=args.strict,
                                   everything=args.everything)
    active = [f for f in findings if not f.suppressed]
    shown = findings if args.show_suppressed else active
    if args.json:
        print(json.dumps({
            "files_checked": checked,
            "findings": [f.to_dict() for f in shown],
            "active": len(active),
            "suppressed": len(findings) - len(active),
        }, indent=1, sort_keys=True))
    else:
        for f in shown:
            print(f.format())
        print(f"{checked} file(s) checked: {len(active)} finding(s), "
              f"{len(findings) - len(active)} suppressed")
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
