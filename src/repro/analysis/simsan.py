"""SimSan — opt-in runtime invariant sanitizer for the replay stack.

Every pinned result in this repo (fig20's p99 win, the fault plane's
digest-identical replays, the conn-pool byte invariants) rests on a small
set of conservation and monotonicity invariants that nothing used to
check at runtime:

* **clock/lane monotonicity** — per-node link-lane reservations never
  overlap beyond the NIC's lane count, and the absolute busy-until stamps
  (``channel_busy``, ``link_free``) only move forward;
* **meter conservation** — per-backend ``{name}.bytes`` exactly equals
  the payload bytes the transports charged (a shadow ledger), faulted
  retries move zero payload, and every transport-returned page payload is
  handed to ``PagePool.write_pages`` whole (no rows dropped or doubled);
* **connection-pool consistency** — pool slots and the manager's live
  table agree bidirectionally, refcount indices never dangle, evicted
  QPs are never touched again, and bounded pools respect their cap;
* **lease state machine** — seeds move only along legal edges
  (register -> renew/revoke* -> reclaim, with crash killing a node's
  whole registry), and a lost parent is telemetered as ``parent_lost``
  exactly once per (function, node) incarnation.

The sanitizer is wired into the existing chokepoints behind ``None``
guards, mirroring the fault plane's ``net.faults`` pattern: with it off
(the default) the data plane runs byte-identically to a pre-SimSan
build.  Turn it on with ``REPRO_SIMSAN=1`` in the environment or
``Network(sanitize=True)``; violations raise :class:`SanitizerError`
with the violating op's full context.  A sanitized replay of a correct
build is digest-identical to an unsanitized one — the sanitizer only
reads, it never perturbs the clock or the meters (``BENCH_faults.json``
pins this for fig22's storm row).

This module deliberately imports nothing from ``repro.net`` /
``repro.sim`` (the network imports *us*), so it can sit underneath the
whole stack without an import cycle.
"""
from __future__ import annotations

import contextlib
import os
from typing import Any, Dict, Iterator, List, Optional, Set, Tuple

# float slop for comparing absolute sim-time stamps: resource math is
# sums/maxes of small floats, so equality checks get one ulp-ish margin
EPS = 1e-9

_ENV = "REPRO_SIMSAN"


def enabled() -> bool:
    """True iff the environment opts into sanitized runs
    (``REPRO_SIMSAN=1`` / ``true`` / ``yes`` / ``on``)."""
    return os.environ.get(_ENV, "").strip().lower() in (
        "1", "true", "yes", "on")


class SanitizerError(AssertionError):
    """A runtime invariant violation, carrying the violating op's context.

    ``check`` names the invariant (e.g. ``lane-overlap``, ``meter-drift``,
    ``lease-edge``); ``op`` describes the operation that tripped it;
    ``context`` holds every value the check compared, so the message is a
    complete bug report on its own.
    """

    def __init__(self, check: str, op: str, **context: Any):
        self.check = check
        self.op = op
        self.context = context
        ctx = " ".join(f"{k}={v!r}" for k, v in context.items())
        super().__init__(f"[simsan:{check}] {op}" + (f" ({ctx})" if ctx else ""))


class Sanitizer:
    """All SimSan state for one Network.  Install via
    ``Network(sanitize=True)`` (or ``REPRO_SIMSAN=1``); every hook is a
    no-op path in the instrumented code when the network's ``sanitizer``
    is None."""

    def __init__(self, net):
        self.net = net
        self.checks = 0             # checks performed (deterministic count)
        # shadow of the per-backend {name}.bytes meter keys: only the
        # transports' _charge writes them, so the shadow must track exactly
        self._shadow_bytes: Dict[str, float] = {}
        # transport-returned page payloads awaiting adoption:
        # id(arr) -> (arr, backend, rows, nbytes).  The strong reference
        # pins the array so a recycled id can never alias a stale tag;
        # prefetch payloads that are discarded unadopted simply stay until
        # the sanitizer is dropped with its network.
        self._payloads: Dict[int, Tuple[Any, str, int, int]] = {}
        # lease registry state: (node_id, handler_id) -> "live" | "reclaimed"
        self._leases: Dict[Tuple[str, int], str] = {}
        # parent_lost accounting: node -> funcs already counted for this
        # incarnation of the node (cleared when the node re-registers)
        self._lost: Dict[str, Set[str]] = {}
        # >0 while inside a multi-step teardown whose intermediate states
        # are deliberately inconsistent (see ``bulk``)
        self._suspended = 0

    @contextlib.contextmanager
    def bulk(self) -> Iterator[None]:
        """Suspend per-mutation connection scans across a cascade (e.g.
        ``drop_node`` pops the pool first, then evicts its conns one by
        one): the caller re-runs ``check_conns`` once at the end, so only
        the intermediate states are exempt."""
        self._suspended += 1
        try:
            yield
        finally:
            self._suspended -= 1

    # -- clock / lane monotonicity ------------------------------------------

    def link_hold(self, node_id: str, start: float, end: float,
                  op: str) -> None:
        """A transport is about to hold one of ``node_id``'s link lanes
        for [start, end].  Legal iff the hold has non-negative duration
        and starts no earlier than the node's earliest-free lane — an
        earlier start would overlap a reservation on EVERY lane, i.e. the
        caller skipped the ``link_free`` term of its start max()."""
        self.checks += 1
        if end < start - EPS:
            raise SanitizerError("negative-hold", op, node=node_id,
                                 start=start, end=end)
        free = self.net.link_free(node_id)
        if start < free - EPS:
            raise SanitizerError(
                "lane-overlap", op, node=node_id, start=start, end=end,
                earliest_free_lane=free,
                lanes=self.net.model.node_links)

    def channel_hold(self, src: str, dst: str, start: float, end: float,
                     op: str) -> None:
        """A transfer is about to occupy the (src, dst) channel for
        [start, end]: it must start at/after the channel's current
        busy-until stamp (channels serialize) and never move it backward."""
        self.checks += 1
        busy = self.net.channel_busy(src, dst)
        if start < busy - EPS:
            raise SanitizerError("channel-overlap", op, src=src, dst=dst,
                                 start=start, end=end, channel_busy=busy)
        if end < busy - EPS:
            raise SanitizerError("channel-backward", op, src=src, dst=dst,
                                 end=end, channel_busy=busy)

    # -- meter conservation --------------------------------------------------

    def charged(self, backend: str, nbytes: float, op: str) -> None:
        """``_charge`` just added ``nbytes`` to ``{backend}.bytes``: the
        meter must equal the shadow ledger exactly — any drift means
        something other than the transports wrote a payload meter."""
        self.checks += 1
        self._shadow_bytes[backend] = \
            self._shadow_bytes.get(backend, 0.0) + nbytes
        actual = self.net.meter.get(f"{backend}.bytes", 0)
        if abs(actual - self._shadow_bytes[backend]) > EPS:
            raise SanitizerError(
                "meter-drift", op, backend=backend, charged_now=nbytes,
                meter_bytes=actual, expected=self._shadow_bytes[backend])

    def retry_conserved(self, backend: str, before_bytes: float,
                        op: str) -> None:
        """A faulted attempt just timed out inside ``_admit``: it must
        have moved ZERO payload bytes (timeouts hold lanes, not data)."""
        self.checks += 1
        now = self.net.meter.get(f"{backend}.bytes", 0)
        if now != before_bytes:
            raise SanitizerError(
                "retry-payload", op, backend=backend,
                bytes_before=before_bytes, bytes_after=now)

    def reset_meters(self) -> None:
        """``Network.reset_meter`` cleared the counters: the shadow ledger
        follows (busy stamps were cleared with it, so lane/channel checks
        restart clean too)."""
        self._shadow_bytes.clear()

    # -- payload conservation (transport -> PagePool.write_pages) ------------

    def tag_payload(self, arr, backend: str, rows: int, nbytes: int) -> None:
        """A transport returned a page payload of ``rows`` pages /
        ``nbytes`` bytes; remember it until an adopter hands it to
        ``PagePool.write_pages``."""
        self._payloads[id(arr)] = (arr, backend, rows, nbytes)

    def adopt_payload(self, arr, rows: int, row_bytes: int, op: str) -> None:
        """``ModelInstance._adopt_pages`` is writing ``arr`` into ``rows``
        freshly allocated frames of ``row_bytes`` each: if the payload
        came off a transport, every byte the wire moved must land — no
        rows dropped, none duplicated."""
        tag = self._payloads.pop(id(arr), None)
        if tag is None:
            return                  # cache hit / local / RPC reply: untagged
        self.checks += 1
        _, backend, wire_rows, wire_bytes = tag
        if rows != wire_rows or rows * row_bytes != wire_bytes:
            raise SanitizerError(
                "payload-conservation", op, backend=backend,
                wire_rows=wire_rows, wire_bytes=wire_bytes,
                adopted_rows=rows, adopted_bytes=rows * row_bytes)

    # -- connection pools ----------------------------------------------------

    def touch_live(self, conn, manager, op: str) -> None:
        """Every use of a connection object must find it in the manager's
        live table — touching an evicted QP is use-after-free."""
        self.checks += 1
        if manager.conns.get(conn.key) is not conn:
            raise SanitizerError("evicted-conn-use", op, key=conn.key,
                                 backend=conn.backend)

    def check_conns(self, manager, op: str) -> None:
        """Full consistency scan of the connection control plane (runs
        after every state change while sanitized):

        * every live connection holds a slot in each of its nodes' pools,
          and every pool slot points back at a live connection (RC slot
          accounting balances across ``fault_pair``/eviction);
        * the user refcount index and the per-connection user sets agree
          bidirectionally (refcounts can never go "negative" — a release
          without a reference surfaces here as a dangling index entry);
        * no bounded pool exceeds ``NetModel.conn_cap``.
        """
        if self._suspended:
            return
        self.checks += 1
        cap = manager.cap
        for key, conn in manager.conns.items():
            for nid in conn.nodes:
                pool = manager.pools.get(nid)
                if pool is None or key not in pool:
                    raise SanitizerError(
                        "conn-slot-missing", op, key=key, node=nid)
            for u in conn.users:    # sim-ok: set-iter -- membership checks only, order-free
                if key not in manager._user_index.get(u, ()):
                    raise SanitizerError(
                        "refcount-unindexed", op, key=key, user=u)
        for nid, pool in manager.pools.items():
            if cap > 0 and len(pool) > cap:
                raise SanitizerError("pool-over-cap", op, node=nid,
                                     size=len(pool), cap=cap)
            for key in pool._order:
                if key not in manager.conns:
                    raise SanitizerError(
                        "conn-slot-dangling", op, key=key, node=nid)
        for user, keys in manager._user_index.items():
            for key in keys:        # sim-ok: set-iter -- membership checks only, order-free
                conn = manager.conns.get(key)
                if conn is None or user not in conn.users:
                    raise SanitizerError(
                        "refcount-dangling", op, user=user, key=key)

    # -- lease state machine -------------------------------------------------

    def lease_register(self, node_id: str, handler_id: int) -> None:
        self.checks += 1
        key = (node_id, handler_id)
        if self._leases.get(key) == "live":
            raise SanitizerError("lease-edge", "register_seed",
                                 node=node_id, handler_id=handler_id,
                                 state="live",
                                 detail="handler_id reused while live")
        self._leases[key] = "live"

    def _lease_event(self, node_id: str, handler_id: int, op: str) -> None:
        self.checks += 1
        key = (node_id, handler_id)
        state = self._leases.get(key)
        if state != "live":
            raise SanitizerError("lease-edge", op, node=node_id,
                                 handler_id=handler_id,
                                 state=state or "unregistered")

    def lease_renew(self, node_id: str, handler_id: int) -> None:
        self._lease_event(node_id, handler_id, "renew_seed")

    def lease_revoke(self, node_id: str, handler_id: int) -> None:
        self._lease_event(node_id, handler_id, "revoke_seed")

    def lease_reclaim(self, node_id: str, handler_id: int) -> None:
        """Only called for EFFECTIVE reclaims (the entry existed) — the
        public ``reclaim_seed`` stays idempotent, a second call never
        reaches this hook."""
        self._lease_event(node_id, handler_id, "reclaim_seed")
        self._leases[(node_id, handler_id)] = "reclaimed"

    def node_crashed(self, node_id: str) -> None:
        """A fail-stop kills the node's whole seed registry in one edge."""
        self.checks += 1
        for key, state in self._leases.items():
            if key[0] == node_id and state == "live":
                self._leases[key] = "reclaimed"

    def node_registered(self, node_id: str) -> None:
        """A (re-)registered node is a fresh incarnation: its parent_lost
        ledger resets, so a later loss of the NEW incarnation counts."""
        self._lost.pop(node_id, None)

    def parent_lost(self, func: str, node_id: str) -> None:
        """``parent_lost`` telemetry must fire exactly once per
        (function, node incarnation) — double counting would inflate the
        fig20/fig22 lease rows."""
        self.checks += 1
        funcs = self._lost.setdefault(node_id, set())
        if func in funcs:
            raise SanitizerError(
                "parent-lost-twice", "lease_telemetry", func=func,
                node=node_id,
                detail="parent_lost counted twice without re-registration")
        funcs.add(func)

    # -- reporting -----------------------------------------------------------

    def stats(self) -> Dict[str, int]:
        return {"checks": self.checks,
                "pending_payloads": len(self._payloads),
                "leases_tracked": len(self._leases)}
