"""Sim-time race detector: find handlers that depend on incidental ordering.

The event loop dispatches same-time events by declared ``priority`` and
then by schedule order.  Everything a replay *pins* (latency percentiles,
meters, digests) is supposed to be a function of the trace and the seed —
not of which same-``(time, priority)`` event happened to be scheduled
first.  That claim is exactly what ``EventLoop(tiebreak_seed=...)`` makes
testable: a non-None seed shuffles dispatch order *within* each
(time, priority) tie class while leaving cross-class order alone.

The detector replays the same workload once with the deterministic
tiebreak (the baseline) and N times with seeded shuffles, then compares

* the **semantic digest** — ``ReplayResult.summary()`` minus the
  ``event_log_digest`` entry (the log legitimately reorders within a tie
  class, results must not); and
* the **time-grouped event log** — for each sim time, the multiset of
  dispatched labels.  A race-free replay dispatches the *same work* at
  every instant regardless of intra-tie order; a shuffle that makes
  different events exist at some time means an earlier handler's effect
  leaked into scheduling.

On divergence the report pinpoints the first sim time whose label
multiset differs (the earliest observable symptom, usually right where
the racy handlers collided) plus which summary keys changed.

Usage::

    from repro.analysis.races import detect
    report = detect(lambda tiebreak_seed: ReplayEngine(
        trace, policy, funcs, seed=0, tiebreak_seed=tiebreak_seed))
    assert not report.racy, report.describe()

or from the command line (a fig20-style smoke replay)::

    PYTHONPATH=src python -m repro.analysis.races --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

DEFAULT_SEEDS: Tuple[int, ...] = (1, 2, 3)


@dataclasses.dataclass
class RaceReport:
    """Outcome of one detection run (baseline vs N shuffled replays)."""

    racy: bool
    baseline_digest: str                 # semantic digest of the baseline
    seeds_tried: List[int]
    # first shuffle that diverged (None when race-free):
    racy_seed: Optional[int] = None
    changed_keys: List[str] = dataclasses.field(default_factory=list)
    # earliest sim time whose dispatched-label multiset differs, with the
    # two multisets at that time — the race's first observable symptom
    first_divergence: Optional[Dict[str, Any]] = None

    def describe(self) -> str:
        if not self.racy:
            return (f"race-free across tiebreak seeds {self.seeds_tried} "
                    f"(digest {self.baseline_digest[:12]})")
        lines = [f"RACE: tiebreak seed {self.racy_seed} changed the result"]
        if self.changed_keys:
            lines.append(f"  summary keys changed: {self.changed_keys}")
        d = self.first_divergence
        if d is not None:
            lines.append(
                f"  first divergence at t={d['time']}: "
                f"baseline dispatched {d['baseline']}, "
                f"shuffled dispatched {d['shuffled']}")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# -- comparison machinery ----------------------------------------------------

def semantic_summary(summary: dict) -> dict:
    """A replay summary with the order-sensitive log digest removed: what
    must be invariant under same-(time, priority) dispatch shuffles."""
    return {k: v for k, v in summary.items() if k != "event_log_digest"}


def _semantic_digest(summary: dict) -> str:
    from repro.sim.metrics import canonical_digest
    return canonical_digest(semantic_summary(summary))


def _time_groups(log: Sequence[Tuple[float, str]]) -> List[
        Tuple[float, List[str]]]:
    """Collapse an event log to (time, sorted label multiset) groups —
    the order-insensitive view a race-free replay must preserve."""
    groups: List[Tuple[float, List[str]]] = []
    for when, label in log:
        if groups and groups[-1][0] == when:
            groups[-1][1].append(label)
        else:
            groups.append((when, [label]))
    return [(when, sorted(labels)) for when, labels in groups]


def first_log_divergence(base_log: Sequence[Tuple[float, str]],
                         other_log: Sequence[Tuple[float, str]]
                         ) -> Optional[Dict[str, Any]]:
    """Earliest sim time where the two logs dispatch different work
    (different label multisets), or None when equivalent."""
    a, b = _time_groups(base_log), _time_groups(other_log)
    for (ta, la), (tb, lb) in zip(a, b):
        if ta != tb or la != lb:
            return {"time": min(ta, tb), "baseline": la, "shuffled": lb}
    if len(a) != len(b):
        longer, which = (a, "baseline") if len(a) > len(b) else (b, "shuffled")
        t, labels = longer[min(len(a), len(b))]
        return {"time": t, "baseline": labels if which == "baseline" else [],
                "shuffled": labels if which == "shuffled" else []}
    return None


def _changed_keys(base: dict, other: dict) -> List[str]:
    keys = sorted(set(base) | set(other))
    return [k for k in keys if base.get(k) != other.get(k)]


def compare_runs(run_fn: Callable[[Optional[int]], Tuple[Sequence[tuple],
                                                         dict]],
                 seeds: Sequence[int] = DEFAULT_SEEDS) -> RaceReport:
    """Low-level API: ``run_fn(tiebreak_seed)`` performs one replay and
    returns ``(event_log, summary)``.  The baseline runs with
    ``tiebreak_seed=None`` (deterministic schedule-order ties); each seed
    runs shuffled and is compared semantically."""
    base_log, base_summary = run_fn(None)
    base_sem = semantic_summary(base_summary)
    base_digest = _semantic_digest(base_summary)
    tried: List[int] = []
    for seed in seeds:
        tried.append(seed)
        log, summary = run_fn(seed)
        sem = semantic_summary(summary)
        diverged_log = first_log_divergence(base_log, log)
        if sem != base_sem or diverged_log is not None:
            return RaceReport(
                racy=True, baseline_digest=base_digest, seeds_tried=tried,
                racy_seed=seed, changed_keys=_changed_keys(base_sem, sem),
                first_divergence=diverged_log)
    return RaceReport(racy=False, baseline_digest=base_digest,
                      seeds_tried=tried)


def detect(engine_factory: Callable[[Optional[int]], Any],
           seeds: Sequence[int] = DEFAULT_SEEDS) -> RaceReport:
    """Run the detector on replay engines.  ``engine_factory(tiebreak_seed)``
    must build a FRESH :class:`~repro.sim.engine.ReplayEngine` (same trace,
    policy and seed every call) with the given tiebreak seed."""
    def run(tiebreak_seed: Optional[int]):
        eng = engine_factory(tiebreak_seed)
        res = eng.run()
        return list(eng.loop.log), res.summary()
    return compare_runs(run, seeds=seeds)


# -- CLI ---------------------------------------------------------------------

def _smoke_factory(scale: int, n_nodes: int, seed: int):
    """A small fig20-style spike replay (the same workload the replay
    benchmark pins), parameterized by tiebreak seed."""
    from repro.sim import (ForkOnDemand, ReplayEngine, SimFunction,
                           spike_660323)
    page_elems = 1024
    fn = SimFunction("spike", state_bytes=16 * page_elems * 4,
                     touch_frac=0.05, exec_s=0.030, coldstart_s=0.167,
                     hold_s=60.0)

    def factory(tiebreak_seed: Optional[int]):
        return ReplayEngine(spike_660323(scale=scale),
                            ForkOnDemand(replicas=4, prefetch=0), [fn],
                            n_nodes=n_nodes, seed=seed,
                            page_elems=page_elems,
                            tiebreak_seed=tiebreak_seed)
    return factory


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.races",
        description="replay-shuffle race detector (fig20-style smoke trace)")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for the default small replay (CI entry)")
    ap.add_argument("--scale", type=int, default=2,
                    help="spike-trace scale factor (default 2)")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--seed", type=int, default=20260809)
    ap.add_argument("--tiebreak-seeds", type=int, nargs="+",
                    default=list(DEFAULT_SEEDS),
                    help="shuffle seeds to try (default: 1 2 3)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON")
    args = ap.parse_args(argv)
    report = detect(_smoke_factory(args.scale, args.nodes, args.seed),
                    seeds=args.tiebreak_seeds)
    if args.json:
        print(json.dumps(report.to_dict(), sort_keys=True, indent=1))
    else:
        print(report.describe())
    return 1 if report.racy else 0


if __name__ == "__main__":
    sys.exit(main())
