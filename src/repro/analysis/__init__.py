"""repro.analysis — correctness tooling for the replay stack.

Three parts (see ``docs/analysis.md``):

* :mod:`repro.analysis.lint` — the stdlib-``ast`` determinism linter
  (``python -m repro.analysis.lint src/repro``);
* :mod:`repro.analysis.simsan` — SimSan, the opt-in runtime invariant
  sanitizer (``REPRO_SIMSAN=1`` / ``Network(sanitize=True)``);
* :mod:`repro.analysis.races` — the sim-time race detector
  (``python -m repro.analysis.races --smoke``).

Only the sanitizer surface is re-exported here: ``repro.net.network``
imports it at module load, so this ``__init__`` must stay free of any
import that reaches back into ``repro.net`` / ``repro.sim`` (``lint``
and ``races`` are imported as submodules on demand).
"""
from repro.analysis.simsan import Sanitizer, SanitizerError, enabled

__all__ = ["Sanitizer", "SanitizerError", "enabled"]
