"""Container descriptor (§5.1): metadata-only capture of an instance.

The descriptor holds the page tables (not the pages!), "registers" (step
counter, RNG key, tiny recurrent states), the pytree layout, DC keys and
the ancestry chain.  msgpack-serialized; KB-sized for GB-sized instances —
the paper's orders-of-magnitude win over checkpoint files.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import msgpack
import numpy as np

from repro.core.pagetable import VMA


def _pack_default(o):
    if isinstance(o, np.ndarray):
        return {b"__nd": True, b"d": o.tobytes(), b"t": o.dtype.str,
                b"s": list(o.shape)}
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    raise TypeError(f"unserializable {type(o)}")


def _unpack_hook(o):
    if b"__nd" in o or "__nd" in o:
        d = o.get(b"d", o.get("d"))
        t = o.get(b"t", o.get("t"))
        s = o.get(b"s", o.get("s"))
        return np.frombuffer(d, np.dtype(t)).reshape(s).copy()
    return o


@dataclasses.dataclass
class Descriptor:
    arch: str                           # config name
    kind: str                           # "weights" | "kv" | "full"
    parent_node: str                    # RDMA address of the parent machine
    handler_id: int
    ancestry: List[str]                 # hop h reads from ancestry[h-1]
    leaf_paths: List[List[Any]]         # pytree paths, in leaf order
    vmas: List[dict]                    # VMA.table_dict() per leaf
    registers: Dict[str, Any]           # step, rng, inline small state
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # per-VMA route plan (repro.placement): vma name -> {"owner", "transport"}.
    # Children fetch each VMA from its routed owner over its routed fabric;
    # absent (legacy blobs) = every VMA at parent_node over the default.
    routes: Dict[str, Dict[str, Any]] = dataclasses.field(default_factory=dict)

    def to_bytes(self) -> bytes:
        return msgpack.packb(dataclasses.asdict(self), default=_pack_default,
                             use_bin_type=True)

    @classmethod
    def from_bytes(cls, data: bytes) -> "Descriptor":
        d = msgpack.unpackb(data, object_hook=_unpack_hook, raw=False,
                            strict_map_key=False)
        return cls(**d)

    def vma_objects(self) -> List[VMA]:
        return [VMA.from_table_dict(d) for d in self.vmas]

    def route_for(self, name: str) -> Dict[str, Any]:
        """The route of VMA ``name``: explicit entry, else the implicit
        single-parent default (owner = parent_node, default transport)."""
        return self.routes.get(name) or {"owner": self.parent_node,
                                         "transport": None}

    @property
    def nbytes(self) -> int:
        return len(self.to_bytes())


# ---------------------------------------------------------------------------
# pytree <-> (paths, leaves)
# ---------------------------------------------------------------------------


def flatten_with_names(tree) -> Tuple[List[str], List[List[Any]], List[Any]]:
    """Returns (names, paths, leaves). Paths are [key_or_index, ...]."""
    import jax
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names, paths, leaves = [], [], []
    for kp, leaf in flat:
        path = []
        for k in kp:
            if hasattr(k, "key"):
                path.append(k.key)
            elif hasattr(k, "idx"):
                path.append(k.idx)
            else:
                path.append(str(k))
        paths.append(path)
        names.append("/".join(str(p) for p in path))
        leaves.append(leaf)
    return names, paths, leaves


def unflatten_from_paths(paths: List[List[Any]], leaves: List[Any]):
    """Rebuild nested dict/list pytrees from paths."""
    root: Any = None

    def ensure_container(container, key, next_key):
        want_list = isinstance(next_key, int)
        if isinstance(container, dict):
            if key not in container:
                container[key] = [] if want_list else {}
            return container[key]
        assert isinstance(container, list)
        while len(container) <= key:
            container.append(None)
        if container[key] is None:
            container[key] = [] if want_list else {}
        return container[key]

    for path, leaf in zip(paths, leaves):
        if not path:                 # the whole tree is a single leaf
            return leaf
        if root is None:
            root = [] if isinstance(path[0], int) else {}
        node = root
        for i, key in enumerate(path[:-1]):
            node = ensure_container(node, key, path[i + 1])
        last = path[-1]
        if isinstance(node, list):
            while len(node) <= last:
                node.append(None)
            node[last] = leaf
        else:
            node[last] = leaf
    return root
