"""PrefetchEngine — asynchronous demand-paging lookahead (rFaaS-style).

The fault handler's synchronous prefetch widens each blocking read; this
engine instead *issues* the policy's lookahead window as background
fetches that ride the (child, owner) channel while the function keeps
executing.  The sim's channel busy-time accounting (repro.net) makes the
overlap honest: an async read occupies its channel without advancing the
clock, and the clock only waits (``Network.wait_until``) when execution
actually touches a page whose transfer has not completed yet.

One ``PrefetchEngine`` hangs off a ``ModelInstance`` when the child was
resumed with ``ForkPolicy(async_prefetch=N)``:

* ``issue(name, pages)``    — background-fetch missing pages (cache hits
  are adopted immediately; swapped/hop-0 pages are left to the sync
  fallback path; ``AccessRevoked`` aborts the issue, the sync path will
  degrade to the RPC daemon as usual).
* ``issue_ahead(name, faulted)`` — queue the next ``window`` missing
  pages beyond the highest page the current fault served.
* ``drain(name, pages)``    — adopt in-flight fetches: entries covering
  ``pages`` are waited for; unrelated entries land only if their
  transfer already completed.  ``pages=None`` waits for everything.

Pages in flight are excluded from both re-issue and the synchronous
fault path, so every page moves over the wire exactly once — async and
sync sweeps are byte-identical, only their clocks differ.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.net import AccessRevoked, TransportError


@dataclasses.dataclass
class _Pending:
    pages: np.ndarray        # VMA page indices covered by this transfer
    data: np.ndarray         # fetched page payload, (len(pages), page_elems)
    complete_at: float       # absolute sim time the transfer finishes
    owner: str               # node the pages were read from
    remote_frames: np.ndarray  # owner-pool frames (sibling-cache keys)
    dc_key: int              # the VMA's DC key at issue time (revalidated
                             # before republishing to the sibling cache)


class PrefetchEngine:
    """Issues and lands background page fetches for one ModelInstance."""

    def __init__(self, inst, window: int):
        if window < 1:
            raise ValueError(f"async prefetch window must be >= 1, got {window}")
        self.inst = inst
        self.window = window
        self._pending: Dict[str, List[_Pending]] = {}

    # -- introspection ------------------------------------------------------

    def pending_mask(self, name: str) -> np.ndarray:
        """Bool mask over the VMA's pages currently in flight."""
        vma = self.inst.aspace[name]
        mask = np.zeros(vma.npages, bool)
        for entry in self._pending.get(name, ()):
            mask[entry.pages] = True
        return mask

    def pending_count(self) -> int:
        return sum(len(e.pages) for lst in self._pending.values() for e in lst)

    # -- issue --------------------------------------------------------------

    def issue(self, name: str, pages) -> int:
        """Background-fetch the missing, not-already-pending subset of
        ``pages``.  Returns the number of pages put in flight."""
        inst = self.inst
        vma = inst.aspace[name]
        want = vma.request_mask(pages)
        want &= vma.missing_mask() & ~self.pending_mask(name)
        # hop-0 misses are swapped-out locals: inherently two-sided, leave
        # them to the synchronous fallback daemon
        want &= vma.owner_hop > 0
        plist = np.nonzero(want)[0]
        if plist.size == 0:
            return 0
        node = inst.node
        net = node.network
        issued = 0
        # _hop_groups serves sibling-cache hits inline (local copies, zero
        # wire cost) and yields only what must be read off-node
        for owner, key, sub, rframes in inst._hop_groups(vma, plist):
            try:
                data = net.read_pages(node.node_id, owner, vma.dtype,
                                      rframes, key,
                                      transport=vma.transport
                                      or inst.page_transport,
                                      async_read=True,
                                      user=inst._conn_user)
            except AccessRevoked:
                continue            # sync path will take the RPC fallback
            except TransportError:
                # owner unreachable (crash/flap/retries exhausted): leave
                # the pages missing — the sync fault path runs the full
                # recovery chain when they are actually touched
                continue
            self._pending.setdefault(name, []).append(_Pending(
                pages=sub.astype(np.int64),
                data=np.asarray(data),
                complete_at=net.channel_busy(node.node_id, owner),
                owner=owner,
                remote_frames=np.asarray(rframes),
                dc_key=key))
            issued += int(sub.size)
        inst.stats["prefetch_issued"] += issued
        return issued

    def issue_window(self, name: str) -> int:
        """Put up to the window's remaining budget of this VMA's missing
        pages in flight (lowest pages first) — the pipelined-ensure_all
        entry point; like issue_ahead it respects the TOTAL in-flight
        bound, never the whole VMA at once."""
        room = self.window - self.pending_count()
        if room <= 0:
            return 0
        vma = self.inst.aspace[name]
        ahead = np.nonzero(vma.missing_mask() & ~self.pending_mask(name))[0]
        return self.issue(name, ahead[:room])

    def issue_ahead(self, name: str, faulted) -> int:
        """Queue the next ``window`` missing pages beyond the highest page
        the current fault served — the policy's lookahead, off-clock."""
        vma = self.inst.aspace[name]
        faulted = np.atleast_1d(np.asarray(faulted, np.int64))
        if faulted.size == 0:
            return 0
        hi = int(faulted.max())
        # the window bounds TOTAL in-flight depth across VMAs, not
        # per-touch (or per-tensor) issuance
        room = self.window - self.pending_count()
        if room <= 0:
            return 0
        ahead = np.nonzero(vma.missing_mask() & ~self.pending_mask(name))[0]
        ahead = ahead[ahead > hi][:room]
        return self.issue(name, ahead)

    # -- land ---------------------------------------------------------------

    def drain(self, name: str, pages: Optional[np.ndarray] = None) -> int:
        """Adopt pending fetches for ``name``.  Entries overlapping
        ``pages`` are *needed now*: the clock waits for their completion.
        Other entries adopt free iff their transfer already finished.
        ``pages=None`` means everything is needed.  Returns pages landed."""
        lst = self._pending.get(name)
        if not lst:
            return 0
        inst = self.inst
        vma = inst.aspace[name]
        net = inst.node.network
        needed = None
        if pages is not None:
            # only still-missing requests force a wait: a COW-won page is
            # already resident, so its in-flight payload is just dropped
            needed = vma.request_mask(pages) & vma.missing_mask()
        keep, landed = [], 0
        for entry in lst:
            # a page may have been COW-written while in flight: the local
            # copy wins, and a fully-stale payload is dropped WITHOUT
            # blocking the clock — nobody needs its bytes
            still = vma.missing_mask()[entry.pages]
            if not still.any():
                inst.stats["prefetch_wasted"] += len(entry.pages)
                continue
            want_now = needed is None or bool(needed[entry.pages].any())
            if want_now:
                net.wait_until(entry.complete_at)
            elif entry.complete_at > net.sim_time:
                keep.append(entry)
                continue
            # full landings (the common case) adopt the payload buffer as
            # is — the fancy-index copy only happens when a COW raced a
            # page out of the entry
            payload = entry.data if still.all() else entry.data[still]
            local = inst._adopt_pages(vma, entry.pages[still], payload)
            # publish to the sibling cache like the sync path — but only
            # if the owner's DC target is still live.  A free/reclaim
            # between issue and drain broadcasts a cache drop; putting
            # the entry back AFTER that broadcast would let a reused
            # owner frame serve another seed's bytes.
            if net.target_valid(entry.owner, entry.dc_key):
                inst.node.page_cache_put_many(entry.owner, vma.dtype,
                                              entry.remote_frames[still],
                                              local)
            n = int(still.sum())
            landed += n
            inst.stats["prefetch_used"] += n
            inst.stats["pages_rdma"] += n       # served by the page transport
            inst.stats["prefetch_wasted"] += int((~still).sum())
        if keep:
            self._pending[name] = keep
        else:
            self._pending.pop(name, None)
        return landed

    def drain_all(self) -> int:
        return sum(self.drain(name) for name in list(self._pending))

    def discard(self) -> None:
        """Forget in-flight transfers (instance teardown)."""
        for lst in self._pending.values():
            self.inst.stats["prefetch_wasted"] += sum(
                len(e.pages) for e in lst)
        self._pending.clear()


def issue_fan_in(children) -> int:
    """Put every child's missing working set in flight as K *concurrent*
    children would: round-robin across the children, each child's
    per-owner VMA groups rotated by its index.

    The link clock (``NetModel.node_links``) reserves lanes FCFS in issue
    order, so child-major sequential issuing — child 0's entire set, then
    child 1's — would stamp one child's reads onto every parent link
    before the next child exists, serializing the whole fleet even when S
    replicas could serve in parallel.  Interleaving the issue order is
    what a real concurrent fan-out looks like to the fabric; the benchmark
    and property-test fan-ins both drive it through here.  Children must
    have a PrefetchEngine attached.  Returns total pages issued."""
    plans = []
    for i, child in enumerate(children):
        by_owner: Dict[str, list] = {}
        for name in child.leaf_names:
            vma = child.aspace[name]
            owner = vma.ancestry[0] if vma.ancestry else child.ancestry[0]
            by_owner.setdefault(owner, []).append(name)
        owners = sorted(by_owner)
        r = i % len(owners)
        plans.append((child, [by_owner[o] for o in owners[r:] + owners[:r]]))
    issued = 0
    for rnd in range(max((len(g) for _, g in plans), default=0)):
        for child, groups in plans:
            if rnd < len(groups):
                for name in groups[rnd]:
                    issued += child.prefetch_engine.issue(
                        name, np.arange(child.aspace[name].npages))
    return issued
