"""Page tables with remote/owner-hop bits — the extended PTE of §5.4/5.5.

Each tensor of an instance is one VMA.  Per page we track:
  owner_hop : 0 = local frame, h>0 = frame lives on the h-th ancestor
              (4-bit field, <= 15 hops, exactly the paper's PTE encoding)
  frame     : frame index in the owner's PagePool
  flags     : PRESENT | DIRTY
A VMA also carries its DC keys (connection-based access control, §5.4):
one key per ancestor hop, since after partial COW a VMA can mix pages owned
by several ancestors (§5.5) — plus its ROUTE (repro.placement): a per-VMA
owner chain and transport name, so one child's VMAs can page in from
different parent replicas over different fabrics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

MAX_HOPS = 15          # 4 bits in the PTE's ignored bits (paper §5.5)

F_PRESENT = 0x1        # local copy materialized
F_DIRTY = 0x2          # locally modified (COW'd)


@dataclasses.dataclass
class VMA:
    name: str
    shape: Tuple[int, ...]
    dtype: str
    npages: int
    owner_hop: np.ndarray        # (npages,) uint8
    frames: np.ndarray           # (npages,) int32, index into owner pool
    flags: np.ndarray            # (npages,) uint8
    dc_keys: Dict[int, int] = dataclasses.field(default_factory=dict)
                                 # hop -> DC key at that ancestor
    version: int = 0             # bumped on every residency/content change;
                                 # lets callers cache assembled tensors and
                                 # reassemble only when pages actually moved
    page_version: Optional[np.ndarray] = None
                                 # (npages,) int64 — the VMA version at which
                                 # each page last changed (residency or
                                 # dirty).  Lets the assembler patch exactly
                                 # the pages that moved since a cached
                                 # snapshot instead of rebuilding the tensor
    # -- route (repro.placement): per-VMA owner chain + transport ----------
    ancestry: List[str] = dataclasses.field(default_factory=list)
                                 # hop h reads from ancestry[h-1]; empty =
                                 # fall back to the instance-level chain
    transport: Optional[str] = None
                                 # page-fetch transport for THIS VMA; None =
                                 # the instance/policy default

    def __post_init__(self):
        if self.page_version is None:
            self.page_version = np.zeros(self.npages, np.int64)

    @classmethod
    def new_local(cls, name, shape, dtype, frames):
        n = len(frames)
        return cls(
            name=name, shape=tuple(shape), dtype=str(dtype), npages=n,
            owner_hop=np.zeros(n, np.uint8),
            frames=np.asarray(frames, np.int32),
            flags=np.full(n, F_PRESENT, np.uint8),
        )

    def child_view(self, parent_key: int, parent_node: Optional[str] = None,
                   default_ancestry=()) -> "VMA":
        """Fork: child's pages point one hop further up; nothing resident.

        Pages the parent owned (hop 0) become hop 1, guarded by the freshly
        assigned `parent_key`; pages the parent itself still reads from
        ancestors shift one hop up and keep their ancestors' keys.

        ``parent_node`` stamps the child VMA's own owner chain (route):
        hop 1 is the parent, deeper hops are the parent's chain (its own
        per-VMA ancestry, or ``default_ancestry`` — the descriptor's
        instance-level chain — when it has none).  The route transport is
        inherited: a VMA pinned to e.g. ``shared_fs`` stays there across
        generations until a placement policy re-routes it.
        """
        hop = self.owner_hop.astype(np.int32)
        # Pages the parent had not COW'd still belong to the same ancestor:
        # hop h>0 stays pointing at that ancestor, renumbered h+1 in the
        # child's chain. Hop-0 pages become hop 1 (the parent).
        new_hop = hop + 1
        if new_hop.max(initial=0) > MAX_HOPS:
            raise OverflowError(
                f"fork depth exceeds {MAX_HOPS} hops (paper §5.5 PTE encoding)")
        keys = {h + 1: k for h, k in self.dc_keys.items()}
        keys[1] = parent_key
        chain = []
        if parent_node is not None:
            chain = [parent_node] + list(self.ancestry or default_ancestry)
        return VMA(
            name=self.name, shape=self.shape, dtype=self.dtype,
            npages=self.npages,
            owner_hop=new_hop.astype(np.uint8),
            frames=self.frames.copy(),
            flags=np.zeros(self.npages, np.uint8),
            dc_keys=keys,
            ancestry=chain,
            transport=self.transport,
        )

    def owner_at(self, hop: int, default_ancestry=()) -> str:
        """Node id serving this VMA's pages at ``hop`` (>= 1), resolved
        against the VMA's own route chain, falling back to the instance
        chain the caller passes."""
        chain = self.ancestry or default_ancestry
        return chain[hop - 1]

    # -- queries -------------------------------------------------------------

    def resident_mask(self) -> np.ndarray:
        return (self.flags & F_PRESENT) != 0

    def missing_mask(self) -> np.ndarray:
        return (self.flags & F_PRESENT) == 0

    def missing_pages(self) -> np.ndarray:
        return np.nonzero(self.missing_mask())[0].astype(np.int32)

    def request_mask(self, pages) -> np.ndarray:
        """Bool mask over this VMA's pages for a requested page list:
        out-of-range indices are silently dropped.  The one clipping/
        validation site for the fault path and the prefetch engine."""
        mask = np.zeros(self.npages, bool)
        req = np.atleast_1d(np.asarray(pages, np.int64)).ravel()
        mask[req[(req >= 0) & (req < self.npages)]] = True
        return mask

    def want_mask(self, pages, prefetch: int = 0) -> np.ndarray:
        """Bool mask of missing pages a fault on ``pages`` should fetch:
        the missing requested pages, plus up to ``prefetch`` pages of
        lookahead window behind each missing requested page.

        Pure numpy mask ops — the prefetch window is an interval union
        built with a difference array (one cumsum), so cost is
        O(npages + len(pages)) regardless of the window size, not the
        quadratic per-page expansion loop this replaces."""
        miss = self.missing_mask()
        want = self.request_mask(pages) & miss
        if prefetch > 0:
            # windows extend only behind *missing* requested pages — a
            # resident touch is not a fault and must not trigger pulls
            faulted = np.nonzero(want)[0]
            if faulted.size:
                diff = np.zeros(self.npages + 1, np.int32)
                starts = np.minimum(faulted + 1, self.npages)
                ends = np.minimum(faulted + 1 + prefetch, self.npages)
                np.add.at(diff, starts, 1)
                np.add.at(diff, ends, -1)
                window = np.cumsum(diff[:-1]) > 0
                want |= window & miss
        return want

    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize

    # -- updates -------------------------------------------------------------

    def mark_resident(self, pages, local_frames):
        self.owner_hop[pages] = 0
        self.frames[pages] = local_frames
        self.flags[pages] |= F_PRESENT
        self.version += 1
        self.page_version[pages] = self.version

    def mark_dirty(self, pages):
        self.flags[pages] |= F_DIRTY
        self.version += 1
        self.page_version[pages] = self.version

    def changed_since(self, version: int) -> np.ndarray:
        """Pages whose residency/content changed after VMA version
        ``version`` — the incremental-reassembly work list."""
        return np.nonzero(self.page_version > version)[0].astype(np.int32)

    def table_dict(self) -> dict:
        return {
            "name": self.name, "shape": list(self.shape), "dtype": self.dtype,
            "npages": self.npages,
            "owner_hop": self.owner_hop.tobytes(),
            "frames": self.frames.tobytes(),
            "dc_keys": {int(h): int(k) for h, k in self.dc_keys.items()},
            "ancestry": list(self.ancestry),
            "transport": self.transport,
        }

    @classmethod
    def from_table_dict(cls, d) -> "VMA":
        n = d["npages"]
        return cls(
            name=d["name"], shape=tuple(d["shape"]), dtype=d["dtype"], npages=n,
            owner_hop=np.frombuffer(d["owner_hop"], np.uint8).copy(),
            frames=np.frombuffer(d["frames"], np.int32).copy(),
            flags=np.zeros(n, np.uint8),
            dc_keys={int(h): int(k) for h, k in d["dc_keys"].items()},
            ancestry=list(d.get("ancestry") or []),
            transport=d.get("transport"),
        )


AddressSpace = Dict[str, VMA]
