"""Compatibility re-export — the data plane lives in :mod:`repro.net`.

The monolithic Network (hardwired ``dct``/``rc`` flags, bespoke
``rdma_read_pages``/``rdma_read_blob``/``rpc`` methods) was redesigned into
the pluggable transport package: a :class:`repro.net.Transport` interface
behind a name-keyed registry, with :class:`repro.net.Network` as a thin
router.  Import from ``repro.net`` in new code; this module only keeps the
old import path alive for one release (same warn-then-delete cycle the
``repro.core.fork`` tuple shims went through — CI's DeprecationWarning-as-
error job proves no in-repo code still imports it).
"""
import warnings

from repro.net import (AccessRevoked, LeaseExpired, NetModel, Network,
                       Transport, register_transport, resolve_transport,
                       transport_names)

warnings.warn(
    "repro.core.network is deprecated; import from repro.net instead "
    "(see docs/transport.md)", DeprecationWarning, stacklevel=2)

__all__ = [
    "AccessRevoked",
    "LeaseExpired",
    "NetModel",
    "Network",
    "Transport",
    "register_transport",
    "resolve_transport",
    "transport_names",
]
