"""Cluster network: DCT-style connection pool, connection-based access
control, one-sided reads, RPC — with byte metering and an RDMA/ICI latency
model for derived benchmark columns (§5.3, §5.4).

"One-sided read" here is a real device gather out of the owner pool's frames
array — the reading node's CPU-side logic never calls into the owner's
instance code, mirroring CPU-bypass.  Access control is enforced exactly as
in the paper: the read is admitted iff the (node, dc_key) pair is a live DC
target; revoking the target kills all remote access to that VMA.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np


class AccessRevoked(PermissionError):
    """One-sided access rejected: the DC target is gone or the handle's
    generation was revoked at the parent (§5.2 connection-based control)."""


class LeaseExpired(PermissionError):
    """The seed's lease ran out before the child authenticated — the parent
    refuses resume, mirroring rFaaS-style leased capabilities."""


@dataclasses.dataclass
class NetModel:
    """Latency/bandwidth constants (defaults ~ConnectX-4 100Gb/s, paper §7)."""
    rdma_lat: float = 2e-6          # one-sided READ latency
    rdma_bw: float = 12.5e9         # 100 Gb/s
    rpc_lat: float = 8e-6           # two-sided RPC round trip
    rc_setup: float = 4e-3          # RC QP connect (paper: 4 ms)
    dct_setup: float = 1e-6         # DCT: piggybacked, <1 us
    dfs_lat: float = 100e-6         # distributed-FS request (CRIU-remote)
    disk_bw: float = 2e9            # checkpoint "disk" (tmpfs-ish)
    ici_bw: float = 50e9            # TPU ICI per link (for TPU-mode derivations)


class Network:
    def __init__(self, model: Optional[NetModel] = None, transport: str = "dct"):
        assert transport in ("dct", "rc")
        self.model = model or NetModel()
        self.transport = transport
        self.nodes: Dict[str, "object"] = {}
        self.meter = Counter()
        self.sim_time = 0.0
        self._connections = set()           # (src, dst) with a live QP
        # DC targets: (node_id, dc_key) -> True while valid
        self._dc_targets: Dict[tuple, bool] = {}
        self._next_key = 1

    # -- membership -----------------------------------------------------------

    def register(self, node) -> None:
        self.nodes[node.node_id] = node

    def unregister(self, node_id: str) -> None:
        self.nodes.pop(node_id, None)
        for k in [k for k in self._dc_targets if k[0] == node_id]:
            del self._dc_targets[k]

    # -- DC targets (access control) -------------------------------------------

    def create_dc_target(self, node_id: str) -> int:
        """Allocate a DC key guarding one VMA (paper: 12 B child-side)."""
        key = self._next_key
        self._next_key += 1
        self._dc_targets[(node_id, key)] = True
        self.meter["dc_targets"] += 1
        return key

    def destroy_dc_target(self, node_id: str, key: int) -> None:
        self._dc_targets.pop((node_id, key), None)

    def target_valid(self, node_id: str, key: int) -> bool:
        return self._dc_targets.get((node_id, key), False)

    # -- connections ------------------------------------------------------------

    def _connect(self, src: str, dst: str) -> None:
        if (src, dst) in self._connections:
            return
        self._connections.add((src, dst))
        self.meter["conn_setups"] += 1
        self.sim_time += (self.model.dct_setup if self.transport == "dct"
                          else self.model.rc_setup)

    # -- data plane ---------------------------------------------------------------

    def rdma_read_pages(self, src: str, dst: str, dtype, frames, dc_key: int):
        """One-sided READ of `frames` from dst's pool. Returns (n, page_elems)."""
        if dst not in self.nodes:
            raise ConnectionError(f"node {dst} is down")
        if not self.target_valid(dst, dc_key):
            raise AccessRevoked(f"DC target {dc_key}@{dst} destroyed")
        self._connect(src, dst)
        pool = self.nodes[dst].pool
        pages = pool.read_pages(dtype, frames)
        nbytes = pages.size * pages.dtype.itemsize
        self.meter["rdma_bytes"] += nbytes
        self.meter["rdma_ops"] += 1
        self.sim_time += self.model.rdma_lat + nbytes / self.model.rdma_bw
        return pages

    def rdma_read_blob(self, src: str, dst: str, nbytes: int) -> None:
        """Metered one-sided read of an opaque blob (descriptor fetch)."""
        if dst not in self.nodes:
            raise ConnectionError(f"node {dst} is down")
        self._connect(src, dst)
        self.meter["rdma_bytes"] += nbytes
        self.meter["rdma_ops"] += 1
        self.sim_time += self.model.rdma_lat + nbytes / self.model.rdma_bw

    def rpc(self, src: str, dst: str, nbytes: int, fn, *args, **kwargs):
        """Two-sided RPC executed by the destination node (FaSST-style)."""
        if dst not in self.nodes:
            raise ConnectionError(f"node {dst} is down")
        self.meter["rpc_bytes"] += nbytes
        self.meter["rpc_ops"] += 1
        self.sim_time += self.model.rpc_lat + nbytes / self.model.rdma_bw
        return fn(*args, **kwargs)

    # -- reporting -----------------------------------------------------------------

    def snapshot(self) -> dict:
        return dict(self.meter) | {"sim_time": self.sim_time}

    def reset_meter(self) -> None:
        self.meter.clear()
        self.sim_time = 0.0
