"""The remote-fork primitive: fork_prepare / fork_resume / fork_reclaim
(paper Figure 7 API).

fork_prepare : build the KB-sized descriptor (page tables + registers, NO
               memory copy), assign one DC key per VMA from the pooled
               targets, register under (handler_id, auth_key).
fork_resume  : authentication RPC -> one-sided descriptor fetch ->
               child page tables via child_view -> (optionally) on-demand
               lazy paging thereafter.
fork_reclaim : destroy the seed's DC targets; subsequent child reads are
               rejected by the RNIC-analogue and surface as AccessRevoked.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

from repro.core.descriptor import Descriptor
from repro.core.instance import ModelInstance
from repro.core.pagetable import VMA
from repro.platform.node import NodeRuntime, SeedEntry, make_auth_key


def fork_prepare(node: NodeRuntime, instance: ModelInstance) -> Tuple[int, int]:
    handler_id = next(node._hid)
    auth_key = make_auth_key()
    prepared_keys = {name: node.take_dc_target() for name in instance.aspace}
    desc = Descriptor(
        arch=instance.arch,
        kind=instance.kind,
        parent_node=node.node_id,
        handler_id=handler_id,
        ancestry=list(instance.ancestry),
        leaf_paths=instance.leaf_paths,
        vmas=[v.table_dict() for v in instance.aspace.values()],
        registers=dict(instance.registers),
        extra={"prepared_keys": prepared_keys,
               "leaf_names": list(instance.leaf_names)},
    )
    blob = desc.to_bytes()
    node.register_seed(handler_id, SeedEntry(
        descriptor=desc, blob=blob, auth_key=auth_key, instance=instance,
        keys=prepared_keys, created=node.clock()))
    return handler_id, auth_key


def fork_resume(child_node: NodeRuntime, parent_node_id: str, handler_id: int,
                auth_key: int, *, lazy: bool = True, prefetch: int = 0,
                descriptor_fetch: str = "rdma") -> ModelInstance:
    net = child_node.network
    if parent_node_id not in net.nodes:
        raise ConnectionError(f"parent {parent_node_id} is down")
    parent = net.nodes[parent_node_id]

    # 1) authentication RPC (malformed ids/keys rejected here, §5.2)
    info = net.rpc(child_node.node_id, parent_node_id, 64,
                   parent.auth_seed, handler_id, auth_key)

    # 2) descriptor fetch: one one-sided READ (fast path) or RPC (ablation)
    if descriptor_fetch == "rdma":
        net.rdma_read_blob(child_node.node_id, parent_node_id, info["nbytes"])
        blob = parent.seed_blob(handler_id)
    else:
        blob = net.rpc(child_node.node_id, parent_node_id, info["nbytes"],
                       parent.seed_blob, handler_id)
    desc = Descriptor.from_bytes(blob)

    # 3) child address space: page tables shifted one hop up
    prepared = desc.extra["prepared_keys"]
    aspace = {}
    for vd in desc.vmas:
        vma = VMA.from_table_dict(vd)
        aspace[vma.name] = vma.child_view(prepared[vma.name])
    ancestry = [parent_node_id] + list(desc.ancestry)

    inst = ModelInstance(child_node, desc.arch, desc.kind, aspace,
                         desc.leaf_paths, desc.extra["leaf_names"],
                         ancestry, dict(desc.registers))
    if not lazy:
        inst.ensure_all(prefetch=0)
    inst.default_prefetch = prefetch
    return inst


def fork_reclaim(node: NodeRuntime, handler_id: int,
                 free_instance: bool = False) -> None:
    entry = node.seeds.pop(handler_id, None)
    if entry is None:
        return
    for key in entry.keys.values():
        node.network.destroy_dc_target(node.node_id, key)
    if free_instance and entry.instance is not None:
        entry.instance.free()
