"""DEPRECATED tuple-based fork API — thin shims over ``repro.fork``.

The paper-Figure-7 primitives used to live here, exposing seeds as raw
``(handler_id, auth_key)`` int tuples.  The control plane is now the
capability-style ``repro.fork`` package:

    handle = node.prepare_fork(instance, lease=...)   # ForkHandle
    child  = handle.resume_on(child_node, ForkPolicy(lazy=True, prefetch=1))
    handle.reclaim()                                  # or `with handle: ...`

These shims delegate to the ForkHandle path (identical wire behavior and
page-fault stats) and emit DeprecationWarning; they will be removed one
release after the migration (see docs/fork_api.md for the mapping).
"""
from __future__ import annotations

import math
import warnings
from typing import Tuple

from repro.fork.handle import ForkHandle
from repro.fork.policy import ForkPolicy
from repro.core.instance import ModelInstance
from repro.platform.node import NodeRuntime


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use {new} (see docs/fork_api.md)",
                  DeprecationWarning, stacklevel=3)


def fork_prepare(node: NodeRuntime, instance: ModelInstance) -> Tuple[int, int]:
    """Deprecated: use ``node.prepare_fork(instance, lease=...)``."""
    _deprecated("fork_prepare", "NodeRuntime.prepare_fork")
    handle = node.prepare_fork(instance)
    return handle.handler_id, handle.auth_key


def fork_resume(child_node: NodeRuntime, parent_node_id: str, handler_id: int,
                auth_key: int, *, lazy: bool = True, prefetch: int = 0,
                descriptor_fetch: str = "rdma") -> ModelInstance:
    """Deprecated: use ``ForkHandle.resume_on(child_node, ForkPolicy(...))``."""
    _deprecated("fork_resume", "ForkHandle.resume_on")
    handle = ForkHandle(parent_node=parent_node_id, handler_id=handler_id,
                        auth_key=auth_key, lease_deadline=math.inf,
                        generation=0)
    return handle.resume_on(child_node, ForkPolicy(
        lazy=lazy, prefetch=prefetch, descriptor_fetch=descriptor_fetch))


def fork_reclaim(node: NodeRuntime, handler_id: int,
                 free_instance: bool = False) -> None:
    """Deprecated: use ``ForkHandle.reclaim()`` / ``NodeRuntime.reclaim_seed``."""
    _deprecated("fork_reclaim", "ForkHandle.reclaim")
    node.reclaim_seed(handler_id, free_instance=free_instance)
