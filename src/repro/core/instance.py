"""ModelInstance — the "container" of MITOSIS-JAX.

An instance's state (weights / KV pages / optimizer state) lives in its
node's PagePool behind per-tensor VMAs.  Children created by fork hold page
tables pointing at ancestor frames; the *fault handler* (`fetch_pages`)
materializes pages on demand over one-sided reads, with prefetch, sibling
page caching (MITOSIS+cache) and RPC fallback; writes are copy-on-write.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import descriptor as desc_mod
from repro.core.pagetable import F_DIRTY, F_PRESENT, VMA, AddressSpace
from repro.core.prefetch import PrefetchEngine
from repro.kernels.cow_scatter import ops as cow_ops
from repro.memory import paging
from repro.net import AccessRevoked, RecoveryFailed, TransportError


class ModelInstance:
    def __init__(self, node, arch: str, kind: str, aspace: AddressSpace,
                 leaf_paths: List[List[Any]], leaf_names: List[str],
                 ancestry: List[str], registers: Dict[str, Any]):
        self.node = node
        self.arch = arch
        self.kind = kind
        self.aspace = aspace
        self.leaf_paths = leaf_paths
        self.leaf_names = leaf_names
        self.ancestry = ancestry            # hop h -> ancestry[h-1]
        self.registers = registers
        self._tensors: Dict[str, jax.Array] = {}
        # VMA.version at which each cached tensor was assembled: assembly
        # re-runs only on actual residency/content change, not on every
        # cache invalidation
        self._tensor_versions: Dict[str, int] = {}
        self._owned_frames: Dict[str, list] = {}
        self.instance_id = node.new_instance_id()
        # connection-pool identity: reads take a refcount on their
        # (src, dst) connection under this name, so siblings landed on
        # one node share a warm slot and free() releases exactly ours
        self._conn_user = f"{node.node_id}/{self.instance_id}"
        # page-fetch transport name (repro.net registry); None = the
        # network's default backend.  Set from ForkPolicy.page_fetch; a
        # routed VMA's own `VMA.transport` takes precedence per VMA.
        self.page_transport: Optional[str] = None
        # ForkPolicy.prefetch: pages pulled per fault when the caller
        # doesn't pass an explicit prefetch
        self.default_prefetch = 0
        # ForkPolicy.async_prefetch: background lookahead engine (None = off)
        self.prefetch_engine: Optional[PrefetchEngine] = None
        # repro.placement.Router: dynamic hot-spot re-routing, attached by
        # the sharded resume when ForkPolicy.reroute_backlog is set (None =
        # static routes).  Consulted by _hop_groups before hop-1 reads.
        self.router = None
        # coordinator recovery hook: called as hook(inst, vma, lost_owner)
        # when a remote read fails past transport retries AND the Router
        # (if any) could not move the plan to a live sibling.  Returns
        # True after re-stamping the VMA's missing pages from a fresh
        # (possibly re-replicated) seed so the fetch can be retried.
        self.recover_owner = None
        # True once this instance's frame table traveled in a descriptor
        # (prepare_fork): only then can other nodes hold cache entries
        # keyed on our frames, so only then must free() broadcast
        self.frames_published = False
        # stats keys are historical: "pages_rdma" counts pages served by the
        # (possibly two-sided) page transport, "pages_rpc" the fallback daemon
        self.stats = {"faults": 0, "pages_rdma": 0, "pages_rpc": 0,
                      "pages_cached": 0, "pages_local": 0, "cow_pages": 0,
                      "prefetch_issued": 0, "prefetch_used": 0,
                      "prefetch_wasted": 0,
                      "assemble_full": 0, "assemble_patch_pages": 0}
        node.instances[self.instance_id] = self

    # ------------------------------------------------------------------
    # construction from a concrete pytree (the "running container")
    # ------------------------------------------------------------------

    @classmethod
    def create(cls, node, arch: str, pytree, kind: str = "weights",
               registers: Optional[dict] = None):
        names, paths, leaves = desc_mod.flatten_with_names(pytree)
        inst = cls(node, arch, kind, {}, paths, names, [], registers or {"step": 0})
        for name, leaf in zip(names, leaves):
            # host leaves stay host-side: the pool is host memory and a
            # device round trip per leaf would dominate container boot at
            # replay scale; ensure_tensor materializes on demand
            if not isinstance(leaf, np.ndarray):
                leaf = jnp.asarray(leaf)
            pages = paging.to_pages(leaf, node.pool.page_elems)
            frames = node.pool.alloc(leaf.dtype, pages.shape[0])
            node.pool.write_pages(leaf.dtype, frames, pages)
            inst._owned_frames.setdefault(jnp.dtype(leaf.dtype).name, []).extend(
                frames.tolist())
            inst.aspace[name] = VMA.new_local(name, leaf.shape, leaf.dtype, frames)
            inst._tensors[name] = leaf
            inst._tensor_versions[name] = inst.aspace[name].version
        return inst

    # ------------------------------------------------------------------
    # the fault handler (§5.4 Table 2)
    # ------------------------------------------------------------------

    def fetch_pages(self, name: str, pages: np.ndarray,
                    prefetch: Optional[int] = None) -> None:
        """Materialize the given (missing) pages of a VMA, plus `prefetch`
        adjacent pages per fault — the RDMA-aware page-fault handler.
        ``prefetch=None`` falls back to the policy's ``default_prefetch``.

        The whole fault is vectorized: page selection and the prefetch
        window are numpy mask ops (``VMA.want_mask``), cache probes are one
        batched call, and each by-hop group goes to the transport as ONE
        gather whose contiguous frame runs ride a doorbell-batched op.
        With an async engine attached, in-flight lookahead is landed first
        and a fresh window is issued behind the fault."""
        if prefetch is None:
            prefetch = self.default_prefetch
        vma = self.aspace[name]
        pages = np.atleast_1d(np.asarray(pages))
        engine = self.prefetch_engine
        if engine is not None:
            engine.drain(name, pages)   # land lookahead; wait only if needed
        want_mask = vma.want_mask(pages, prefetch)
        if engine is not None:
            want_mask &= ~engine.pending_mask(name)   # in flight: never refetch
        want = np.nonzero(want_mask)[0]
        if want.size == 0:
            if engine is not None:
                # readahead cursor: keep the window full past the touch
                # point even when the touch itself was served from flight
                engine.issue_ahead(name, pages)
            return
        self.stats["faults"] += 1
        self._fetch_now(vma, want)
        if engine is not None:
            engine.issue_ahead(name, want)

    def _hop_groups(self, vma: VMA, want: np.ndarray):
        """Group ``want`` pages by owner hop and serve sibling-cache hits;
        yields (owner, dc_key, pages, remote_frames) for what is left to
        read off-node.  Hop-0 entries (swapped-out locals) are served via
        the fallback daemon here.  Shared by the synchronous fault path
        and the async PrefetchEngine so probe/adopt semantics can't drift.

        Owners resolve per VMA: a routed VMA (sharded seed / placement
        plan) carries its own ancestry chain; unrouted VMAs fall back to
        the instance-level chain."""
        hops = vma.owner_hop[want]
        for hop in np.unique(hops):
            plist = want[hops == hop]
            if hop == 0:
                # local frames that lost PRESENT (swapped out): fallback path
                self._fallback_fetch(vma, self.node.node_id, plist)
                continue
            if hop == 1 and self.router is not None:
                # hot-spot (or lost-owner) re-routing: the Router may move
                # this VMA's plan to a cooler sibling replica and re-stamp
                # its frames/key/ancestry before we resolve the owner
                self.router.sync(vma)
            owner = vma.owner_at(int(hop), self.ancestry)
            key = vma.dc_keys.get(int(hop), -1)
            remote_frames = vma.frames[plist]

            # sibling page cache (MITOSIS+cache): hits are COPIED into frames
            # this instance owns — sharing the fetcher's frames would leave
            # our page table dangling once the fetcher frees them
            cached = self.node.page_cache_get_many(owner, vma.dtype,
                                                   remote_frames)
            hit = cached >= 0
            if hit.any():
                data = self.node.pool.read_pages_host(vma.dtype, cached[hit])
                self._adopt_pages(vma, plist[hit], data)
                self.stats["pages_cached"] += int(hit.sum())

            plist, remote_frames = plist[~hit], remote_frames[~hit]
            if plist.size:
                yield owner, key, plist, remote_frames

    def _fetch_now(self, vma: VMA, want: np.ndarray) -> None:
        """Synchronously materialize ``want`` (missing) pages, grouped by
        owner hop, with batched cache probes and run-coalesced reads."""
        for owner, key, plist, remote_frames in self._hop_groups(vma, want):
            self._read_group(vma, owner, key, plist, remote_frames)

    def _read_group(self, vma: VMA, owner: str, key: int, plist,
                    remote_frames, depth: int = 0) -> None:
        """One grouped remote read, with the §6.2-style failure ladder:
        revoked access degrades to the owner's RPC daemon; a transport
        failure (owner crashed, NIC flapped, retries exhausted) enters the
        recovery chain (sibling replica -> coordinator re-seed -> typed
        :class:`RecoveryFailed` that callers degrade to a coldstart)."""
        net = self.node.network
        try:
            data = net.read_pages(
                self.node.node_id, owner, vma.dtype, remote_frames, key,
                transport=vma.transport or self.page_transport,
                user=self._conn_user)
            self.stats["pages_rdma"] += int(plist.size)
        except AccessRevoked:
            # VA->PA changed at the owner (swap, reclaim): RPC fallback —
            # which itself rides the fabric, so its failure recovers too
            try:
                self._fallback_fetch(vma, owner, plist)
            except TransportError as err:
                self._recover_group(vma, owner, plist, err, depth)
            return
        except TransportError as err:
            self._recover_group(vma, owner, plist, err, depth)
            return
        local = self._adopt_pages(vma, plist, data)
        self.node.page_cache_put_many(owner, vma.dtype, remote_frames,
                                      local)

    def _recover_group(self, vma: VMA, owner: str, plist, err: Exception,
                       depth: int) -> None:
        """Recover ``plist`` after ``owner`` became unreachable.  Each rung
        re-resolves owners and re-reads only the still-missing subset, so a
        half-materialized retry adopts every page at most once (no
        double-charged pagetable, no COW corruption — dirty pages are
        resident and never re-stamped)."""
        net = self.node.network
        if depth >= 2:
            raise RecoveryFailed(
                f"recovery exhausted for {int(np.size(plist))} page(s) of "
                f"{vma.name} owned by {owner}") from err
        if owner not in net.nodes:
            # fail-stop owner: its frame namespace is gone — local cache
            # entries keyed on it must never serve a future probe
            self.node.page_cache_drop_owner(owner)
        if depth == 0 and self.router is not None:
            before = vma.ancestry[0] if vma.ancestry else None
            self.router.sync(vma)
            now = vma.ancestry[0] if vma.ancestry else None
            if now is not None and now != before and now != owner:
                # rung 1: the Router re-stamped the plan onto a live
                # sibling replica (lost-owner re-route from PR 5)
                net.meter["recovery.sibling"] += 1
                self._refetch(vma, plist, depth + 1)
                return
        hook = self.recover_owner
        if hook is not None and hook(self, vma, owner):
            # rung 2: the coordinator re-stamped us from a fresh (possibly
            # just re-replicated) seed
            net.meter["recovery.reseed"] += 1
            self._refetch(vma, plist, depth + 1)
            return
        raise RecoveryFailed(
            f"no recovery path for {int(np.size(plist))} page(s) of "
            f"{vma.name} owned by {owner}") from err

    def _refetch(self, vma: VMA, plist, depth: int) -> None:
        """Re-issue the still-missing subset of a failed group through the
        normal grouped path (owners/keys re-resolved from the re-stamped
        page table); the recovered bytes are metered separately."""
        plist = np.atleast_1d(np.asarray(plist))
        still = plist[vma.missing_mask()[plist]]
        if still.size == 0:
            return
        net = self.node.network
        net.meter["recovery.pages"] += int(still.size)
        net.meter["recovery.bytes"] += (int(still.size)
                                        * self.node.pool.page_elems
                                        * np.dtype(vma.dtype).itemsize)
        for owner, key, sub, rframes in self._hop_groups(vma, still):
            self._read_group(vma, owner, key, sub, rframes, depth)

    def _fallback_fetch(self, vma: VMA, owner: str, plist) -> None:
        # the fallback daemon is inherently two-sided: always the rpc backend
        net = self.node.network
        target = net.require_node(owner)    # typed NodeDown if it crashed
        frames = vma.frames[plist]
        data = net.rpc(self.node.node_id, owner,
                       len(frames) * self.node.pool.page_elems
                       * np.dtype(vma.dtype).itemsize,
                       target.fallback_serve, vma.dtype, frames,
                       transport="rpc")
        net.meter["page_pages_moved"] += len(frames)
        self._adopt_pages(vma, plist, data)
        self.stats["pages_rpc"] += len(frames)

    # ------------------------------------------------------------------
    # tensor-level API
    # ------------------------------------------------------------------

    def touch_pages(self, name: str, pages,
                    prefetch: Optional[int] = None) -> None:
        self.fetch_pages(name, np.asarray(pages), prefetch)

    def ensure_tensor(self, name: str,
                      prefetch: Optional[int] = None) -> jax.Array:
        vma = self.aspace[name]
        t = self._tensors.get(name)
        v0 = self._tensor_versions.get(name)
        if t is not None and v0 == vma.version:
            # the version gate: residency/content unchanged since assembly
            # (e.g. only disjoint VMAs faulted) — skip the full-pool gather
            return t
        if self.prefetch_engine is not None:
            self.prefetch_engine.drain(name)    # full assembly needs them all
        miss = vma.missing_pages()
        if miss.size:
            self.fetch_pages(name, miss, prefetch)
        pool = self.node.pool
        changed = vma.changed_since(v0) if (t is not None and
                                            v0 is not None) else None
        if changed is not None and changed.size * 2 <= vma.npages:
            # incremental reassembly: a version bump stamps exactly the
            # pages that moved (VMA.page_version), so patch those into the
            # cached tensor instead of re-gathering the whole VMA
            rows = pool.read_pages(vma.dtype, vma.frames[changed])
            t = cow_ops.scatter_patch(t, changed, rows,
                                      page_elems=pool.page_elems)
            self.stats["assemble_patch_pages"] += int(changed.size)
        else:
            # fused gather->reassemble: pages land directly in the
            # destination layout, no intermediate page-list concatenate
            t = pool.assemble(vma.dtype, vma.frames, vma.shape)
            self.stats["assemble_full"] += 1
        self._tensors[name] = t
        self._tensor_versions[name] = vma.version
        return t

    def ensure_all(self, prefetch: Optional[int] = None) -> None:
        """Materialize every tensor.  With an async engine attached this
        pipelines: while tensor i assembles, tensor i+1's pages are already
        in flight on the channel (the §6.2-style overlap of descriptor/page
        pulls with execution)."""
        engine = self.prefetch_engine
        if engine is None:
            for name in self.leaf_names:
                self.ensure_tensor(name, prefetch)
            return
        names = list(self.leaf_names)
        if names:
            engine.issue_window(names[0])
        for i, name in enumerate(names):
            if i + 1 < len(names):
                engine.issue_window(names[i + 1])
            self.ensure_tensor(name, prefetch)

    def materialize_pytree(self):
        self.ensure_all()       # pipelined when an async engine is attached
        leaves = [self.ensure_tensor(n) for n in self.leaf_names]
        return desc_mod.unflatten_from_paths(self.leaf_paths, leaves)

    def _adopt_pages(self, vma: VMA, pages, data) -> np.ndarray:
        """Copy ``data`` into freshly allocated local frames this instance
        OWNS (recorded for free-time invalidation) and mark ``pages``
        resident there.  The single ownership-bookkeeping site for every
        materialization path (transport fetch, cache hit, fallback, COW)."""
        san = self.node.network.sanitizer
        if san is not None:
            san.adopt_payload(
                data, rows=len(pages),
                row_bytes=self.node.pool.page_elems
                * np.dtype(vma.dtype).itemsize,
                op=f"adopt {vma.name}@{self.node.node_id}")
        local = self.node.pool.alloc(vma.dtype, len(pages))
        self.node.pool.write_pages(vma.dtype, local, data)
        self._owned_frames.setdefault(vma.dtype, []).extend(local.tolist())
        vma.mark_resident(pages, local)
        return local

    def write_pages(self, name: str, pages, data) -> None:
        """COW write: dirty pages land in freshly allocated local frames;
        ancestor frames are never touched."""
        vma = self.aspace[name]
        pages = np.atleast_1d(np.asarray(pages))
        self._adopt_pages(vma, pages, data)
        vma.mark_dirty(pages)
        self.stats["cow_pages"] += len(pages)

    def add_tensor(self, name: str, arr) -> None:
        """Pre-materialize new state into the instance (workflow globals,
        KV pages): creates a fresh local VMA — what downstream forks read."""
        arr = jnp.asarray(arr)
        pages = paging.to_pages(arr, self.node.pool.page_elems)
        frames = self.node.pool.alloc(arr.dtype, pages.shape[0])
        self.node.pool.write_pages(arr.dtype, frames, pages)
        dt = jnp.dtype(arr.dtype).name
        self._owned_frames.setdefault(dt, []).extend(frames.tolist())
        self.aspace[name] = VMA.new_local(name, arr.shape, arr.dtype, frames)
        if name not in self.leaf_names:
            self.leaf_names.append(name)
            self.leaf_paths.append([name])
        self._tensors[name] = arr
        self._tensor_versions[name] = self.aspace[name].version

    def write_tensor(self, name: str, arr) -> None:
        arr = jnp.asarray(arr)
        vma = self.aspace[name]
        assert tuple(arr.shape) == vma.shape, (arr.shape, vma.shape)
        pages = paging.to_pages(arr, self.node.pool.page_elems)
        self.write_pages(name, np.arange(vma.npages), pages)
        self._tensors[name] = arr
        self._tensor_versions[name] = vma.version

    # ------------------------------------------------------------------
    # accounting / lifecycle
    # ------------------------------------------------------------------

    def total_bytes(self) -> int:
        return sum(v.nbytes() for v in self.aspace.values())

    def resident_bytes(self) -> int:
        pe = self.node.pool.page_elems
        tot = 0
        for v in self.aspace.values():
            tot += int(v.resident_mask().sum()) * pe * np.dtype(v.dtype).itemsize
        return tot

    def resident_fraction(self) -> float:
        npages = sum(v.npages for v in self.aspace.values())
        res = sum(int(v.resident_mask().sum()) for v in self.aspace.values())
        return res / max(npages, 1)

    def free(self) -> None:
        if self.prefetch_engine is not None:
            self.prefetch_engine.discard()
            self.prefetch_engine = None
        for dt, frames in self._owned_frames.items():
            self.node.page_cache_invalidate_frames(dt, frames)
            if self.frames_published:
                self.node.network.drop_cached_frames(self.node.node_id, dt,
                                                     frames)
            self.node.pool.free(dt, frames)
        self._owned_frames.clear()
        self._tensors.clear()
        self._tensor_versions.clear()
        self.aspace = {}
        # drop our connection refcounts: shared slots stay warm for
        # surviving siblings but become LRU-evictable once unreferenced
        self.node.network.conn_release_user(self._conn_user)
        self.node.instances.pop(self.instance_id, None)
