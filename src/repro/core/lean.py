"""Generalized lean containers (§5.2) -> LeanExecutorPool.

On TPU the analogue of containerization cost is XLA compilation.  The pool
pre-builds ("pools") jitted executables per (arch, entrypoint, shape)
signature, so a fork_resume can skip straight to execution — exactly how
SOCK's pooled lean containers let MITOSIS skip cgroup/namespace setup.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple


class LeanExecutorPool:
    def __init__(self):
        self._cache: Dict[tuple, Callable] = {}
        self.hits = 0
        self.misses = 0
        self.build_time = 0.0

    def get(self, key: tuple, builder: Callable[[], Callable]) -> Callable:
        if key in self._cache:
            self.hits += 1
            return self._cache[key]
        self.misses += 1
        t0 = time.perf_counter()
        fn = builder()
        self.build_time += time.perf_counter() - t0
        self._cache[key] = fn
        return fn

    def prewarm(self, key: tuple, builder: Callable[[], Callable]) -> None:
        self.get(key, builder)

    def clear(self) -> None:
        self._cache.clear()


GLOBAL_EXECUTOR_POOL = LeanExecutorPool()
