"""Paged KV cache on top of the MITOSIS PagePool.

One page = `page_tokens` KV slots of one layer (K heads x head_dim), for K or
V.  Sequences hold per-layer page tables; `fork_sequence` shares pages
copy-on-write with refcounts — the serving-side realization of the paper's
zero-serialization state transfer (children fork the parent's prefix pages
and append privately).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.memory.pool import PagePool


@dataclasses.dataclass
class SeqKV:
    seq_id: int
    length: int
    # page tables: (L, P) int32 frame ids for K and V
    k_pages: np.ndarray
    v_pages: np.ndarray
    # copy-on-write: pages shared with an ancestor are read-only
    shared_mask: np.ndarray       # (P,) bool — True = shared (not writable)


class PagedKV:
    def __init__(self, num_layers: int, kv_heads: int, head_dim: int,
                 page_tokens: int = 16, dtype=jnp.bfloat16,
                 pool: Optional[PagePool] = None):
        self.L = num_layers
        self.K = kv_heads
        self.hd = head_dim
        self.Tp = page_tokens
        self.dtype = jnp.dtype(dtype)
        self.page_elems = page_tokens * kv_heads * head_dim
        self.pool = pool or PagePool(page_elems=self.page_elems)
        assert self.pool.page_elems == self.page_elems
        self.refcount: Dict[int, int] = {}
        self.seqs: Dict[int, SeqKV] = {}
        self._next = 0

    # -- frames view for the attention kernel ---------------------------------

    def frames_view(self):
        f = self.pool.frames_array(self.dtype)
        return f.reshape(f.shape[0], self.Tp, self.K, self.hd)

    # -- sequence lifecycle ----------------------------------------------------

    def new_seq(self) -> int:
        sid = self._next
        self._next += 1
        self.seqs[sid] = SeqKV(sid, 0,
                               np.zeros((self.L, 0), np.int32),
                               np.zeros((self.L, 0), np.int32),
                               np.zeros((0,), bool))
        return sid

    def _alloc_column(self, seq: SeqKV) -> None:
        """Append one page per layer for K and V."""
        kf = self.pool.alloc(self.dtype, self.L)
        vf = self.pool.alloc(self.dtype, self.L)
        for f in list(kf) + list(vf):
            self.refcount[int(f)] = 1
        seq.k_pages = np.concatenate([seq.k_pages, kf[:, None]], axis=1)
        seq.v_pages = np.concatenate([seq.v_pages, vf[:, None]], axis=1)
        seq.shared_mask = np.concatenate([seq.shared_mask, [False]])

    def _cow_column(self, seq: SeqKV, col: int) -> None:
        """Privatize a shared page column before writing (COW)."""
        old_k, old_v = seq.k_pages[:, col].copy(), seq.v_pages[:, col].copy()
        kf = self.pool.alloc(self.dtype, self.L)
        vf = self.pool.alloc(self.dtype, self.L)
        self.pool.write_pages(self.dtype, kf,
                              self.pool.read_pages(self.dtype, old_k))
        self.pool.write_pages(self.dtype, vf,
                              self.pool.read_pages(self.dtype, old_v))
        for f in list(kf) + list(vf):
            self.refcount[int(f)] = 1
        for f in list(old_k) + list(old_v):
            self._unref(int(f))
        seq.k_pages[:, col] = kf
        seq.v_pages[:, col] = vf
        seq.shared_mask[col] = False

    def ensure_writable_slot(self, sid: int) -> tuple:
        """Returns (col, slot) where the next token goes; allocates/COWs."""
        seq = self.seqs[sid]
        col, slot = divmod(seq.length, self.Tp)
        if col >= seq.k_pages.shape[1]:
            self._alloc_column(seq)
        elif seq.shared_mask[col]:
            self._cow_column(seq, col)
        return col, slot

    def append_token(self, sid: int, k_rows, v_rows) -> None:
        """k_rows/v_rows: (L, K, hd) for the new token."""
        seq = self.seqs[sid]
        col, slot = self.ensure_writable_slot(sid)
        row = self.K * self.hd
        slots = [slot] * self.L
        self.pool.write_rows(self.dtype, seq.k_pages[:, col], slots,
                             k_rows.reshape(self.L, -1), row)
        self.pool.write_rows(self.dtype, seq.v_pages[:, col], slots,
                             v_rows.reshape(self.L, -1), row)
        seq.length += 1

    def write_prefill(self, sid: int, k, v) -> None:
        """k/v: (L, S, K, hd) — bulk-write a prefilled prefix."""
        L, S = k.shape[0], k.shape[1]
        seq = self.seqs[sid]
        assert seq.length == 0
        ncols = -(-S // self.Tp)
        for _ in range(ncols):
            self._alloc_column(seq)
        pad = ncols * self.Tp - S
        if pad:
            padw = ((0, 0), (0, pad), (0, 0), (0, 0))
            k, v = jnp.pad(k, padw), jnp.pad(v, padw)
        k = k.reshape(L, ncols, self.Tp, self.K, self.hd)
        v = v.reshape(L, ncols, self.Tp, self.K, self.hd)
        for c in range(ncols):
            self.pool.write_pages(self.dtype, seq.k_pages[:, c],
                                  k[:, c].reshape(L, -1))
            self.pool.write_pages(self.dtype, seq.v_pages[:, c],
                                  v[:, c].reshape(L, -1))
        seq.length = S

    # -- fork (the paper's state transfer) ---------------------------------------

    def fork_sequence(self, sid: int) -> int:
        """COW-fork: child shares every existing page read-only."""
        src = self.seqs[sid]
        child = self.new_seq()
        dst = self.seqs[child]
        dst.length = src.length
        dst.k_pages = src.k_pages.copy()
        dst.v_pages = src.v_pages.copy()
        dst.shared_mask = np.ones(src.k_pages.shape[1], bool)
        src.shared_mask = np.ones(src.k_pages.shape[1], bool)  # parent too
        for f in list(src.k_pages.ravel()) + list(src.v_pages.ravel()):
            self.refcount[int(f)] = self.refcount.get(int(f), 1) + 1
        return child

    def _unref(self, frame: int) -> None:
        self.refcount[frame] = self.refcount.get(frame, 1) - 1
        if self.refcount[frame] <= 0:
            self.pool.free(self.dtype, [frame])
            del self.refcount[frame]

    def free_seq(self, sid: int) -> None:
        seq = self.seqs.pop(sid, None)
        if seq is None:
            return
        for f in list(seq.k_pages.ravel()) + list(seq.v_pages.ravel()):
            self._unref(int(f))

    # -- batched views for attention ----------------------------------------------

    def batch_tables(self, sids: List[int]):
        """Pad page tables to a common length: returns (k_pt, v_pt, lengths)
        with shape (B, L, P)."""
        P = max(self.seqs[s].k_pages.shape[1] for s in sids)
        B = len(sids)
        k_pt = np.zeros((B, self.L, P), np.int32)
        v_pt = np.zeros((B, self.L, P), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, s in enumerate(sids):
            seq = self.seqs[s]
            p = seq.k_pages.shape[1]
            k_pt[i, :, :p] = seq.k_pages
            v_pt[i, :, :p] = seq.v_pages
            lens[i] = seq.length
        return jnp.asarray(k_pt), jnp.asarray(v_pt), jnp.asarray(lens)

    def bytes_in_use(self) -> int:
        return self.pool.bytes_allocated()
