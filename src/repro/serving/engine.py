"""Serving engine: continuous batching over a paged KV cache, with
fork-based prefix sharing (the MITOSIS state-transfer path).

Supports the dense/MoE attention architectures through a paged decode
forward built from the same layer primitives as the training model (SSM
archs serve through lm.decode_step's O(1) recurrent states instead — their
state rides in the fork descriptor like CPU registers).

The decode attention runs through kernels/paged_attention (Pallas on TPU,
oracle elsewhere), reading KV directly from pool frames — children created
by `fork_request` attend over the parent's pages with zero copies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, AttnSpec
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import layers as L
from repro.models import lm
from repro.models import moe as MOE
from repro.serving.kv_cache import PagedKV
from repro.serving.sampling import sample


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    seq_id: Optional[int] = None
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, page_tokens: int = 16,
                 backend: str = "auto", eos_id: int = -1):
        self.cfg = cfg
        specs = [s for s in cfg.block_specs() if isinstance(s, AttnSpec)]
        if len(specs) != cfg.num_layers:
            raise ValueError("paged engine supports attention archs; "
                             "use the recurrent-state engine for SSM archs")
        self.specs = list(cfg.block_specs())
        self.params = params
        self.kv = PagedKV(cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
                          page_tokens=page_tokens,
                          dtype=jnp.dtype(cfg.compute_dtype))
        self.backend = backend
        self.eos_id = eos_id
        self.requests: Dict[int, Request] = {}
        self.active: List[int] = []
        self.waiting: List[int] = []
        self._rid = 0
        self._block_params = self._flatten_blocks()

    def _flatten_blocks(self):
        """Per-layer param slices (unstacked views for the python-loop path)."""
        out = []
        for g, gp in zip(self.cfg.groups, self.params["groups"]):
            for r in range(g.repeat):
                for bi, spec in enumerate(g.unit):
                    bp = gp["blocks"][bi]
                    if getattr(spec, "shared", False):
                        out.append((spec, bp))
                    else:
                        out.append((spec, jax.tree.map(lambda x: x[r], bp)))
        return out

    # -- request lifecycle -----------------------------------------------------

    def submit(self, prompt: List[int], max_tokens: int = 16) -> int:
        rid = self._rid
        self._rid += 1
        self.requests[rid] = Request(rid, list(prompt), max_tokens)
        self.waiting.append(rid)
        return rid

    def fork_request(self, src_rid: int, max_tokens: int = 16) -> int:
        """Fork a running request: shares its KV prefix pages COW."""
        src = self.requests[src_rid]
        rid = self._rid
        self._rid += 1
        r = Request(rid, list(src.prompt) + list(src.out_tokens), max_tokens)
        r.seq_id = self.kv.fork_sequence(src.seq_id)
        self.requests[rid] = r
        self.active.append(rid)
        return rid

    # -- model internals ---------------------------------------------------------

    def _prefill(self, req: Request) -> None:
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        cache_len = ((len(req.prompt) + self.kv.Tp - 1) // self.kv.Tp) * self.kv.Tp
        logits, caches = lm.prefill(self.params, self.cfg, toks, cache_len)
        req.seq_id = self.kv.new_seq()
        # flatten the grouped caches into (L, S, K, hd)
        ks, vs = [], []
        for g, gc in zip(self.cfg.groups, caches["groups"]):
            for r in range(g.repeat):               # execution order: repeat
                for bi, spec in enumerate(g.unit):  # outer, unit inner
                    c = gc["blocks"][bi]
                    ks.append(c["k"][r, 0])
                    vs.append(c["v"][r, 0])
        k = jnp.stack(ks)[:, :len(req.prompt)]
        v = jnp.stack(vs)[:, :len(req.prompt)]
        self.kv.write_prefill(req.seq_id, k, v)
        tok = int(jnp.argmax(logits[0, -1] if logits.ndim == 3 else logits[0]))
        req.out_tokens.append(tok)

    def _decode_batch(self, rids: List[int], key) -> None:
        B = len(rids)
        cfg = self.cfg
        reqs = [self.requests[r] for r in rids]
        sids = [r.seq_id for r in reqs]
        toks = jnp.asarray([(r.out_tokens[-1] if r.out_tokens else r.prompt[-1])
                            for r in reqs], jnp.int32)
        pos = jnp.asarray([self.kv.seqs[s].length for s in sids], jnp.int32)
        dt = jnp.dtype(cfg.compute_dtype)

        # reserve the slot for the incoming token (alloc/COW before write)
        for s in sids:
            self.kv.ensure_writable_slot(s)
        k_pt, v_pt, lens = self.kv.batch_tables(sids)

        h = L.embed_tokens(self.params["embed"], cfg, toks[:, None], dt)
        for li, (spec, bp) in enumerate(self._block_params):
            hn = L.rms_norm(h, bp["norm1"]["scale"], cfg.norm_eps)
            q, k1, v1 = L._project_qkv(bp["attn"], hn, spec, cfg, pos[:, None])
            # write this token's K/V into the reserved slot, then attend
            self._write_token(sids, li, k1[:, 0], v1[:, 0])
            frames = self.kv.frames_view()
            G = cfg.num_heads // cfg.num_kv_heads
            qh = q[:, 0].reshape(B, cfg.num_kv_heads, G, cfg.head_dim)
            eff = lens + 1
            starts = (jnp.maximum(eff - spec.window, 0)
                      if spec.window is not None else None)
            att = paged_attention(qh, frames, frames, k_pt[:, li], eff,
                                  v_page_table=v_pt[:, li], starts=starts,
                                  backend=self.backend)
            a = att.reshape(B, 1, cfg.num_heads, cfg.head_dim)
            y = jnp.einsum("bshk,hkd->bsd", a, bp["attn"]["wo"].astype(dt))
            h = h + y
            if "mlp" in bp or "moe" in bp:
                hn2 = L.rms_norm(h, bp["norm2"]["scale"], cfg.norm_eps)
                if "moe" in bp:
                    h = h + MOE.moe_mlp(bp["moe"], hn2, cfg)
                else:
                    h = h + L.mlp(bp["mlp"], hn2, cfg.mlp_gated)
        h = L.rms_norm(h, self.params["final_norm"]["scale"], cfg.norm_eps)
        logits = L.output_logits(self.params["embed"], cfg, h)[:, 0]
        toks_new = sample(logits, key)
        for i, (r, s) in enumerate(zip(reqs, sids)):
            self.kv.seqs[s].length += 1
            t = int(toks_new[i])
            r.out_tokens.append(t)
            if t == self.eos_id or len(r.out_tokens) >= r.max_tokens:
                r.done = True

    def _write_token(self, sids, layer, k_rows, v_rows) -> None:
        """k_rows/v_rows: (B, K, hd) for one layer at each seq's current pos."""
        kv = self.kv
        kf, vf, slots = [], [], []
        for s in sids:
            seq = kv.seqs[s]
            col, slot = divmod(seq.length, kv.Tp)
            kf.append(seq.k_pages[layer, col])
            vf.append(seq.v_pages[layer, col])
            slots.append(slot)
        B = len(sids)
        row = kv.K * kv.hd
        kv.pool.write_rows(kv.dtype, kf, slots, k_rows.reshape(B, -1), row)
        kv.pool.write_rows(kv.dtype, vf, slots, v_rows.reshape(B, -1), row)

    # -- scheduler ------------------------------------------------------------------

    def step(self, key=None) -> List[int]:
        """One engine iteration: admit one waiting request (prefill), then
        decode all active. Returns finished request ids."""
        key = key if key is not None else jax.random.PRNGKey(0)
        if self.waiting:
            rid = self.waiting.pop(0)
            self._prefill(self.requests[rid])
            self.active.append(rid)
        if self.active:
            self._decode_batch(self.active, key)
        finished = [r for r in self.active if self.requests[r].done]
        for r in finished:
            self.active.remove(r)
            self.kv.free_seq(self.requests[r].seq_id)
        return finished

    def run_to_completion(self, key=None, max_steps: int = 1000):
        if key is None:
            key = jax.random.PRNGKey(0)
        steps = 0
        while (self.waiting or self.active) and steps < max_steps:
            self.step(jax.random.fold_in(key, steps))
            steps += 1
        return {r.req_id: r.out_tokens for r in self.requests.values()}
