"""Sharding rules for the production meshes.

Axes: `model` = tensor/expert parallel; `data` (+ `pod` when present) =
data parallel and FSDP (ZeRO-3-style parameter sharding on a non-model dim).

Policy (per DESIGN.md §5):
  * attention: head-TP when both H and KV divide the model axis; else shard
    head_dim (partial-sum contractions); else replicate heads.
  * MLP: F_ff over model, D over fsdp.  MoE: experts over model (EP).
  * embeddings: vocab over model, d_model over fsdp.
  * Mamba/xLSTM in/out projections: fsdp only in the baseline (documented
    hillclimb: split the fused in_proj to unlock TP — see EXPERIMENTS §Perf).
  * activations: batch over (pod, data); batch-1 long-context decode shards
    the KV sequence axis instead (sequence-parallel decode).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    mesh: Mesh
    fsdp: Tuple[str, ...]          # param-shard axes
    dp: Tuple[str, ...]            # batch axes
    model: str = "model"
    # attention head policy: "v1" = head-TP only if H and K both divide
    # (else shard head_dim); "qtp" = shard Q heads over model whenever H
    # divides, replicate K/V when K doesn't — kills the scores partial-sum
    # all-reduce for MQA/GQA (§Perf hillclimb, granite/kimi/chameleon).
    attn_policy: str = "v1"
    # MoE dispatch: "gspmd" = einsum/sort under GSPMD; "shardmap" = explicit
    # expert-parallel dispatch with local sort + psum combine (§Perf).
    moe_impl: str = "gspmd"
    # Mamba/SSD tensor parallelism: shard the inner (head) dim of the SSD
    # block over `model` via activation constraints — GSPMD then partitions
    # the in/out projections by output dim (§Perf, zamba2).
    mamba_tp: bool = False

    @property
    def msize(self) -> int:
        return self.mesh.shape[self.model]

    @property
    def fsize(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.fsdp]))

    @property
    def dpsize(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp]))


def make_axis_env(mesh: Mesh, fsdp_over_pod: bool = True,
                  attn_policy: str = "v1", moe_impl: str = "gspmd",
                  mamba_tp: bool = False) -> AxisEnv:
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    fsdp = dp if fsdp_over_pod else ("data",)
    return AxisEnv(mesh=mesh, fsdp=fsdp, dp=dp, attn_policy=attn_policy,
                   moe_impl=moe_impl, mamba_tp=mamba_tp)


def _div(n: int, k: int) -> bool:
    return n % k == 0


def param_pspec(path: str, shape, cfg: ArchConfig, env: AxisEnv) -> P:
    """Name-based sharding rule. `path` is 'a/b/c' leaf path; stacked block
    params carry a leading repeat axis (never sharded)."""
    m, F = env.model, env.fsdp
    ms, fs = env.msize, env.fsize
    parts = path.split("/")
    leaf = parts[-1]
    owner = parts[-2] if len(parts) >= 2 else ""
    nd = len(shape)

    def lead(spec_tail):  # prepend None for the stacked repeat axis
        pad = nd - len(spec_tail)
        return P(*([None] * pad + list(spec_tail)))

    # ---- embeddings ----
    # Vocab over model only: sharding D over the data axis conflicts with
    # batch-sharded token gathers and triggers involuntary full
    # rematerialization in SPMD (observed in the dry-run).
    if owner == "embed" and leaf == "tok":
        return lead([m, None]) if _div(shape[-2], ms) else P()
    if owner == "embed" and leaf == "out":
        return lead([None, m]) if _div(shape[-1], ms) else P()

    # ---- attention ----
    if owner == "attn" or (len(parts) >= 3 and parts[-3] == "attn"):
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        if env.attn_policy == "qtp":
            q_tp = _div(H, ms)
            kv_tp = _div(K, ms)
            if leaf == "wq":
                return lead([F, m, None]) if q_tp else lead([F, None, None])
            if leaf in ("wk", "wv"):
                return lead([F, m, None]) if kv_tp else lead([F, None, None])
            if leaf == "wo":
                return lead([m, None, F]) if q_tp else lead([None, None, F])
            if leaf == "bq":
                return lead([m, None]) if q_tp else P()
            if leaf in ("bk", "bv"):
                return lead([m, None]) if kv_tp else P()
            return P()
        head_tp = _div(H, ms) and _div(K, ms)
        hd_tp = _div(hd, ms)
        if leaf == "wq":
            if head_tp:
                return lead([F, m, None])
            return lead([F, None, m]) if hd_tp else lead([F, None, None])
        if leaf in ("wk", "wv"):
            if head_tp:
                return lead([F, m, None])
            return lead([F, None, m]) if hd_tp else lead([F, None, None])
        if leaf == "wo":
            if head_tp:
                return lead([m, None, F])
            return lead([None, m, F]) if hd_tp else lead([None, None, F])
        if leaf in ("bq", "bk", "bv"):
            if head_tp:
                return lead([m, None])
            return lead([None, m]) if hd_tp else P()
        return P()                                    # q_norm/k_norm scales

    # ---- dense MLP ----
    if owner == "mlp":
        if leaf in ("wi", "wg"):
            return lead([F, m]) if _div(shape[-1], ms) else lead([F, None])
        if leaf == "wd":
            return lead([m, F]) if _div(shape[-2], ms) else lead([None, F])

    # ---- MoE ----
    if owner == "moe":
        E = cfg.moe_experts
        etp = _div(E, ms)
        if leaf == "router":
            return lead([F, None])
        if leaf in ("wi", "wg"):
            return lead([m, F, None]) if etp else lead([None, F, None])
        if leaf == "wd":
            return lead([m, None, F]) if etp else lead([None, None, F])

    # ---- Mamba2 (baseline: fsdp only; see §Perf for the TP variant) ----
    if owner == "mamba":
        if leaf == "in_proj":
            return lead([F, None])
        if leaf == "out_proj":
            return lead([None, F])
        return P()

    # ---- xLSTM ----
    if owner == "mlstm":
        if leaf == "w_up":
            return lead([F, m]) if _div(shape[-1], ms) else lead([F, None])
        if leaf in ("wq", "wk", "wv"):
            return lead([F, m]) if _div(shape[-1], ms) else lead([F, None])
        if leaf == "w_down":
            return lead([m, F]) if _div(shape[-2], ms) else lead([None, F])
        return P()
    if owner == "slstm":
        return P()

    return P()       # norms, biases, scalars


def params_shardings(cfg: ArchConfig, params_shapes, env: AxisEnv):
    """params_shapes: pytree of ShapeDtypeStruct (jax.eval_shape output)."""
    from repro.core.descriptor import flatten_with_names
    names, paths, leaves = flatten_with_names(params_shapes)
    specs = [param_pspec(n, l.shape, cfg, env) for n, l in zip(names, leaves)]
    flat, treedef = jax.tree_util.tree_flatten(params_shapes)
    shardings = [NamedSharding(env.mesh, s) for s in specs]
    return jax.tree_util.tree_unflatten(treedef, shardings)


# ---------------------------------------------------------------------------
# activations / inputs
# ---------------------------------------------------------------------------


def batch_pspec(batch: int, env: AxisEnv) -> P:
    if _div(batch, env.dpsize):
        return P(env.dp)
    if "data" in env.dp and _div(batch, env.mesh.shape["data"]):
        return P("data")
    return P()


def token_sharding(cfg: ArchConfig, batch: int, env: AxisEnv):
    return NamedSharding(env.mesh, batch_pspec(batch, env))


def cache_pspec(path: str, shape, cfg: ArchConfig, env: AxisEnv, batch: int) -> P:
    """KV / SSM cache leaves. Leading axis is the stacked repeat axis.

    Attention caches: (R, B, S, K, hd); SSM: (R, B, H, P, N) etc.
    Prefer batch over dp; for batch-1 long-context shard the seq axis."""
    nd = len(shape)
    leaf = path.split("/")[-1]
    bspec = batch_pspec(batch, env)
    if leaf in ("k", "v") and nd >= 4:
        pads = [None] * nd
        if bspec != P():
            pads[1] = bspec[0] if len(bspec) else None
        else:
            # sequence-parallel cache for unshardable batch
            if _div(shape[2], env.dpsize):
                pads[2] = env.dp
        K, hd = shape[-2], shape[-1]
        if _div(K, env.msize):
            pads[-2] = env.model
        elif _div(hd, env.msize):
            pads[-1] = env.model
        return P(*pads)
    # recurrent states: batch over dp if divisible, else replicate
    pads = [None] * nd
    if nd >= 2 and bspec != P():
        pads[1] = bspec[0] if len(bspec) else None
    return P(*pads)


def cache_shardings(cfg: ArchConfig, cache_shapes, env: AxisEnv, batch: int):
    from repro.core.descriptor import flatten_with_names
    names, paths, leaves = flatten_with_names(cache_shapes)
    specs = [cache_pspec(n, l.shape, cfg, env, batch) for n, l in zip(names, leaves)]
    flat, treedef = jax.tree_util.tree_flatten(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(env.mesh, s) for s in specs])


def opt_state_shardings(param_sh, count_sharding=None):
    """m/v mirror params; count is replicated."""
    import jax
    rep = count_sharding
    return {
        "m": param_sh,
        "v": param_sh,
        "count": rep,
    }
