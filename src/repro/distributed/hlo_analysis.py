"""Loop-aware cost extraction from compiled (post-SPMD) HLO text.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE, which
undercounts scan-over-layers models by ~depth x.  We therefore walk the HLO
module ourselves:

  * computations are segmented from the text; every op line records
    `name -> result type` (a per-computation symbol table);
  * `while` ops carry `backend_config={"known_trip_count":{"n":N}}` (XLA
    emits this for lax.scan); nested loops multiply;
  * dot FLOPs = 2 * prod(result dims) * prod(contracting dims), with
    contracting sizes resolved through the symbol table;
  * collective bytes = result-shape bytes per op kind;
  * HBM traffic model (documented): every materialized buffer is written
    once and read ~once downstream (2 x result bytes), plus entry
    parameters read once.  Elementwise FLOPs are ignored (dot-dominated
    graphs; stated in EXPERIMENTS.md).

All quantities are per-device (the module is the partitioned program);
callers normalize to global.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict
from typing import Dict, List, Optional, Tuple

_DT_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z]+\d*(?:e\dm\d(?:fn)?)?)\[([0-9,]*)\]")
_OPCODE = re.compile(r"^((?:\([^)]*\)|[a-z0-9\[\],{}\s])*?)\s*([a-z][a-z0-9\-]*)\(")
_TRIP = re.compile(r'known_trip_count[":{ ]+n["\s:]+"?(\d+)')
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_OPERANDS = re.compile(r"%([\w.\-]+)")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt in _DT_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += int(n * _DT_BYTES[dt])
    return total


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    result_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]
    symbols: Dict[str, str]          # op name -> result type str


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line.strip())
        if hdr and "->" in line:
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = _OPCODE.match(rest)
        if not om:
            continue
        type_str, opcode = om.group(1).strip(), om.group(2)
        cur.ops.append(OpInfo(name, opcode, type_str, rest))
        cur.symbols[name] = type_str
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Trip-count multiplier per computation, walked from ENTRY."""
    mult: Dict[str, float] = defaultdict(float)
    entry = None
    for name, c in comps.items():
        if any(op.opcode == "while" for op in c.ops) or True:
            pass
    # entry = the computation not referenced as body/cond/to_apply/calls
    referenced = set()
    refs: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for name, c in comps.items():
        for op in c.ops:
            called = _CALLED.findall(op.line)
            trips = 1.0
            if op.opcode == "while":
                tm = _TRIP.search(op.line)
                trips = float(tm.group(1)) if tm else 1.0
            for cal in called:
                referenced.add(cal)
                refs[name].append((cal, trips if op.opcode == "while" else 1.0))
    entries = [n for n in comps if n not in referenced]
    stack = [(e, 1.0) for e in entries]
    while stack:
        name, m = stack.pop()
        if m <= mult[name]:
            continue
        mult[name] = m
        for cal, trips in refs.get(name, ()):  # descend
            stack.append((cal, m * trips))
    return dict(mult)


_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(op: OpInfo, sym: Dict[str, str]) -> float:
    dims = _shape_dims(op.result_type)
    if not dims:
        return 0.0
    out_elems = 1
    for d in dims[0][1]:
        out_elems *= d
    cm = _CONTRACT.search(op.line)
    contract = 1
    if cm is not None:
        # first operand after the opcode parens
        inner = op.line[op.line.index("(") + 1:]
        ops = _OPERANDS.findall(inner[:inner.index(")")])
        if ops:
            lhs_type = sym.get(ops[0], "")
            lds = _shape_dims(lhs_type)
            if lds:
                idxs = [int(i) for i in cm.group(1).split(",") if i]
                for i in idxs:
                    if i < len(lds[0][1]):
                        contract *= lds[0][1][i]
    return 2.0 * out_elems * contract


# opcodes whose result we exclude from the traffic model (pure bookkeeping)
_NO_TRAFFIC = {"get-tuple-element", "tuple", "parameter", "constant",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "conditional", "call"}


def analyze(hlo: str) -> Dict[str, float]:
    """Loop-aware per-device totals: dot flops, collective bytes (by kind and
    total, ring-model), HBM traffic estimate, op counts."""
    comps = parse_computations(hlo)
    mult = _multipliers(comps)
    flops = 0.0
    traffic = 0.0
    coll: Counter = Counter()
    # computations used as fusion bodies: their interiors are not separate
    # buffers — traffic is accounted at the fusion call site.
    fusion_bodies = set()
    inplace_bodies = set()      # fusion bodies doing dynamic-update-slice
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                for cal in _CALLED.findall(op.line):
                    fusion_bodies.add(cal)
    for name in fusion_bodies:
        c = comps.get(name)
        if c and any(o.opcode in ("dynamic-update-slice", "scatter")
                     for o in c.ops):
            inplace_bodies.add(name)

    def _op_traffic(op, symbols) -> float:
        b = _type_bytes(op.result_type)
        if op.opcode == "fusion":
            called = _CALLED.findall(op.line)
            if any(c in inplace_bodies for c in called):
                # TPU performs DUS on loop carries in place: the write is the
                # updated slice, not the whole buffer.  Approximate the slice
                # as (result - largest operand); CPU's full-copy lowering
                # would otherwise dominate decode/train caches spuriously.
                inner = op.line[op.line.index("(") + 1:]
                names = _OPERANDS.findall(inner[:inner.index(")")])
                opb = [_type_bytes(symbols.get(n, "")) for n in names]
                if opb:
                    return max(b - max(opb), min(x for x in opb if x > 0)
                               if any(x > 0 for x in opb) else 0)
        return b

    entry_params = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        in_fusion = cname in fusion_bodies
        for op in comp.ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, comp.symbols)
                if in_fusion:
                    continue
            kind = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if kind in COLLECTIVES and not in_fusion:
                b = _type_bytes(op.result_type)
                coll[kind] += m * b
                coll[kind + "_ops"] += m
            if op.opcode.endswith("-done") or in_fusion:
                continue
            if op.opcode not in _NO_TRAFFIC:
                traffic += m * _op_traffic(op, comp.symbols)
            if op.opcode == "parameter" and m == 1.0:
                entry_params += _type_bytes(op.result_type)
    return {
        "dot_flops": flops,
        "traffic_bytes": 2.0 * traffic + entry_params,
        "collectives": dict(coll),
        "n_computations": len(comps),
    }


def total_collective_bytes(coll: Dict[str, float]) -> float:
    """Ring-model bytes per device: all-reduce ~2x payload (RS+AG phases)."""
    tot = 0.0
    for k in COLLECTIVES:
        b = coll.get(k, 0)
        tot += 2 * b if k == "all-reduce" else b
    return tot


# Backwards-compatible helpers -------------------------------------------------


def collective_stats(hlo_text: str) -> Dict[str, float]:
    return analyze(hlo_text)["collectives"]
