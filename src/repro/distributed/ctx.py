"""Activation-sharding context.

Model code is mesh-agnostic; drivers (dryrun/train/serve) install an AxisEnv
here and layers pin their activations with `constrain(x, dims)` — logical
dims 'dp' (batch) / 'model' / None per axis, applied only when the dim size
divides the mesh axis.  Without an installed env every call is a no-op, so
single-device CPU tests never touch sharding machinery.

This pinning is what keeps GSPMD from replicating the batch inside
scan bodies (observed 3x FLOP inflation in the dry-run without it).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import numpy as np

_ENV = None


def set_env(env) -> None:
    global _ENV
    _ENV = env


def get_env():
    return _ENV


@contextlib.contextmanager
def use_env(env):
    global _ENV
    prev = _ENV
    _ENV = env
    try:
        yield
    finally:
        _ENV = prev


def _axis_size(env, name) -> int:
    if name == "dp":
        return env.dpsize
    return env.mesh.shape[name]


def constrain(x, dims):
    """dims: tuple of 'dp' | 'model' | None per axis of x."""
    env = _ENV
    if env is None:
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = []
    for size, d in zip(x.shape, dims):
        if d is None:
            spec.append(None)
        elif size % _axis_size(env, d) == 0:
            spec.append(env.dp if d == "dp" else d)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env.mesh, P(*spec)))
