"""Three-term roofline model for TPU v5e (target hardware; CPU is only the
compile host).

  compute    = HLO_FLOPs   / (chips * 197e12)
  memory     = HLO_bytes   / (chips * 819e9)
  collective = coll_bytes  / (chips * 50e9)

HLO_FLOPs / HLO_bytes are normalized to GLOBAL (all-chip) quantities before
applying the formulas; the dry-run records which normalization was applied.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link
HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_global: float
    bytes_global: float
    coll_bytes_global: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Lower bound on step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self, model_flops: float) -> float:
        """Useful-FLOPs throughput achievable at the bound, as a fraction of
        peak: (model_flops / step_time_lb) / (chips * peak)."""
        if self.step_time_lb == 0:
            return 0.0
        return (model_flops / self.step_time_lb) / (self.chips * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_global": self.flops_global, "bytes_global": self.bytes_global,
            "coll_bytes_global": self.coll_bytes_global, "chips": self.chips,
        }


def roofline(flops_global: float, bytes_global: float,
             coll_bytes_global: float, chips: int) -> Roofline:
    return Roofline(
        compute_s=flops_global / (chips * PEAK_FLOPS),
        memory_s=bytes_global / (chips * HBM_BW),
        collective_s=coll_bytes_global / (chips * LINK_BW),
        flops_global=flops_global,
        bytes_global=bytes_global,
        coll_bytes_global=coll_bytes_global,
        chips=chips,
    )
