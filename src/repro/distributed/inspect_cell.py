import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler: attributes loop-aware collective bytes / HBM traffic /
dot FLOPs to HLO ops (with jax op_name metadata), so §Perf hypotheses are
grounded in the compiled artifact rather than guesses.

  PYTHONPATH=src python -m repro.distributed.inspect_cell granite-34b \
      prefill_32k [--multi-pod] [--opt k=v]
"""
import argparse
import re

import jax

from repro.distributed import ctx as _ctx
from repro.distributed import hlo_analysis as H


def inspect(arch, shape, multi_pod=False, opts=None, top=18):
    from repro.launch.dryrun import input_specs
    spec = input_specs(arch, shape, multi_pod, opts)
    fn = jax.jit(spec["fn"], donate_argnums=spec["donate"])
    with _ctx.use_env(spec["env"]):
        compiled = fn.lower(*spec["args"]).compile()
    hlo = compiled.as_text()
    comps = H.parse_computations(hlo)
    mult = H._multipliers(comps)
    fusion_bodies = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                for cal in H._CALLED.findall(op.line):
                    fusion_bodies.add(cal)

    def opname(line):
        m = re.search(r'op_name="([^"]+)"', line)
        return m.group(1)[:90] if m else ""

    coll_rows, traf_rows, flop_rows = [], [], []
    for cname, comp in comps.items():
        m = mult.get(cname, 0)
        if m <= 0:
            continue
        for op in comp.ops:
            kind = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if op.opcode == "dot":
                flop_rows.append((m * H._dot_flops(op, comp.symbols), m,
                                  op.result_type[:40], opname(op.line)))
            if cname in fusion_bodies:
                continue
            b = H._type_bytes(op.result_type)
            if kind in H.COLLECTIVES:
                coll_rows.append((m * b, m, kind, op.result_type[:40],
                                  opname(op.line)))
            elif op.opcode not in H._NO_TRAFFIC and not op.opcode.endswith("-done"):
                traf_rows.append((m * b, m, op.opcode, op.result_type[:40],
                                  opname(op.line)))

    print(f"=== {arch} x {shape} x {'pod512' if multi_pod else 'pod256'} "
          f"opts={opts} ===")
    for title, rows in (("collectives", coll_rows), ("traffic", traf_rows),
                        ("dot flops", flop_rows)):
        print(f"-- top {title} (per device, loop-aware) --")
        tot = sum(r[0] for r in rows)
        for r in sorted(rows, reverse=True)[:top]:
            if title == "dot flops":
                print(f"  {r[0]:12.3e} x{r[1]:6.0f} {r[2]:40s} {r[3]}")
            else:
                print(f"  {r[0]/2**30:9.2f}GiB x{r[1]:6.0f} {r[2]:18s} "
                      f"{r[3]:40s} {r[4]}")
        print(f"  TOTAL {title}: "
              + (f"{tot:.3e} flops" if title == "dot flops"
                 else f"{tot/2**30:.1f} GiB"))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="append", default=[])
    ap.add_argument("--top", type=int, default=18)
    args = ap.parse_args()
    opts = {}
    for kv in args.opt:
        k, v = kv.split("=", 1)
        import ast
        try:
            opts[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            opts[k] = v
    inspect(args.arch, args.shape, args.multi_pod, opts, args.top)
