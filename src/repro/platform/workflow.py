"""Serverless workflows (§2.3, §6.1): DAGs of functions with fork-based
state transfer, plus the message-passing baseline (Fn/Redis-style).

Upstream functions pre-materialize state into their instance
(`instance.add_tensor`); downstream functions fork the upstream seed and
read it with zero serialization — the FINRA pattern of Figure 3(b).
"""
from __future__ import annotations

import dataclasses
import pickle
import time
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.fork import ForkHandle, ForkPolicy
from repro.platform.coordinator import Coordinator, ForkTreeNode


@dataclasses.dataclass
class WorkflowFunc:
    name: str
    func: str                      # FunctionDef name at the coordinator
    fork_from: Optional[str] = None  # annotated upstream to fork (§6.1)


class Workflow:
    def __init__(self, wf_id: str):
        self.wf_id = wf_id
        self.nodes: Dict[str, WorkflowFunc] = {}
        self.edges: List[tuple] = []

    def add(self, wfunc: WorkflowFunc) -> "Workflow":
        self.nodes[wfunc.name] = wfunc
        return self

    def edge(self, up: str, down: str) -> "Workflow":
        self.edges.append((up, down))
        return self

    def topo_order(self) -> List[str]:
        indeg = {n: 0 for n in self.nodes}
        for u, v in self.edges:
            indeg[v] += 1
        order, frontier = [], [n for n, d in indeg.items() if d == 0]
        while frontier:
            n = frontier.pop(0)
            order.append(n)
            for u, v in self.edges:
                if u == n:
                    indeg[v] -= 1
                    if indeg[v] == 0:
                        frontier.append(v)
        assert len(order) == len(self.nodes), "cycle in workflow"
        return order

    def upstreams(self, name: str) -> List[str]:
        return [u for u, v in self.edges if v == name]


def run_workflow(coord: Coordinator, wf: Workflow, inputs: dict, *,
                 transfer: str = "fork", fan_out: Dict[str, int] = None,
                 prefetch: int = 1) -> dict:
    """Execute a workflow. transfer: "fork" (MITOSIS) or "message"
    (serialize->copy->deserialize, the Fn/Redis baseline).

    fan_out: optional {func_name: n} to run n parallel children of one node
    (FINRA's ~200 runAuditRule instances)."""
    fan_out = fan_out or {}
    results: Dict[str, Any] = {}
    instances: Dict[str, Any] = {}
    seeds: Dict[str, ForkHandle] = {}      # wf node -> short-lived seed handle
    root = ForkTreeNode(func="<root>", node_id="", handle=None)
    tree_nodes = {None: root}
    coord.tree_open(wf.wf_id, root)
    mailbox: Dict[str, bytes] = {}

    for name in wf.topo_order():
        wfunc = wf.nodes[name]
        fdef = coord.functions[wfunc.func]
        ups = wf.upstreams(name)
        n_copies = fan_out.get(name, 1)
        outs = []
        for ci in range(n_copies):
            # route-aware: the scheduler sees the function's seed demand
            node = coord.pick_node(func=wfunc.func)
            ctx = dict(inputs)
            inst = None
            if transfer == "fork" and ups:
                src = wfunc.fork_from or ups[0]
                inst = seeds[src].resume_on(node, ForkPolicy(lazy=True,
                                                             prefetch=prefetch))
                ctx["__fork_parent"] = src
            elif transfer == "message" and ups:
                # Fn-style: deserialize upstream state from the mailbox
                for u in ups:
                    ctx[f"msg:{u}"] = pickle.loads(mailbox[u])
            if inst is None:
                inst = coord.acquire_instance(wfunc.func, node=node,
                                              policy="fork")
            out = fdef.behavior(inst, ctx)
            outs.append(out)
            tn = ForkTreeNode(func=name, node_id=node.node_id, handle=None)
            tree_nodes.setdefault(name, tn)
            parent_tn = tree_nodes.get(wfunc.fork_from or (ups[0] if ups else None), root)
            parent_tn.children.append(tn)
            instances.setdefault(name, []).append(inst)
        results[name] = outs if n_copies > 1 else outs[0]

        # prepare this node as a short-lived seed for downstreams (§6.1)
        has_down = any(u == name for u, _ in wf.edges)
        if has_down:
            if transfer == "fork":
                inst0 = instances[name][0]
                handle = inst0.node.prepare_fork(inst0)
                seeds[name] = handle
                tree_nodes[name].handle = handle
            else:
                # message baseline: serialize outputs (the cost MITOSIS skips)
                payload = {k: np.asarray(v) if hasattr(v, "shape") else v
                           for k, v in (results[name] or {}).items()}
                mailbox[name] = pickle.dumps(payload)
                nbytes = len(mailbox[name])
                coord.network.meter["msg_bytes"] += nbytes
                # modeled store round trip: producer PUT + consumer GET
                # (Redis-style; paper: ~27 ms store latency for FINRA)
                nm = coord.network.model
                coord.network.sim_time += 2 * nbytes / nm.rdma_bw + 27e-3

    coord.tree_close(wf.wf_id)
    for insts in instances.values():
        for inst in insts:
            inst.free()
    return results


# ---------------------------------------------------------------------------
# FINRA (Figure 2): fetchPortfolioData + fetchMarketData -> runAuditRule x N
# ---------------------------------------------------------------------------


def build_finra(coord: Coordinator, market_mb: float = 6.0,
                n_rules: int = 8) -> Workflow:
    """The paper's FINRA app: upstream functions fetch market/portfolio data
    (fused, per §7.6), N audit-rule children consume it."""
    wf = Workflow("finra")
    wf.add(WorkflowFunc(name="fetchData", func="finra-fetch"))
    wf.add(WorkflowFunc(name="runAuditRule", func="finra-audit",
                        fork_from="fetchData"))
    wf.edge("fetchData", "runAuditRule")
    return wf
