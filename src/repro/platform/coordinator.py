"""Fork-aware serverless coordinator (§6): seed store, long/short-lived seed
management, fork trees, timeout GC, and startup-policy dispatch.

"Functions" are model instances + a behavior callable; the coordinator
schedules them onto invoker nodes, accelerating startup via long-lived seeds
and state transfer via short-lived seeds, exactly mirroring the paper's Fn
integration.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.core import fork
from repro.core.instance import ModelInstance
from repro.platform.node import NodeRuntime

DEFAULT_SEED_KEEPALIVE = 600.0      # §6.2: 10 min vs caching's 1 min
DEFAULT_CACHE_KEEPALIVE = 30.0      # Fn caches coldstarted containers 30 s
MAX_FUNCTION_LIFETIME = 900.0       # §6.3: AWS-style 15 min upper bound


@dataclasses.dataclass
class FunctionDef:
    name: str
    arch: str
    make_params: Callable[[], Any]          # builds the pristine state
    behavior: Callable[[ModelInstance, dict], dict]
    exec_sim_time: float = 0.0              # modeled pure-exec seconds


@dataclasses.dataclass
class SeedRecord:
    func: str
    node_id: str
    handler_id: int
    auth_key: int
    created: float
    keep_alive: float
    long_lived: bool


@dataclasses.dataclass
class ForkTreeNode:
    func: str
    node_id: str
    handler_id: Optional[int]
    children: List["ForkTreeNode"] = dataclasses.field(default_factory=list)


class Coordinator:
    def __init__(self, network, nodes: List[NodeRuntime], clock=time.monotonic):
        self.network = network
        self.nodes = {n.node_id: n for n in nodes}
        self.clock = clock
        self.functions: Dict[str, FunctionDef] = {}
        self.seed_store: Dict[str, SeedRecord] = {}
        self.fork_trees: Dict[str, ForkTreeNode] = {}
        self.cached: Dict[str, List[tuple]] = {}       # func -> [(inst, ts)]
        self._rr = 0

    # -- registry ---------------------------------------------------------

    def register_function(self, fdef: FunctionDef) -> None:
        self.functions[fdef.name] = fdef

    def pick_node(self, exclude=()) -> NodeRuntime:
        ids = [i for i in self.nodes if self.nodes[i].alive and i not in exclude]
        node = self.nodes[ids[self._rr % len(ids)]]
        self._rr += 1
        return node

    # -- startup paths ------------------------------------------------------

    def coldstart(self, func: str, node: NodeRuntime) -> ModelInstance:
        fdef = self.functions[func]
        params = fdef.make_params()
        inst = ModelInstance.create(node, fdef.arch, params, kind="weights")
        # §6.2: cache only the FIRST coldstart container platform-wide as seed
        if func not in self.seed_store:
            self.deploy_seed(func, node, instance=inst)
        return inst

    def deploy_seed(self, func: str, node: NodeRuntime,
                    instance: Optional[ModelInstance] = None,
                    long_lived: bool = True,
                    keep_alive: float = DEFAULT_SEED_KEEPALIVE) -> SeedRecord:
        fdef = self.functions[func]
        if instance is None:
            instance = ModelInstance.create(node, fdef.arch, fdef.make_params(),
                                            kind="weights")
        hid, key = fork.fork_prepare(node, instance)
        rec = SeedRecord(func=func, node_id=node.node_id, handler_id=hid,
                         auth_key=key, created=self.clock(),
                         keep_alive=keep_alive, long_lived=long_lived)
        if long_lived:
            self.seed_store[func] = rec
        return rec

    def acquire_instance(self, func: str, *, node: Optional[NodeRuntime] = None,
                         policy: str = "fork", lazy: bool = True,
                         prefetch: int = 1):
        """Start (or reuse) a container for `func` without executing it.
        policy: fork | cache | coldstart."""
        node = node or self.pick_node()
        inst = None
        if policy == "cache":
            pool = self.cached.get(func, [])
            # local cached instance (unpause): only usable on its own node
            for i, (cand, ts) in enumerate(pool):
                if cand.node is node:
                    inst = pool.pop(i)[0]
                    break
        if inst is None and policy == "fork":
            rec = self.seed_store.get(func)
            if rec is not None and self._seed_fresh(rec):
                inst = fork.fork_resume(node, rec.node_id, rec.handler_id,
                                        rec.auth_key, lazy=lazy,
                                        prefetch=prefetch)
        if inst is None:
            inst = self.coldstart(func, node)
        return inst

    def invoke(self, func: str, inputs: Optional[dict] = None, *,
               node: Optional[NodeRuntime] = None, policy: str = "fork",
               lazy: bool = True, prefetch: int = 1) -> tuple:
        """Returns (outputs, instance). policy: fork | cache | coldstart."""
        inst = self.acquire_instance(func, node=node, policy=policy,
                                     lazy=lazy, prefetch=prefetch)
        out = self.functions[func].behavior(inst, inputs or {})
        return out, inst

    def release(self, func: str, inst: ModelInstance, policy: str) -> None:
        """Post-execution: caching keeps the container; fork frees the child
        (§6.2: children are never cached)."""
        if policy == "cache":
            self.cached.setdefault(func, []).append((inst, self.clock()))
        else:
            inst.free()

    # -- lifecycle / GC -------------------------------------------------------

    def _seed_fresh(self, rec: SeedRecord) -> bool:
        if rec.node_id not in self.network.nodes:
            return False
        return self.clock() - rec.created < rec.keep_alive

    def renew_seed(self, func: str) -> None:
        rec = self.seed_store.get(func)
        if rec:
            rec.created = self.clock()

    def gc(self) -> dict:
        """Timeout-based reclamation: expired long-lived seeds, stale cached
        containers, and node-side dangling short-lived seeds (§6.3)."""
        now = self.clock()
        freed = {"seeds": 0, "cached": 0, "dangling": 0}
        for func, rec in list(self.seed_store.items()):
            if now - rec.created >= rec.keep_alive:
                node = self.nodes.get(rec.node_id)
                if node is not None:
                    fork.fork_reclaim(node, rec.handler_id, free_instance=True)
                del self.seed_store[func]
                freed["seeds"] += 1
        for func, pool in self.cached.items():
            keep = []
            for inst, ts in pool:
                if now - ts >= DEFAULT_CACHE_KEEPALIVE:
                    inst.free()
                    freed["cached"] += 1
                else:
                    keep.append((inst, ts))
            self.cached[func] = keep
        # invoker-side fault tolerance: GC seeds past max function lifetime
        for node in self.nodes.values():
            for hid, entry in list(node.seeds.items()):
                if now - entry.created >= MAX_FUNCTION_LIFETIME:
                    fork.fork_reclaim(node, hid, free_instance=False)
                    freed["dangling"] += 1
        return freed

    # -- fork trees (short-lived seeds, §6.3) -----------------------------------

    def tree_open(self, wf_id: str, root: ForkTreeNode) -> None:
        self.fork_trees[wf_id] = root

    def tree_close(self, wf_id: str) -> None:
        """Reclaim every short-lived seed in the tree except the root."""
        root = self.fork_trees.pop(wf_id, None)
        if root is None:
            return

        def walk(n: ForkTreeNode, is_root: bool):
            for c in n.children:
                walk(c, False)
            if not is_root and n.handler_id is not None:
                node = self.nodes.get(n.node_id)
                if node is not None:
                    fork.fork_reclaim(node, n.handler_id, free_instance=False)

        walk(root, True)

    def memory_by_node(self) -> Dict[str, int]:
        return {i: n.memory_bytes() for i, n in self.nodes.items()}
