"""Fork-aware serverless coordinator (§6): seed store, long/short-lived seed
management, fork trees, timeout GC, and startup-policy dispatch.

"Functions" are model instances + a behavior callable; the coordinator
schedules them onto invoker nodes, accelerating startup via long-lived seeds
and state transfer via short-lived seeds, exactly mirroring the paper's Fn
integration.

The seed store holds leased ``ForkHandle`` capabilities (repro.fork) — or,
for sharded seeds, a ``ShardedSeed`` (repro.placement) wrapping S replica
handles behind one logical record: lease freshness, renewal and reclamation
all go through the handle surface instead of the old raw (handler_id,
auth_key) SeedRecord tuples.  Node selection is a pluggable scheduler
(transport- and load-aware by default, exclusion-stable round-robin
fallback); a seed replica whose parent drops out of the network is purged
on sight and telemetered as ``parent_lost``, and ``gc()`` re-replicates
sharded seeds back to their target replica count.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Union

import jax

from repro.core.instance import ModelInstance
from repro.core.pagetable import VMA
from repro.fork import ForkHandle, ForkPolicy
from repro.net import NoNodesAvailable, TransportError
from repro.placement import (PlacementPolicy, ShardedSeed,
                             TransportAwareScheduler, route_demand)
from repro.platform.node import NodeRuntime

DEFAULT_SEED_KEEPALIVE = 600.0      # §6.2: 10 min vs caching's 1 min
DEFAULT_CACHE_KEEPALIVE = 30.0      # Fn caches coldstarted containers 30 s
MAX_FUNCTION_LIFETIME = 900.0       # §6.3: AWS-style 15 min upper bound


@dataclasses.dataclass
class FunctionDef:
    name: str
    arch: str
    make_params: Callable[[], Any]          # builds the pristine state
    behavior: Callable[[ModelInstance, dict], dict]
    exec_sim_time: float = 0.0              # modeled pure-exec seconds


@dataclasses.dataclass
class ForkTreeNode:
    func: str
    node_id: str
    handle: Optional[ForkHandle]
    children: List["ForkTreeNode"] = dataclasses.field(default_factory=list)


Seed = Union[ForkHandle, ShardedSeed]


def _seed_handles(seed: Seed) -> List[ForkHandle]:
    """The replica handles behind a seed-store entry (one for a plain
    handle) — the seam that lets every lifecycle pass treat sharded and
    unsharded seeds uniformly."""
    return list(seed.handles) if isinstance(seed, ShardedSeed) else [seed]


class Coordinator:
    def __init__(self, network, nodes: List[NodeRuntime],
                 clock=time.monotonic,  # sim-ok: wall-clock -- host default; replays pass SimClock

                 scheduler=None, seed_replicas: int = 1,
                 seed_placement: Optional[PlacementPolicy] = None,
                 reroute_backlog: Optional[float] = None,
                 cache_keepalive: float = DEFAULT_CACHE_KEEPALIVE,
                 auto_seed: bool = True):
        self.network = network
        self.nodes = {n.node_id: n for n in nodes}
        self.clock = clock
        # how long a released container stays warm before gc() frees it —
        # the keep-warm TTL knob autoscaler policies (repro.sim) tune
        self.cache_keepalive = cache_keepalive
        # §6.2 registers the first coldstart container platform-wide as the
        # function's seed; pure-caching baselines turn that off so a
        # no-MITOSIS control run holds no seed state at all
        self.auto_seed = auto_seed
        self.functions: Dict[str, FunctionDef] = {}
        self.seed_store: Dict[str, Seed] = {}          # func -> seed record
        self.fork_trees: Dict[str, ForkTreeNode] = {}
        self.cached: Dict[str, List[tuple]] = {}       # func -> [(inst, ts)]
        # per-function lease churn (renewals/expiries/revocations/losses)
        # for fig20-style spike replays; surfaced by gc()
        self.lease_telemetry: Dict[str, Counter] = {}
        # node selection is pluggable; the default scores candidates by
        # per-backend setup cost + channel backlog and degrades to a
        # deterministic, exclusion-stable round-robin without context
        self.scheduler = scheduler or TransportAwareScheduler(network)
        # replication defaults applied by the coldstart auto-seed path
        self.seed_replicas = seed_replicas
        self.seed_placement = seed_placement
        # seconds of planned-owner link backlog above which sharded forks
        # re-route VMAs to a cooler replica (ForkPolicy.reroute_backlog on
        # every platform fork); None = static routes
        self.reroute_backlog = reroute_backlog

    def _lease_event(self, func: str, event: str, n: int = 1) -> None:
        self.lease_telemetry.setdefault(func, Counter())[event] += n

    def _count_lost(self, func: str, lost: List[str]) -> None:
        if lost:
            san = self.network.sanitizer
            if san is not None:
                for nid in lost:
                    san.parent_lost(func, nid)
            self._lease_event(func, "parent_lost", len(lost))

    # -- registry ---------------------------------------------------------

    def register_function(self, fdef: FunctionDef) -> None:
        self.functions[fdef.name] = fdef

    def pick_node(self, exclude=(), func: Optional[str] = None) -> NodeRuntime:
        """Schedule the next child.  With ``func``, the scheduler sees the
        seed's route demand — its replica parents × its placement policy's
        transport mix — and lands the child where connection setup (paid RC
        connects amortize, fresh ones don't) plus channel backlog is
        cheapest."""
        return self.scheduler.pick(self.nodes, exclude=exclude,
                                   demand=self._route_demand(func))

    def _route_demand(self, func: Optional[str]):
        seed = self.seed_store.get(func) if func else None
        if seed is None:
            return None
        if isinstance(seed, ShardedSeed):
            return route_demand(seed.parent_nodes,
                                seed.placement.transport_hints())
        return route_demand([seed.parent_node], [None])

    # -- startup paths ------------------------------------------------------

    def coldstart(self, func: str, node: NodeRuntime) -> ModelInstance:
        fdef = self.functions[func]
        params = fdef.make_params()
        inst = ModelInstance.create(node, fdef.arch, params, kind="weights")
        # §6.2: cache only the FIRST coldstart container platform-wide as seed
        if self.auto_seed and func not in self.seed_store:
            self.deploy_seed(func, node, instance=inst,
                             replicas=self.seed_replicas,
                             placement=self.seed_placement)
        return inst

    def deploy_seed(self, func: str, node: Optional[NodeRuntime] = None,
                    instance: Optional[ModelInstance] = None,
                    long_lived: bool = True,
                    keep_alive: float = DEFAULT_SEED_KEEPALIVE,
                    replicas: int = 1,
                    placement: Optional[PlacementPolicy] = None) -> Seed:
        """Prepare ``func``'s seed on ``node``.  ``replicas=S`` shards the
        logical seed over S parents: the origin handle is replicated onto
        S-1 further nodes through the ordinary fork path (eager restore,
        then prepare), and children route their VMAs across the replica set
        per ``placement`` (byte-balanced spread by default).  Returns the
        plain ``ForkHandle`` for an unsharded seed, else the
        ``ShardedSeed``."""
        fdef = self.functions[func]
        node = node or self.pick_node()
        if instance is None:
            instance = ModelInstance.create(node, fdef.arch, fdef.make_params(),
                                            kind="weights")
        handle = node.prepare_fork(instance, lease=keep_alive)
        seed: Seed = handle
        if replicas > 1 or placement is not None:
            seed = ShardedSeed([handle], placement=placement,
                               target_replicas=replicas)
            self._replicate(func, seed, keep_alive=keep_alive,
                            telemetry=False)
        if long_lived:
            self.seed_store[func] = seed
        return seed

    def _replicate(self, func: str, seed: ShardedSeed,
                   keep_alive: Optional[float] = None,
                   telemetry: bool = True) -> int:
        """Grow ``seed`` back to its target replica count by forking a live
        replica onto nodes not already hosting one.  Returns replicas
        added; stops early when no source replica or spare node exists."""
        added = 0
        while seed.replicas < seed.target_replicas:
            live = seed.live_handles()
            if not live:
                break
            src = live[0]
            try:
                node = self.pick_node(exclude=set(seed.parent_nodes))
            except RuntimeError:
                break
            try:
                rinst = src.resume_on(node, ForkPolicy(lazy=False))
            except TransportError:
                # the source replica died (or its fabric flapped) mid-heal:
                # stop growing this sweep, the next pass re-purges and
                # retries from whatever survived
                break
            lease = keep_alive if keep_alive is not None \
                else self._seed_lease(src)
            seed.add_replica(node.prepare_fork(rinst, lease=lease))
            added += 1
            if telemetry:
                self._lease_event(func, "rereplicated")
        return added

    def _seed_lease(self, handle: ForkHandle) -> Optional[float]:
        """The lease duration a replacement replica should inherit."""
        rt = handle.runtime
        entry = rt.seeds.get(handle.handler_id) if rt is not None else None
        return entry.lease_duration if entry is not None \
            else DEFAULT_SEED_KEEPALIVE

    # -- lease-driven recovery (the fault plane's rung 2) ---------------------

    def _make_recovery(self, func: str):
        """Build the ``ModelInstance.recover_owner`` hook for a forked child
        of ``func``: when a remote read fails and no sibling replica can
        serve (``repro.core.instance._recover_group`` rung 1), the
        coordinator re-replicates the seed — replacement replicas inherit
        the survivors' lease via ``_seed_lease`` — or redeploys it from
        pristine state, then re-stamps the VMA's missing pages onto a live
        parent.  Returns True iff the child can retry its read."""
        def recover(inst: ModelInstance, vma: VMA, lost_owner: str) -> bool:
            seed = self._fresh_seed(func)
            if seed is None:
                if not self.auto_seed:
                    return False
                try:
                    seed = self.deploy_seed(func, replicas=self.seed_replicas,
                                            placement=self.seed_placement)
                except (NoNodesAvailable, TransportError):
                    return False
                self._lease_event(func, "reseeded")
            elif (isinstance(seed, ShardedSeed)
                    and seed.replicas < seed.target_replicas):
                # heal the shard set now, not at the next gc() tick — the
                # restamp below then has a spare replica to point at
                self._replicate(func, seed)
            return self._restamp_from_seed(inst, vma, seed, lost_owner)
        return recover

    def _restamp_from_seed(self, inst: ModelInstance, vma: VMA, seed: Seed,
                           lost_owner: str) -> bool:
        """Point ``vma``'s still-missing remote pages at a live seed
        replica: fetch that replica's descriptor (minting a fresh DC key)
        and rewrite the route — frames, hop-1 owner, DC key, ancestry —
        for the missing remote pages ONLY.  Resident and COW-dirty pages
        are untouched, so a half-fetched VMA keeps its local state
        (idempotent: re-running the restamp moves no extra bytes and
        never double-charges the pagetable)."""
        net = self.network
        for h in _seed_handles(seed):
            if h.parent_node not in net.nodes or h.parent_node == lost_owner:
                continue
            try:
                desc = h.fetch_descriptor(inst.node, ForkPolicy())
            except (TransportError, PermissionError):
                continue
            table = next((vd for vd in desc.vmas
                          if vd["name"] == vma.name), None)
            key = desc.extra.get("prepared_keys", {}).get(vma.name)
            if table is None or key is None:
                continue
            fresh = VMA.from_table_dict(table)
            # only pages the replica itself owns (hop 0 there) can be
            # served at hop 1 here; a replica mid-restore contributes what
            # it has and the next handle covers the rest on a later rung
            remote = (vma.missing_mask() & (vma.owner_hop >= 1)
                      & (fresh.owner_hop == 0))
            if not remote.any():
                continue
            vma.frames[remote] = fresh.frames[remote]
            vma.owner_hop[remote] = 1
            vma.dc_keys[1] = key
            vma.ancestry = [h.parent_node] + list(desc.ancestry)
            vma.version += 1
            net.meter["recovery.reseed_fetches"] += 1
            return True
        return False

    def acquire_instance(self, func: str, *, node: Optional[NodeRuntime] = None,
                         policy: str = "fork", lazy: bool = True,
                         prefetch: int = 1):
        """Start (or reuse) a container for `func` without executing it.
        policy: fork | cache | coldstart."""
        node = node or self.pick_node(func=func)
        inst = None
        if policy == "cache":
            pool = self.cached.get(func, [])
            # local cached instance (unpause): only usable on its own node;
            # husks (freed underneath the pool, e.g. by seed-expiry GC with
            # free_instance=True) are dropped, never handed out
            self.cached[func] = pool = [(c, ts) for c, ts in pool if c.aspace]
            for i, (cand, ts) in enumerate(pool):
                if cand.node is node:
                    inst = pool.pop(i)[0]
                    break
        if inst is None and policy == "fork":
            seed = self._fresh_seed(func)
            if seed is not None:
                try:
                    inst = seed.resume_on(node, ForkPolicy(
                        lazy=lazy, prefetch=prefetch,
                        reroute_backlog=self.reroute_backlog))
                except TransportError:
                    # every usable replica died between the freshness check
                    # and the descriptor fetch — degrade to coldstart below.
                    # Lease violations (PermissionError) stay loud: those are
                    # capability bugs, not infrastructure faults.
                    inst = None
                if isinstance(seed, ShardedSeed):
                    # a replica can die between the freshness check and the
                    # fetch; the resume re-routes and records the victim
                    self._count_lost(func, seed.drain_lost())
                if inst is not None:
                    inst.recover_owner = self._make_recovery(func)
        if inst is None:
            inst = self.coldstart(func, node)
        return inst

    def invoke(self, func: str, inputs: Optional[dict] = None, *,
               node: Optional[NodeRuntime] = None, policy: str = "fork",
               lazy: bool = True, prefetch: int = 1) -> tuple:
        """Returns (outputs, instance). policy: fork | cache | coldstart."""
        inst = self.acquire_instance(func, node=node, policy=policy,
                                     lazy=lazy, prefetch=prefetch)
        out = self.functions[func].behavior(inst, inputs or {})
        return out, inst

    def release(self, func: str, inst: ModelInstance, policy: str) -> None:
        """Post-execution: caching keeps the container; fork frees the child
        (§6.2: children are never cached).  An instance pinned as the
        platform seed is NOT freed here — the seed store owns it until its
        lease expires (coldstart registers the first container as seed, and
        freeing it would yank the live seed out from under later forks)."""
        if policy == "cache":
            self.cached.setdefault(func, []).append((inst, self.clock()))
        elif not self._pinned_as_seed(inst):
            inst.free()

    def _pinned_as_seed(self, inst: ModelInstance) -> bool:
        for seed in self.seed_store.values():
            for handle in _seed_handles(seed):
                node = self.nodes.get(handle.parent_node)
                entry = node.seeds.get(handle.handler_id) \
                    if node is not None else None
                if entry is not None and entry.instance is inst:
                    return True
        return False

    # -- lifecycle / GC -------------------------------------------------------

    def _seed_fresh(self, seed: Seed) -> bool:
        # alive: the node-side dangling-seed GC may have reclaimed the seed
        # (MAX_FUNCTION_LIFETIME) while the store still holds the handle —
        # treat that as stale so invokes fall back to coldstart.  A sharded
        # seed is fresh while ANY replica can serve.
        return any(h.parent_node in self.network.nodes
                   and h.alive and not h.expired
                   for h in _seed_handles(seed))

    def _purge_lost(self, func: str) -> Optional[Seed]:
        """THE loss-accounting site: purge ``func``'s seed replicas whose
        parent dropped out of the network, telemeter each loss as
        ``parent_lost`` exactly once, and drop a fully lost seed from the
        store.  Every lifecycle pass (_fresh_seed, _live_handle, gc) goes
        through here FIRST, so a crashed parent is never misattributed to
        the "reclaimed" bucket just because its cleared seed table also
        reads as not-alive.  Returns the surviving seed, else None."""
        seed = self.seed_store.get(func)
        if seed is None:
            return None
        if isinstance(seed, ShardedSeed):
            seed.purge_lost(self.network.nodes)
            self._count_lost(func, seed.drain_lost())
            if seed.replicas == 0:
                del self.seed_store[func]
                return None
        elif seed.parent_node not in self.network.nodes:
            san = self.network.sanitizer
            if san is not None:
                san.parent_lost(func, seed.parent_node)
            del self.seed_store[func]
            self._lease_event(func, "parent_lost")
            return None
        return seed

    def _fresh_seed(self, func: str) -> Optional[Seed]:
        """The store's seed for ``func`` iff it can serve a fork right now.
        A replica whose parent dropped out of the network is purged ON
        SIGHT (not left for gc to eventually notice) and telemetered as
        ``parent_lost``; a fully lost seed leaves the store immediately."""
        seed = self._purge_lost(func)
        if seed is None:
            return None
        return seed if self._seed_fresh(seed) else None

    def _live_handle(self, func: str) -> Optional[Seed]:
        """The store's seed for ``func`` iff it is still registered at (at
        least one) parent; a seed reclaimed underneath the store is dropped
        (and telemetered as "reclaimed")."""
        seed = self._purge_lost(func)
        if seed is None:
            return None
        if not seed.alive:
            del self.seed_store[func]
            self._lease_event(func, "reclaimed")
            return None
        return seed

    def renew_seed(self, func: str) -> None:
        handle = self._live_handle(func)
        if handle is None:
            return
        handle.renew()
        self._lease_event(func, "renewals")

    def revoke_seed(self, func: str) -> Optional[ForkHandle]:
        """Invalidate every outstanding handle for ``func``'s seed (bump its
        generation); the store keeps serving through the fresh handle.
        Returns None if there is nothing to revoke (no seed, or reclaimed
        underneath the store — dropped like renew_seed does)."""
        handle = self._live_handle(func)
        if handle is None:
            return None
        fresh = handle.revoke()
        self.seed_store[func] = fresh
        self._lease_event(func, "revocations")
        return fresh

    def gc(self) -> dict:
        """Timeout-based reclamation: expired long-lived seeds, stale cached
        containers, and node-side dangling short-lived seeds (§6.3).  The
        returned dict also carries the accumulated lease telemetry:
        ``lease`` (per-function renew/expiry/revocation counters) and
        ``lease_nodes`` (per-node parent-side counters)."""
        now = self.clock()
        freed = {"seeds": 0, "cached": 0, "dangling": 0, "rereplicated": 0}
        for func in list(self.seed_store):
            seed = self._purge_lost(func)
            if seed is None:
                freed["seeds"] += 1
                continue
            if isinstance(seed, ShardedSeed):
                for h in list(seed.handles):
                    if h.expired or not h.alive:
                        self._lease_event(
                            func, "expiries" if h.expired else "reclaimed")
                        h.reclaim(free_instance=True)  # no-op if already gone
                        seed.handles.remove(h)
                if not seed.handles:
                    del self.seed_store[func]
                    freed["seeds"] += 1
                else:
                    # heal the shard set back to its target replica count
                    freed["rereplicated"] += self._replicate(func, seed)
                continue
            if seed.expired or not seed.alive:
                self._lease_event(
                    func, "expiries" if seed.expired else "reclaimed")
                seed.reclaim(free_instance=True)   # no-op if already gone
                del self.seed_store[func]
                freed["seeds"] += 1
        for func, pool in self.cached.items():
            keep = []
            for inst, ts in pool:
                if now - ts >= self.cache_keepalive:
                    if inst.aspace and not self._pinned_as_seed(inst):
                        inst.free()
                    freed["cached"] += 1
                else:
                    keep.append((inst, ts))
            self.cached[func] = keep
        # invoker-side fault tolerance: GC seeds past max function lifetime
        for node in self.nodes.values():
            for hid, entry in list(node.seeds.items()):
                if now - entry.created >= MAX_FUNCTION_LIFETIME:
                    node.reclaim_seed(hid, free_instance=False)
                    freed["dangling"] += 1
        freed["lease"] = {f: dict(c) for f, c in self.lease_telemetry.items()}
        freed["lease_nodes"] = {i: dict(n.lease_stats)
                                for i, n in self.nodes.items()}
        return freed

    # -- fork trees (short-lived seeds, §6.3) -----------------------------------

    def tree_open(self, wf_id: str, root: ForkTreeNode) -> None:
        self.fork_trees[wf_id] = root

    def tree_close(self, wf_id: str) -> None:
        """Reclaim every short-lived seed in the tree except the root."""
        root = self.fork_trees.pop(wf_id, None)
        if root is None:
            return

        def walk(n: ForkTreeNode, is_root: bool):
            for c in n.children:
                walk(c, False)
            if not is_root and n.handle is not None:
                n.handle.reclaim()

        walk(root, True)

    def memory_by_node(self) -> Dict[str, int]:
        return {i: n.memory_bytes() for i, n in self.nodes.items()}
