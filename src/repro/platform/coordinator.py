"""Fork-aware serverless coordinator (§6): seed store, long/short-lived seed
management, fork trees, timeout GC, and startup-policy dispatch.

"Functions" are model instances + a behavior callable; the coordinator
schedules them onto invoker nodes, accelerating startup via long-lived seeds
and state transfer via short-lived seeds, exactly mirroring the paper's Fn
integration.

The seed store holds leased ``ForkHandle`` capabilities (repro.fork): lease
freshness, renewal and reclamation all go through the handle instead of the
old raw (handler_id, auth_key) SeedRecord tuples.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.core.instance import ModelInstance
from repro.fork import ForkHandle, ForkPolicy
from repro.platform.node import NodeRuntime

DEFAULT_SEED_KEEPALIVE = 600.0      # §6.2: 10 min vs caching's 1 min
DEFAULT_CACHE_KEEPALIVE = 30.0      # Fn caches coldstarted containers 30 s
MAX_FUNCTION_LIFETIME = 900.0       # §6.3: AWS-style 15 min upper bound


@dataclasses.dataclass
class FunctionDef:
    name: str
    arch: str
    make_params: Callable[[], Any]          # builds the pristine state
    behavior: Callable[[ModelInstance, dict], dict]
    exec_sim_time: float = 0.0              # modeled pure-exec seconds


@dataclasses.dataclass
class ForkTreeNode:
    func: str
    node_id: str
    handle: Optional[ForkHandle]
    children: List["ForkTreeNode"] = dataclasses.field(default_factory=list)


class Coordinator:
    def __init__(self, network, nodes: List[NodeRuntime], clock=time.monotonic):
        self.network = network
        self.nodes = {n.node_id: n for n in nodes}
        self.clock = clock
        self.functions: Dict[str, FunctionDef] = {}
        self.seed_store: Dict[str, ForkHandle] = {}    # func -> leased handle
        self.fork_trees: Dict[str, ForkTreeNode] = {}
        self.cached: Dict[str, List[tuple]] = {}       # func -> [(inst, ts)]
        # per-function lease churn (renewals/expiries/revocations) for
        # fig20-style spike replays; surfaced by gc()
        self.lease_telemetry: Dict[str, Counter] = {}
        self._rr = 0

    def _lease_event(self, func: str, event: str, n: int = 1) -> None:
        self.lease_telemetry.setdefault(func, Counter())[event] += n

    # -- registry ---------------------------------------------------------

    def register_function(self, fdef: FunctionDef) -> None:
        self.functions[fdef.name] = fdef

    def pick_node(self, exclude=()) -> NodeRuntime:
        ids = [i for i in self.nodes if self.nodes[i].alive and i not in exclude]
        if not ids:
            raise RuntimeError("no live nodes")
        node = self.nodes[ids[self._rr % len(ids)]]
        self._rr += 1
        return node

    # -- startup paths ------------------------------------------------------

    def coldstart(self, func: str, node: NodeRuntime) -> ModelInstance:
        fdef = self.functions[func]
        params = fdef.make_params()
        inst = ModelInstance.create(node, fdef.arch, params, kind="weights")
        # §6.2: cache only the FIRST coldstart container platform-wide as seed
        if func not in self.seed_store:
            self.deploy_seed(func, node, instance=inst)
        return inst

    def deploy_seed(self, func: str, node: NodeRuntime,
                    instance: Optional[ModelInstance] = None,
                    long_lived: bool = True,
                    keep_alive: float = DEFAULT_SEED_KEEPALIVE) -> ForkHandle:
        fdef = self.functions[func]
        if instance is None:
            instance = ModelInstance.create(node, fdef.arch, fdef.make_params(),
                                            kind="weights")
        handle = node.prepare_fork(instance, lease=keep_alive)
        if long_lived:
            self.seed_store[func] = handle
        return handle

    def acquire_instance(self, func: str, *, node: Optional[NodeRuntime] = None,
                         policy: str = "fork", lazy: bool = True,
                         prefetch: int = 1):
        """Start (or reuse) a container for `func` without executing it.
        policy: fork | cache | coldstart."""
        node = node or self.pick_node()
        inst = None
        if policy == "cache":
            pool = self.cached.get(func, [])
            # local cached instance (unpause): only usable on its own node;
            # husks (freed underneath the pool, e.g. by seed-expiry GC with
            # free_instance=True) are dropped, never handed out
            self.cached[func] = pool = [(c, ts) for c, ts in pool if c.aspace]
            for i, (cand, ts) in enumerate(pool):
                if cand.node is node:
                    inst = pool.pop(i)[0]
                    break
        if inst is None and policy == "fork":
            handle = self.seed_store.get(func)
            if handle is not None and self._seed_fresh(handle):
                inst = handle.resume_on(node, ForkPolicy(lazy=lazy,
                                                         prefetch=prefetch))
        if inst is None:
            inst = self.coldstart(func, node)
        return inst

    def invoke(self, func: str, inputs: Optional[dict] = None, *,
               node: Optional[NodeRuntime] = None, policy: str = "fork",
               lazy: bool = True, prefetch: int = 1) -> tuple:
        """Returns (outputs, instance). policy: fork | cache | coldstart."""
        inst = self.acquire_instance(func, node=node, policy=policy,
                                     lazy=lazy, prefetch=prefetch)
        out = self.functions[func].behavior(inst, inputs or {})
        return out, inst

    def release(self, func: str, inst: ModelInstance, policy: str) -> None:
        """Post-execution: caching keeps the container; fork frees the child
        (§6.2: children are never cached).  An instance pinned as the
        platform seed is NOT freed here — the seed store owns it until its
        lease expires (coldstart registers the first container as seed, and
        freeing it would yank the live seed out from under later forks)."""
        if policy == "cache":
            self.cached.setdefault(func, []).append((inst, self.clock()))
        elif not self._pinned_as_seed(inst):
            inst.free()

    def _pinned_as_seed(self, inst: ModelInstance) -> bool:
        for handle in self.seed_store.values():
            node = self.nodes.get(handle.parent_node)
            entry = node.seeds.get(handle.handler_id) if node is not None else None
            if entry is not None and entry.instance is inst:
                return True
        return False

    # -- lifecycle / GC -------------------------------------------------------

    def _seed_fresh(self, handle: ForkHandle) -> bool:
        # alive: the node-side dangling-seed GC may have reclaimed the seed
        # (MAX_FUNCTION_LIFETIME) while the store still holds the handle —
        # treat that as stale so invokes fall back to coldstart.
        return (handle.parent_node in self.network.nodes
                and handle.alive and not handle.expired)

    def _live_handle(self, func: str) -> Optional[ForkHandle]:
        """The store's handle for ``func`` iff its seed is still registered
        at the parent; a handle reclaimed underneath the store is dropped
        (and telemetered as "reclaimed")."""
        handle = self.seed_store.get(func)
        if handle is None:
            return None
        if not handle.alive:
            del self.seed_store[func]
            self._lease_event(func, "reclaimed")
            return None
        return handle

    def renew_seed(self, func: str) -> None:
        handle = self._live_handle(func)
        if handle is None:
            return
        handle.renew()
        self._lease_event(func, "renewals")

    def revoke_seed(self, func: str) -> Optional[ForkHandle]:
        """Invalidate every outstanding handle for ``func``'s seed (bump its
        generation); the store keeps serving through the fresh handle.
        Returns None if there is nothing to revoke (no seed, or reclaimed
        underneath the store — dropped like renew_seed does)."""
        handle = self._live_handle(func)
        if handle is None:
            return None
        fresh = handle.revoke()
        self.seed_store[func] = fresh
        self._lease_event(func, "revocations")
        return fresh

    def gc(self) -> dict:
        """Timeout-based reclamation: expired long-lived seeds, stale cached
        containers, and node-side dangling short-lived seeds (§6.3).  The
        returned dict also carries the accumulated lease telemetry:
        ``lease`` (per-function renew/expiry/revocation counters) and
        ``lease_nodes`` (per-node parent-side counters)."""
        now = self.clock()
        freed = {"seeds": 0, "cached": 0, "dangling": 0}
        for func, handle in list(self.seed_store.items()):
            if handle.expired or not handle.alive:
                self._lease_event(
                    func, "expiries" if handle.expired else "reclaimed")
                handle.reclaim(free_instance=True)   # no-op if already gone
                del self.seed_store[func]
                freed["seeds"] += 1
        for func, pool in self.cached.items():
            keep = []
            for inst, ts in pool:
                if now - ts >= DEFAULT_CACHE_KEEPALIVE:
                    if inst.aspace and not self._pinned_as_seed(inst):
                        inst.free()
                    freed["cached"] += 1
                else:
                    keep.append((inst, ts))
            self.cached[func] = keep
        # invoker-side fault tolerance: GC seeds past max function lifetime
        for node in self.nodes.values():
            for hid, entry in list(node.seeds.items()):
                if now - entry.created >= MAX_FUNCTION_LIFETIME:
                    node.reclaim_seed(hid, free_instance=False)
                    freed["dangling"] += 1
        freed["lease"] = {f: dict(c) for f, c in self.lease_telemetry.items()}
        freed["lease_nodes"] = {i: dict(n.lease_stats)
                                for i, n in self.nodes.items()}
        return freed

    # -- fork trees (short-lived seeds, §6.3) -----------------------------------

    def tree_open(self, wf_id: str, root: ForkTreeNode) -> None:
        self.fork_trees[wf_id] = root

    def tree_close(self, wf_id: str) -> None:
        """Reclaim every short-lived seed in the tree except the root."""
        root = self.fork_trees.pop(wf_id, None)
        if root is None:
            return

        def walk(n: ForkTreeNode, is_root: bool):
            for c in n.children:
                walk(c, False)
            if not is_root and n.handle is not None:
                n.handle.reclaim()

        walk(root, True)

    def memory_by_node(self) -> Dict[str, int]:
        return {i: n.memory_bytes() for i, n in self.nodes.items()}
