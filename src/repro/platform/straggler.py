"""Straggler mitigation via backup forks.

The coordinator tracks per-worker step latencies; when a worker's EWMA
exceeds `threshold` x the cluster median, its shard is BACKUP-FORKED onto a
spare node (remote fork: descriptor + on-demand pages — no checkpoint
read), and whichever replica reports first wins.  This is the paper's
O(1)-provisioning argument applied to straggler handling: no standby
replicas are kept warm; the seed is enough.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Callable, Dict, List, Optional

from repro.fork import ForkHandle, ForkPolicy


@dataclasses.dataclass
class WorkerStat:
    node_id: str
    ewma_s: float = 0.0
    steps: int = 0


class StragglerMonitor:
    def __init__(self, network, threshold: float = 2.0, alpha: float = 0.4,
                 min_steps: int = 3):
        self.network = network
        self.threshold = threshold
        self.alpha = alpha
        self.min_steps = min_steps
        self.stats: Dict[str, WorkerStat] = {}
        self.backups: Dict[str, str] = {}       # straggler -> backup node

    def report(self, node_id: str, step_seconds: float) -> None:
        st = self.stats.setdefault(node_id, WorkerStat(node_id))
        st.ewma_s = (step_seconds if st.steps == 0
                     else self.alpha * step_seconds + (1 - self.alpha) * st.ewma_s)
        st.steps += 1

    def stragglers(self) -> List[str]:
        ready = [s for s in self.stats.values() if s.steps >= self.min_steps]
        if len(ready) < 2:
            return []
        med = statistics.median(s.ewma_s for s in ready)
        return [s.node_id for s in ready
                if med > 0 and s.ewma_s > self.threshold * med
                and s.node_id not in self.backups]

    def mitigate(self, straggler_id: str, handle: ForkHandle,
                 spare_node) -> object:
        """Backup-fork the straggler's worker state (its prepared seed
        handle) onto a spare node."""
        child = handle.resume_on(spare_node, ForkPolicy(lazy=True, prefetch=1))
        self.backups[straggler_id] = spare_node.node_id
        return child

    def resolve(self, straggler_id: str, winner: str) -> None:
        self.backups.pop(straggler_id, None)
        if winner != straggler_id and straggler_id in self.stats:
            del self.stats[straggler_id]
