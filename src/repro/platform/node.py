"""NodeRuntime — one "machine" (kernel) in the MITOSIS cluster.

Hosts the page pool, prepared seeds, the DC-target pool (pooled, refilled in
the background per §5.4), the sibling page cache, the fallback daemon, and
swap-out (the VA->PA-change corner case that exercises connection-based
access control).
"""
from __future__ import annotations

import itertools
import math
import secrets
import time
from collections import Counter, OrderedDict
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.memory.pool import PAGE_ELEMS, PagePool
from repro.net import AccessRevoked, AuthError, LeaseExpired, SeedGone

DEFAULT_PAGE_CACHE_CAP = 65536     # sibling-cache entries (pages), LRU-bounded


class SeedEntry:
    def __init__(self, descriptor, blob, auth_key, instance, keys, created,
                 lease_deadline: float = math.inf,
                 lease_duration: Optional[float] = None, generation: int = 0,
                 desc_key: int = -1):
        self.descriptor = descriptor
        self.blob = blob
        self.auth_key = auth_key
        self.instance = instance
        self.keys = keys                  # vma name -> DC key
        self.desc_key = desc_key          # DC key guarding the blob itself
        self.created = created
        self.lease_deadline = lease_deadline   # absolute (this node's clock)
        self.lease_duration = lease_duration   # seconds; None = unbounded
        self.generation = generation           # bumped by revoke_seed
        self.forks = 0


class NodeRuntime:
    def __init__(self, node_id: str, network, page_elems: int = PAGE_ELEMS,
                 cache_enabled: bool = False,
                 clock=time.monotonic,  # sim-ok: wall-clock -- host default; replays pass SimClock

                 page_cache_cap: int = DEFAULT_PAGE_CACHE_CAP,
                 page_cache_cap_bytes: Optional[int] = None,
                 pool_frames: int = 0, device_pool: bool = False,
                 kernel_backend: str = "auto"):
        self.node_id = node_id
        self.network = network
        # pool_frames pre-reserves physical-frame capacity (lazily zeroed),
        # so replay clusters that churn thousands of containers never pay
        # pool-growth copies mid-run.  device_pool=True holds frames on
        # device and routes the pool's data plane through the
        # page_gather/cow_scatter kernels (kernel_backend selects the impl
        # via kernels.dispatch; the chosen impl surfaces in network.meter).
        self.pool = PagePool(page_elems, initial_frames=pool_frames,
                             device=device_pool,
                             kernel_backend=kernel_backend,
                             meter=network.meter)
        self.clock = clock
        self.instances: Dict[int, "object"] = {}
        self.seeds: Dict[int, SeedEntry] = {}
        self.cache_enabled = cache_enabled
        self._page_cache: "OrderedDict[tuple, int]" = OrderedDict()
        # reverse index (dtype, local_frame) -> cache key, so freeing an
        # instance invalidates its frames in O(frames), not O(cache)
        self._page_cache_rev: Dict[tuple, tuple] = {}
        self._page_cache_bytes = 0
        self.page_cache_cap = page_cache_cap
        self.page_cache_cap_bytes = page_cache_cap_bytes  # None = unbounded
        self.page_cache_stats = {"hits": 0, "misses": 0, "evictions": 0}
        # per-node lease telemetry (renewals/expiries/revocations), rolled
        # up per-function by Coordinator.gc()
        self.lease_stats = Counter()
        self._dc_pool: list = []
        self._swapped: Dict[tuple, np.ndarray] = {}
        self._iid = itertools.count()
        self._hid = itertools.count(1)
        self.alive = True
        network.register(self)

    def new_instance_id(self) -> int:
        return next(self._iid)

    # -- DC target pooling (§5.4: creation amortized via pooling) -------------

    def refill_dc_pool(self, n: int) -> None:
        for _ in range(n):
            self._dc_pool.append(self.network.create_dc_target(self.node_id))

    def take_dc_target(self) -> int:
        if self._dc_pool:
            return self._dc_pool.pop()
        return self.network.create_dc_target(self.node_id)

    # -- seed registry ---------------------------------------------------------

    def prepare_fork(self, instance, lease: Optional[float] = None):
        """Prepare ``instance`` as a seed and mint its leased capability
        (``ForkHandle``).  ``lease`` is a duration in seconds; None means
        unbounded.  The handle is a context manager (reclaim on exit)."""
        from repro.fork.handle import prepare_fork as _prepare
        return _prepare(self, instance, lease=lease)

    def register_seed(self, handler_id: int, entry: SeedEntry) -> None:
        san = self.network.sanitizer
        if san is not None:
            san.lease_register(self.node_id, handler_id)
        self.seeds[handler_id] = entry

    def auth_seed(self, handler_id: int, auth_key: int,
                  generation: int = 0) -> dict:
        """Authentication RPC (§5.2 + rFaaS leases): validates the id/key,
        the handle's revocation generation and the lease deadline, then
        returns the descriptor's size and DC key for the follow-up read."""
        e = self.seeds.get(handler_id)
        if e is None or e.auth_key != auth_key:
            raise AuthError(f"bad seed credentials for {handler_id}")
        if generation != e.generation:
            raise AccessRevoked(
                f"seed {handler_id}: handle generation {generation} revoked "
                f"(current {e.generation})")
        if self.clock() >= e.lease_deadline:
            self.lease_stats["expiries"] += 1
            raise LeaseExpired(
                f"seed {handler_id}: lease expired at {e.lease_deadline:.3f}")
        e.forks += 1
        return {"nbytes": len(e.blob), "desc_key": e.desc_key}

    def renew_seed(self, handler_id: int,
                   extend: Optional[float] = None) -> float:
        """Extend a seed's lease by ``extend`` seconds (default: its
        original lease duration) and refresh its creation stamp (renewal is
        a keepalive).  Returns the new absolute deadline."""
        if extend is not None and extend <= 0:
            raise ValueError(
                f"extend must be positive seconds or None, got {extend!r}")
        e = self.seeds.get(handler_id)
        if e is None:
            raise SeedGone(f"seed {handler_id} is not registered "
                           "(already reclaimed?)")
        duration = extend if extend is not None else e.lease_duration
        now = self.clock()
        e.created = now
        e.lease_deadline = math.inf if duration is None else now + duration
        self.lease_stats["renewals"] += 1
        san = self.network.sanitizer
        if san is not None:
            san.lease_renew(self.node_id, handler_id)
        return e.lease_deadline

    def revoke_seed(self, handler_id: int) -> int:
        """Bump the seed's revocation generation: every outstanding handle
        dies at the next auth.  The descriptor's DC key is rotated too, so
        a revoked holder who learned it at an earlier auth can no longer
        read the blob (and harvest the VMA keys inside) — the fresh
        generation re-learns the new key at auth.  Returns the new
        generation."""
        e = self.seeds[handler_id]
        e.generation += 1
        self.network.destroy_dc_target(self.node_id, e.desc_key)
        e.desc_key = self.take_dc_target()
        self.lease_stats["revocations"] += 1
        san = self.network.sanitizer
        if san is not None:
            san.lease_revoke(self.node_id, handler_id)
        return e.generation

    def reclaim_seed(self, handler_id: int,
                     free_instance: bool = False) -> None:
        """Destroy the seed's DC targets and unregister it (idempotent);
        in-flight children fall back to the RPC daemon while pages live."""
        entry = self.seeds.pop(handler_id, None)
        if entry is None:
            return
        san = self.network.sanitizer
        if san is not None:
            san.lease_reclaim(self.node_id, handler_id)
        for key in entry.keys.values():
            self.network.destroy_dc_target(self.node_id, key)
        self.network.destroy_dc_target(self.node_id, entry.desc_key)
        if free_instance and entry.instance is not None:
            entry.instance.free()

    def seed_blob(self, handler_id: int,
                  desc_key: Optional[int] = None) -> bytes:
        """Serve a seed's descriptor blob.  The daemon enforces the blob's
        DC key like the RNIC does for one-sided reads, so a reclaimed
        seed's descriptor raises AccessRevoked over two-sided fabrics too."""
        e = self.seeds.get(handler_id)
        if e is None:
            raise AccessRevoked(f"seed {handler_id} reclaimed; descriptor gone")
        if desc_key is not None \
                and not self.network.target_valid(self.node_id, desc_key):
            raise AccessRevoked(
                f"descriptor DC target {desc_key}@{self.node_id} destroyed")
        return e.blob

    # -- fallback daemon (§5.4) -------------------------------------------------

    def fallback_serve(self, dtype, frames):
        """RPC handler: load pages on behalf of a child (swapped or live).
        One pool gather serves every live frame; swapped-out frames are
        overlaid from "disk" — no per-frame read/stack loop."""
        dt = jnp.dtype(dtype).name
        idx = np.asarray(frames, np.int32).ravel()
        live = np.asarray([(dt, int(f)) not in self._swapped
                           for f in idx.tolist()], bool)
        out = np.zeros((idx.size, self.pool.page_elems), dtype=jnp.dtype(dt))
        if live.all() and idx.size:
            # common case (nothing swapped): run-coalesced gather straight
            # into the reply buffer, no intermediate copy
            self.pool.read_pages_host(dtype, idx, out=out)
        elif live.any():
            out[live] = self.pool.read_pages_host(dtype, idx[live])
        for i in np.nonzero(~live)[0]:
            out[i] = self._swapped[(dt, int(idx[i]))]
        return jnp.asarray(out)

    # -- swap-out: the VA->PA change corner case ---------------------------------

    def swap_out_vma(self, instance, name: str) -> None:
        """Move a VMA's pages to "disk" and destroy its DC targets, so
        children's one-sided reads are rejected and take the fallback path."""
        vma = instance.aspace[name]
        dt = jnp.dtype(vma.dtype).name
        data = np.asarray(self.pool.read_pages(vma.dtype, vma.frames))
        for i, f in enumerate(vma.frames.tolist()):
            self._swapped[(dt, int(f))] = data[i]
        for e in self.seeds.values():
            if e.instance is instance and name in e.keys:
                self.network.destroy_dc_target(self.node_id, e.keys[name])

    # -- sibling page cache (MITOSIS+cache, §5.4 optimizations) -------------------
    # LRU-bounded at page_cache_cap entries AND (optionally) at
    # page_cache_cap_bytes — eviction runs on whichever limit trips first,
    # so multi-dtype workloads with fat pages can't blow past a byte budget
    # that the entry cap alone would allow.  Evictions only forget the
    # mapping (the frames stay owned by whichever instance fetched them).

    def _page_cache_entry_bytes(self, key: tuple) -> int:
        return self.pool.page_elems * np.dtype(key[1]).itemsize

    def page_cache_bytes(self) -> int:
        return self._page_cache_bytes

    def page_cache_get(self, owner: str, dtype: str, frame: int) -> Optional[int]:
        if not self.cache_enabled:
            return None
        key = (owner, jnp.dtype(dtype).name, int(frame))
        local = self._page_cache.get(key)
        if local is None:
            self.page_cache_stats["misses"] += 1
            return None
        self._page_cache.move_to_end(key)
        self.page_cache_stats["hits"] += 1
        return local

    def page_cache_get_many(self, owner: str, dtype: str,
                            frames) -> np.ndarray:
        """Batched probe: int32 array of local frames, -1 per miss.  One
        call per fault instead of one per page — the dict walk stays, the
        per-page Python call/stat churn goes."""
        idx = np.asarray(frames, np.int64).ravel()
        out = np.full(idx.size, -1, np.int32)
        if not self.cache_enabled:
            return out
        dt = jnp.dtype(dtype).name
        cache = self._page_cache
        for i, f in enumerate(idx.tolist()):
            key = (owner, dt, int(f))
            local = cache.get(key)
            if local is not None:
                cache.move_to_end(key)
                out[i] = local
        hits = int((out >= 0).sum())
        self.page_cache_stats["hits"] += hits
        self.page_cache_stats["misses"] += idx.size - hits
        return out

    def page_cache_put_many(self, owner: str, dtype: str, frames,
                            locals_) -> None:
        """Batched insert: one call per fault; eviction policy unchanged."""
        if not self.cache_enabled:
            return
        for f, lf in zip(np.asarray(frames).tolist(),
                         np.asarray(locals_).tolist()):
            self.page_cache_put(owner, dtype, int(f), int(lf))

    def page_cache_put(self, owner: str, dtype: str, frame: int, local: int) -> None:
        if not self.cache_enabled:
            return
        key = (owner, jnp.dtype(dtype).name, int(frame))
        old_local = self._page_cache.get(key)
        if old_local is None:
            self._page_cache_bytes += self._page_cache_entry_bytes(key)
        else:
            self._page_cache_rev.pop((key[1], old_local), None)
        rev_key = (key[1], int(local))
        shadowed = self._page_cache_rev.get(rev_key)
        if shadowed is not None:
            # another entry already maps this local frame; evict it rather
            # than leave it un-invalidatable when the frame is freed
            del self._page_cache[shadowed]
            self._page_cache_bytes -= self._page_cache_entry_bytes(shadowed)
            self.page_cache_stats["evictions"] += 1
        self._page_cache[key] = local
        self._page_cache_rev[rev_key] = key
        self._page_cache.move_to_end(key)
        while len(self._page_cache) > self.page_cache_cap or (
                self.page_cache_cap_bytes is not None
                and self._page_cache_bytes > self.page_cache_cap_bytes):
            old_key, old_local = self._page_cache.popitem(last=False)
            self._page_cache_rev.pop((old_key[1], old_local), None)
            self._page_cache_bytes -= self._page_cache_entry_bytes(old_key)
            self.page_cache_stats["evictions"] += 1

    def page_cache_invalidate_frames(self, dtype: str, frames) -> None:
        """Drop cache entries whose LOCAL frame is being returned to the
        pool (the fetching instance freed it) — a later alloc may reuse the
        frame index for unrelated data, so serving it would be silent
        corruption."""
        dt = jnp.dtype(dtype).name
        for f in frames:
            key = self._page_cache_rev.pop((dt, int(f)), None)
            if key is not None:
                del self._page_cache[key]
                self._page_cache_bytes -= self._page_cache_entry_bytes(key)

    def page_cache_drop_owner_frames(self, owner: str, dtype: str,
                                     frames) -> None:
        """Drop cache entries keyed on the OWNER's frames — broadcast by the
        network when the owner frees them, since a reused owner frame would
        make the (owner, dtype, frame) key serve a different seed's data."""
        dt = jnp.dtype(dtype).name
        for f in frames:
            key = (owner, dt, int(f))
            local = self._page_cache.pop(key, None)
            if local is not None:
                self._page_cache_rev.pop((dt, local), None)
                self._page_cache_bytes -= self._page_cache_entry_bytes(key)

    def clear_page_cache(self) -> None:
        self._page_cache.clear()
        self._page_cache_rev.clear()
        self._page_cache_bytes = 0

    def page_cache_drop_owner(self, owner: str) -> None:
        """Drop EVERY cache entry keyed on ``owner`` (any dtype, any
        frame) — the fleet-wide forget when a peer fail-stops.  Its frame
        namespace died with it, and a restarted incarnation reusing the
        same frame indices must never be served another seed's bytes."""
        for key in [k for k in self._page_cache if k[0] == owner]:
            local = self._page_cache.pop(key)
            self._page_cache_rev.pop((key[1], local), None)
            self._page_cache_bytes -= self._page_cache_entry_bytes(key)

    # -- failure ------------------------------------------------------------------

    def crash(self) -> None:
        """Fail-stop this node.  The machine's memory dies with it, and so
        must every piece of distributed state that references it:

        * hosted instances become husks (their pool pages are gone; they
          are NOT ``free()``d — free would broadcast invalidations and
          return frames as if the machine were still up) and each one's
          connection refcounts are released;
        * the seed registry empties: outstanding ForkHandles read
          ``alive == False`` and coordinators count the parent as lost;
        * ``network.unregister`` destroys the DC targets and — via
          ``ConnManager.drop_node`` — evicts every QP/DC context with a
          slot here from BOTH endpoints' pools, so peers re-pay setup;
        * every surviving peer drops its sibling page-cache entries keyed
          on this node (``page_cache_drop_owner``).

        Idempotent: a second crash of a dead node is a no-op."""
        if not self.alive:
            return
        self.alive = False
        net = self.network
        if net.sanitizer is not None:
            net.sanitizer.node_crashed(self.node_id)
        for inst in list(self.instances.values()):
            net.conn_release_user(inst._conn_user)
            if inst.prefetch_engine is not None:
                inst.prefetch_engine.discard()
                inst.prefetch_engine = None
            inst._owned_frames.clear()
            inst._tensors.clear()
            inst._tensor_versions.clear()
            inst.aspace = {}
        self.instances.clear()
        self.seeds.clear()
        self.clear_page_cache()
        self._swapped.clear()
        self._dc_pool.clear()
        net.unregister(self.node_id)
        for peer in net.nodes.values():
            drop = getattr(peer, "page_cache_drop_owner", None)
            if drop is not None:
                drop(self.node_id)

    def memory_bytes(self) -> int:
        return 0 if not self.alive else self.pool.bytes_allocated()


def make_auth_key() -> int:
    # sim-ok: unseeded-random -- auth keys are opaque capabilities compared
    # only for equality; they never reach the event log, meters or digests
    return secrets.randbits(62)
