"""NodeRuntime — one "machine" (kernel) in the MITOSIS cluster.

Hosts the page pool, prepared seeds, the DC-target pool (pooled, refilled in
the background per §5.4), the sibling page cache, the fallback daemon, and
swap-out (the VA->PA-change corner case that exercises connection-based
access control).
"""
from __future__ import annotations

import itertools
import secrets
import time
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.memory.pool import PAGE_ELEMS, PagePool


class SeedEntry:
    def __init__(self, descriptor, blob, auth_key, instance, keys, created):
        self.descriptor = descriptor
        self.blob = blob
        self.auth_key = auth_key
        self.instance = instance
        self.keys = keys                  # vma name -> DC key
        self.created = created
        self.forks = 0


class NodeRuntime:
    def __init__(self, node_id: str, network, page_elems: int = PAGE_ELEMS,
                 cache_enabled: bool = False, clock=time.monotonic):
        self.node_id = node_id
        self.network = network
        self.pool = PagePool(page_elems)
        self.clock = clock
        self.instances: Dict[int, "object"] = {}
        self.seeds: Dict[int, SeedEntry] = {}
        self.cache_enabled = cache_enabled
        self._page_cache: Dict[tuple, int] = {}
        self._page_cache_frames: list = []
        self._dc_pool: list = []
        self._swapped: Dict[tuple, np.ndarray] = {}
        self._iid = itertools.count()
        self._hid = itertools.count(1)
        self.alive = True
        network.register(self)

    def new_instance_id(self) -> int:
        return next(self._iid)

    # -- DC target pooling (§5.4: creation amortized via pooling) -------------

    def refill_dc_pool(self, n: int) -> None:
        for _ in range(n):
            self._dc_pool.append(self.network.create_dc_target(self.node_id))

    def take_dc_target(self) -> int:
        if self._dc_pool:
            return self._dc_pool.pop()
        return self.network.create_dc_target(self.node_id)

    # -- seed registry ---------------------------------------------------------

    def register_seed(self, handler_id: int, entry: SeedEntry) -> None:
        self.seeds[handler_id] = entry

    def auth_seed(self, handler_id: int, auth_key: int) -> dict:
        """Authentication RPC (§5.2): validates the id/key, returns the
        descriptor's address+size for the follow-up one-sided read."""
        e = self.seeds.get(handler_id)
        if e is None or e.auth_key != auth_key:
            raise PermissionError(f"bad seed credentials for {handler_id}")
        return {"nbytes": len(e.blob)}

    def seed_blob(self, handler_id: int) -> bytes:
        return self.seeds[handler_id].blob

    # -- fallback daemon (§5.4) -------------------------------------------------

    def fallback_serve(self, dtype, frames):
        """RPC handler: load pages on behalf of a child (swapped or live)."""
        dt = jnp.dtype(dtype).name
        pages = []
        for f in np.asarray(frames).tolist():
            key = (dt, int(f))
            if key in self._swapped:
                pages.append(jnp.asarray(self._swapped[key]))
            else:
                pages.append(self.pool.read_pages(dtype, np.asarray([f], np.int32))[0])
        return jnp.stack(pages)

    # -- swap-out: the VA->PA change corner case ---------------------------------

    def swap_out_vma(self, instance, name: str) -> None:
        """Move a VMA's pages to "disk" and destroy its DC targets, so
        children's one-sided reads are rejected and take the fallback path."""
        vma = instance.aspace[name]
        dt = jnp.dtype(vma.dtype).name
        data = np.asarray(self.pool.read_pages(vma.dtype, vma.frames))
        for i, f in enumerate(vma.frames.tolist()):
            self._swapped[(dt, int(f))] = data[i]
        for e in self.seeds.values():
            if e.instance is instance and name in e.keys:
                self.network.destroy_dc_target(self.node_id, e.keys[name])

    # -- sibling page cache (MITOSIS+cache, §5.4 optimizations) -------------------

    def page_cache_get(self, owner: str, dtype: str, frame: int) -> Optional[int]:
        if not self.cache_enabled:
            return None
        return self._page_cache.get((owner, jnp.dtype(dtype).name, int(frame)))

    def page_cache_put(self, owner: str, dtype: str, frame: int, local: int) -> None:
        if not self.cache_enabled:
            return
        self._page_cache[(owner, jnp.dtype(dtype).name, int(frame))] = local

    def clear_page_cache(self) -> None:
        self._page_cache.clear()

    # -- failure ------------------------------------------------------------------

    def crash(self) -> None:
        self.alive = False
        self.network.unregister(self.node_id)

    def memory_bytes(self) -> int:
        return self.pool.bytes_allocated()


def make_auth_key() -> int:
    return secrets.randbits(62)
