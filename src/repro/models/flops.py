"""Analytic parameter & MODEL_FLOPS accounting (no allocation).

MODEL_FLOPS convention used in EXPERIMENTS.md §Roofline:
  train   : 6 * N_active_nonembed * tokens + 6 * d_model * vocab * tokens (head)
  prefill : 2 * N_active_nonembed * tokens + 2 * d_model * vocab * batch (last-pos head)
  decode  : 2 * N_active_nonembed * batch  + 2 * d_model * vocab * batch
            + attention-score term 2 * 2 * H * hd * kv_len * batch per attn layer
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, AttnSpec, MambaSpec, MLSTMSpec, SLSTMSpec, ShapeConfig


def _attn_block_params(cfg: ArchConfig, spec: AttnSpec, active: bool):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    n = d * h * hd + 2 * d * k * hd + h * hd * d          # q,k,v,o
    if spec.qkv_bias:
        n += h * hd + 2 * k * hd
    if spec.qk_norm:
        n += 2 * hd
    n += d                                                # norm1
    if cfg.moe_experts:
        e = cfg.moe_topk if active else cfg.moe_experts
        per = cfg.d_model * cfg.moe_d_ff * (3 if cfg.mlp_gated else 2)
        n += e * per + cfg.d_model * cfg.moe_experts + d  # experts + router + norm2
    elif cfg.d_ff:
        n += cfg.d_model * cfg.d_ff * (3 if cfg.mlp_gated else 2) + d
    return n


def _mamba_block_params(cfg, spec):
    d = cfg.d_model
    d_inner = spec.expand * d
    H = d_inner // spec.head_dim
    N = spec.d_state
    conv_ch = d_inner + 2 * N
    return (d * (2 * d_inner + 2 * N + H)        # in_proj
            + spec.d_conv * conv_ch + conv_ch    # conv
            + 3 * H                              # A, dt_bias, D
            + d_inner + d_inner * d + d)         # norm, out_proj, norm1


def _mlstm_block_params(cfg, spec):
    d = cfg.d_model
    d_inner = spec.expand * d
    H = spec.num_heads
    return (d * 2 * d_inner + 4 * d_inner + d_inner      # up, conv
            + 3 * d_inner * d_inner                      # q,k,v
            + d_inner * 2 * H + 2 * H                    # gates
            + d_inner + d_inner * d + d)                 # norm, down, norm1


def _slstm_block_params(cfg, spec):
    d = cfg.d_model
    H = spec.num_heads
    dh = d // H
    p = int(spec.proj_factor * d)
    return d * 4 * d + 4 * H * dh * dh + 4 * d + d + d * 2 * p + p * d + d


def block_params(cfg, spec, active=False):
    if isinstance(spec, AttnSpec):
        return _attn_block_params(cfg, spec, active)
    if isinstance(spec, MambaSpec):
        return _mamba_block_params(cfg, spec)
    if isinstance(spec, MLSTMSpec):
        return _mlstm_block_params(cfg, spec)
    if isinstance(spec, SLSTMSpec):
        return _slstm_block_params(cfg, spec)
    raise TypeError(spec)


def param_counts(cfg: ArchConfig):
    """Returns (total, active, embed) param counts."""
    total = active = 0
    for g in cfg.groups:
        shared_seen = set()
        for bi, spec in enumerate(g.unit):
            if getattr(spec, "shared", False):
                if (id(g), bi) not in shared_seen:
                    total += block_params(cfg, spec)
                    active += block_params(cfg, spec, active=True)
                    shared_seen.add((id(g), bi))
            else:
                total += g.repeat * block_params(cfg, spec)
                active += g.repeat * block_params(cfg, spec, active=True)
    embed = cfg.num_codebooks * cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        embed += cfg.d_model * cfg.num_codebooks * cfg.vocab_size
    total += embed + cfg.d_model
    active += embed + cfg.d_model
    return total, active, embed


def model_flops(cfg: ArchConfig, shape: ShapeConfig):
    """MODEL_FLOPS per the §Roofline convention (global, per step)."""
    total, active, embed = param_counts(cfg)
    nonembed_active = active - embed
    head = cfg.d_model * cfg.num_codebooks * cfg.vocab_size
    B, S = shape.global_batch, shape.seq_len
    if shape.step == "train":
        tokens = B * S
        return 6 * nonembed_active * tokens + 6 * head * tokens
    if shape.step == "prefill":
        tokens = B * S
        # causal attention term: 2(QK)+2(AV) * H*hd * S^2/2 per attn layer
        attn = 0
        for spec in cfg.block_specs():
            if isinstance(spec, AttnSpec):
                ctx = min(spec.window, S) if spec.window else S / 2
                attn += 4 * cfg.num_heads * cfg.head_dim * S * ctx * B
        return 2 * nonembed_active * tokens + attn + 2 * head * B
    # decode: one token per sequence
    attn = 0
    for spec in cfg.block_specs():
        if isinstance(spec, AttnSpec):
            ctx = min(spec.window, S) if spec.window else S
            attn += 4 * cfg.num_heads * cfg.head_dim * ctx * B
    return 2 * nonembed_active * B + attn + 2 * head * B
