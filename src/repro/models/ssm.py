"""Mamba2 (State Space Duality) block: chunked-parallel train/prefill path +
O(1)-state decode recurrence.

Follows the SSD formulation (Dao & Gu, 2024): scalar per-head decay A,
per-step dt (softplus), shared B/C projections (ngroups=1), causal depthwise
conv on (x, B, C), gated output with RMSNorm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_rms_norm, ninit, rms_norm, zinit


def _dims(cfg, spec):
    d_inner = spec.expand * cfg.d_model
    nheads = d_inner // spec.head_dim
    return d_inner, nheads, spec.d_state


def init_mamba(key, cfg, spec):
    d, (d_inner, nheads, N) = cfg.d_model, _dims(cfg, spec)
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 5)
    return {
        # [z, x, B, C, dt]
        "in_proj": ninit(ks[0], (d, 2 * d_inner + 2 * N + nheads)),
        "conv_w": ninit(ks[1], (spec.d_conv, conv_ch), scale=0.1),
        "conv_b": zinit((conv_ch,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, nheads))),
        "D": jnp.ones((nheads,)),
        "norm": init_rms_norm(d_inner),
        "out_proj": ninit(ks[2], (d_inner, d)),
    }


def _split_proj(params, x, cfg, spec):
    d_inner, nheads, N = _dims(cfg, spec)
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * N]
    dt = zxbcdt[..., -nheads:]
    return z, xbc, dt


def _conv_scan(params, xbc):
    """Causal depthwise conv over (B, S, C)."""
    w = params["conv_w"].astype(xbc.dtype)                    # (d_conv, C)
    d_conv = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(d_conv))
    return jax.nn.silu(out + params["conv_b"].astype(xbc.dtype))


def mamba_forward(params, x, cfg, spec, chunk=256, return_state=False):
    """x: (B, S, D). Chunked SSD scan; optionally return final SSM+conv state."""
    B, S, D = x.shape
    d_inner, H, N = _dims(cfg, spec)
    P = spec.head_dim
    dt_ = x.dtype

    z, xbc_raw, dt = _split_proj(params, x, cfg, spec)
    xbc = _conv_scan(params, xbc_raw)
    xs = xbc[..., :d_inner].reshape(B, S, H, P)
    Bm = xbc[..., d_inner:d_inner + N]                        # (B,S,N)
    Cm = xbc[..., d_inner + N:]

    # Mamba TP (§Perf): shard heads over `model`. All SSD einsums carry the
    # head dim and never contract it, so the whole chunked scan runs 16-way
    # parallel; B/C (shared across heads) stay replicated; out_proj's
    # contraction over d_inner produces the single Megatron-style AR.
    from repro.distributed.ctx import constrain, get_env
    _env = get_env()
    _tp = _env is not None and getattr(_env, "mamba_tp", False)
    if _tp:
        z = constrain(z, ("dp", None, "model"))
        xs = constrain(xs, ("dp", None, "model", None))
        dt = constrain(dt, ("dp", None, "model"))

    A = -jnp.exp(params["A_log"].astype(jnp.float32))         # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    dA = dt * A                                               # (B,S,H) log-decay

    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S, "seq must be divisible by chunk"

    def r(t):  # (B,S,...) -> (nc,B,c,...)
        return t.reshape((B, nc, chunk) + t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    xs_c, B_c, C_c = r(xs), r(Bm), r(Cm)
    dA_c, dt_c = r(dA), r(dt)

    def chunk_step(state, xs_i):
        x_i, b_i, c_i, da_i, dt_i = xs_i                      # (B,c,...)
        cum = jnp.cumsum(da_i, axis=1)                        # (B,c,H)
        # intra-chunk: y[s] = sum_{j<=s} exp(cum_s - cum_j) dt_j (C_s.B_j) x_j
        seg = cum[:, :, None, :] - cum[:, None, :, :]         # (B,c,c,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        # clamp masked entries BEFORE exp: exp(+large) -> inf would poison
        # the where() gradient with 0*inf = NaN
        decay = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
        cb = jnp.einsum("bsn,bjn->bsj", c_i.astype(jnp.float32),
                        b_i.astype(jnp.float32))
        att = cb[..., None] * decay * dt_i[:, None, :, :]     # (B,c,c,H)
        y = jnp.einsum("bsjh,bjhp->bshp", att, x_i.astype(jnp.float32))
        # contribution of carried state: y += C_s . state * exp(cum_s)
        y = y + jnp.einsum("bsn,bhpn,bsh->bshp", c_i.astype(jnp.float32), state,
                           jnp.exp(cum))
        # new chunk state: state' = exp(cum_end)*state + sum_j exp(cum_end-cum_j) dt_j B_j x_j^T
        dec_end = jnp.exp(cum[:, -1, None, :] - cum)          # (B,c,H)
        sB = jnp.einsum("bjh,bjn,bjhp->bhpn", dec_end * dt_i, b_i.astype(jnp.float32),
                        x_i.astype(jnp.float32))
        state = jnp.exp(cum[:, -1])[:, :, None, None] * state + sB
        return state, y

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    if _tp:
        state0 = constrain(state0, ("dp", "model", None, None))
    state, ys = jax.lax.scan(chunk_step, state0, (xs_c, B_c, C_c, dA_c, dt_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    if _tp:
        y = constrain(y, ("dp", None, "model", None))
    y = y + xs.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), params["norm"]["scale"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    if return_state:
        d_conv = params["conv_w"].shape[0]
        conv_state = jnp.pad(xbc_raw, ((0, 0), (d_conv - 1, 0), (0, 0)))[:, -(d_conv - 1):]
        return out, {"ssd": state.astype(jnp.float32), "conv": conv_state}
    return out


def init_mamba_cache(cfg, spec, batch, dtype):
    d_inner, H, N = _dims(cfg, spec)
    conv_ch = d_inner + 2 * N
    return {
        "ssd": jnp.zeros((batch, H, spec.head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, spec.d_conv - 1, conv_ch), dtype),
    }


def mamba_decode(params, x, cfg, spec, cache):
    """One-step recurrence. x: (B,1,D)."""
    B = x.shape[0]
    d_inner, H, N = _dims(cfg, spec)
    P = spec.head_dim
    dt_ = x.dtype

    z, xbc_raw, dt = _split_proj(params, x, cfg, spec)        # (B,1,*)
    # conv over ring of last d_conv inputs
    hist = jnp.concatenate([cache["conv"], xbc_raw], axis=1)  # (B,d_conv,C)
    w = params["conv_w"].astype(dt_)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w) + params["conv_b"].astype(dt_))
    new_conv = hist[:, 1:]

    xh = xbc[:, :d_inner].reshape(B, H, P).astype(jnp.float32)
    Bm = xbc[:, d_inner:d_inner + N].astype(jnp.float32)
    Cm = xbc[:, d_inner + N:].astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    decay = jnp.exp(dtv * A)                                  # (B,H)
    state = cache["ssd"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtv, Bm, xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm, state)
    y = y + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_inner).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), params["norm"]["scale"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    return out, {"ssd": state, "conv": new_conv}
