"""Unified causal LM over the 10-arch zoo.

Layer stacks are (unit pattern) x repeat groups (configs/base.py).  Params of
each block position in the unit are stacked over `repeat` and applied with
`lax.scan` — HLO size is depth-independent, which keeps 512-device AOT
compiles tractable for 61–88 layer models.  Blocks marked ``shared=True``
(zamba2's attention) hold ONE param set at group level, closed over by the
scan body; their *caches* are still per-application (stacked), exactly like
the paper's distinction between shared parent pages (weights) and private
child state.

API:
  init_params(key, cfg)
  forward(params, cfg, tokens)                       -> hidden (B,S,D)
  loss_fn(params, cfg, tokens, labels)               -> scalar
  prefill(params, cfg, tokens, cache_len)            -> (last_logits, caches)
  decode_step(params, cfg, caches, token, pos)       -> (logits, caches)
  init_cache(cfg, batch, cache_len, dtype)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttnSpec, MambaSpec, MLSTMSpec, SLSTMSpec
from repro.distributed import ctx
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------


def _has_mlp(cfg: ArchConfig, spec) -> bool:
    return isinstance(spec, AttnSpec) and (cfg.d_ff > 0 or cfg.moe_experts > 0)


def init_block(key, cfg, spec):
    ks = jax.random.split(key, 4)
    if isinstance(spec, AttnSpec):
        p = {"norm1": L.init_rms_norm(cfg.d_model),
             "attn": L.init_attention(ks[0], cfg, spec)}
        if _has_mlp(cfg, spec):
            p["norm2"] = L.init_rms_norm(cfg.d_model)
            if cfg.moe_experts:
                p["moe"] = MOE.init_moe(ks[1], cfg)
            else:
                p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated)
        return p
    if isinstance(spec, MambaSpec):
        return {"norm1": L.init_rms_norm(cfg.d_model),
                "mamba": SSM.init_mamba(ks[0], cfg, spec)}
    if isinstance(spec, MLSTMSpec):
        return {"norm1": L.init_rms_norm(cfg.d_model),
                "mlstm": XL.init_mlstm(ks[0], cfg, spec)}
    if isinstance(spec, SLSTMSpec):
        return {"norm1": L.init_rms_norm(cfg.d_model),
                "slstm": XL.init_slstm(ks[0], cfg, spec)}
    raise TypeError(spec)


def apply_block(params, h, cfg, spec, *, mode, positions=None, cache=None,
                pos=None, cache_len=0, q_chunk=1024, exact_causal=False):
    """mode: train | prefill | decode. Returns (h, cache_out_or_None)."""
    eps = cfg.norm_eps
    hn = L.rms_norm(h, params["norm1"]["scale"], eps)
    cache_out = None

    if isinstance(spec, AttnSpec):
        if mode == "train":
            a = L.attention_train(params["attn"], hn, spec, cfg, positions,
                                  q_chunk=q_chunk, exact_causal_slices=exact_causal)
        elif mode == "prefill":
            a, cache_out = L.attention_prefill(params["attn"], hn, spec, cfg,
                                               positions, cache_len, q_chunk=q_chunk)
        else:
            a, cache_out = L.attention_decode(params["attn"], hn, spec, cfg, cache, pos)
        h = h + a
        if _has_mlp(cfg, spec):
            hn2 = L.rms_norm(h, params["norm2"]["scale"], eps)
            if cfg.moe_experts:
                h = h + MOE.moe_mlp(params["moe"], hn2, cfg)
            else:
                h = h + L.mlp(params["mlp"], hn2, cfg.mlp_gated)
        return h, cache_out

    if isinstance(spec, MambaSpec):
        if mode == "decode":
            y, cache_out = SSM.mamba_decode(params["mamba"], hn, cfg, spec, cache)
        elif mode == "prefill":
            y, cache_out = SSM.mamba_forward(params["mamba"], hn, cfg, spec,
                                             return_state=True)
        else:
            y = SSM.mamba_forward(params["mamba"], hn, cfg, spec)
        return h + y, cache_out

    if isinstance(spec, MLSTMSpec):
        if mode == "decode":
            y, cache_out = XL.mlstm_decode(params["mlstm"], hn, cfg, spec, cache)
        elif mode == "prefill":
            y, cache_out = XL.mlstm_forward(params["mlstm"], hn, cfg, spec,
                                            return_state=True)
        else:
            y = XL.mlstm_forward(params["mlstm"], hn, cfg, spec)
        return h + y, cache_out

    if isinstance(spec, SLSTMSpec):
        if mode == "decode":
            y, cache_out = XL.slstm_decode(params["slstm"], hn, cfg, spec, cache)
        elif mode == "prefill":
            y, cache_out = XL.slstm_forward(params["slstm"], hn, cfg, spec,
                                            return_state=True)
        else:
            y = XL.slstm_forward(params["slstm"], hn, cfg, spec)
        return h + y, cache_out

    raise TypeError(spec)


def init_block_cache(cfg, spec, batch, cache_len, dtype):
    if isinstance(spec, AttnSpec):
        return L.init_attn_cache(cfg, spec, batch, cache_len, dtype)
    if isinstance(spec, MambaSpec):
        return SSM.init_mamba_cache(cfg, spec, batch, dtype)
    if isinstance(spec, MLSTMSpec):
        return XL.init_mlstm_cache(cfg, spec, batch, dtype)
    if isinstance(spec, SLSTMSpec):
        return XL.init_slstm_cache(cfg, spec, batch, dtype)
    raise TypeError(spec)


# ---------------------------------------------------------------------------
# params / cache init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig):
    kg = jax.random.split(key, len(cfg.groups) + 2)
    groups = []
    for gi, g in enumerate(cfg.groups):
        kb = jax.random.split(kg[gi], len(g.unit))
        blocks = []
        for bi, spec in enumerate(g.unit):
            if getattr(spec, "shared", False):
                blocks.append(init_block(kb[bi], cfg, spec))
            else:
                bks = jax.random.split(kb[bi], g.repeat)
                blocks.append(jax.vmap(lambda k, s=spec: init_block(k, cfg, s))(bks))
        groups.append({"blocks": blocks})
    params = {
        "embed": L.init_embed(kg[-2], cfg),
        "groups": groups,
        "final_norm": L.init_rms_norm(cfg.d_model),
    }
    if cfg.param_dtype != "float32":
        params = jax.tree.map(lambda x: x.astype(cfg.param_dtype), params)
    return params


def init_cache(cfg: ArchConfig, batch, cache_len, dtype=jnp.bfloat16):
    groups = []
    for g in cfg.groups:
        blocks = []
        for spec in g.unit:
            single = init_block_cache(cfg, spec, batch, cache_len, dtype)
            blocks.append(jax.tree.map(
                lambda x: jnp.zeros((g.repeat,) + x.shape, x.dtype), single))
        groups.append({"blocks": blocks})
    return {"groups": groups}


# ---------------------------------------------------------------------------
# forward / loss / prefill / decode
# ---------------------------------------------------------------------------


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _run_groups(params, cfg, h, *, mode, positions=None, caches=None, pos=None,
                cache_len=0, q_chunk=1024, exact_causal=False, remat="none"):
    """Scan each group; returns (h, new_caches_or_None)."""
    new_groups = []
    for g, gp, gc in zip(cfg.groups, params["groups"],
                         (caches["groups"] if caches else [None] * len(cfg.groups))):
        scanned = tuple(bp for spec, bp in zip(g.unit, gp["blocks"])
                        if not getattr(spec, "shared", False))
        cache_xs = tuple(gc["blocks"]) if gc is not None else None

        def unit_fn(h, xs, _g=g, _gp=gp):
            param_slices, cache_slices, _ = xs
            si = 0
            new_caches = []
            for bi, spec in enumerate(_g.unit):
                if getattr(spec, "shared", False):
                    bp = _gp["blocks"][bi]
                else:
                    bp = param_slices[si]
                    si += 1
                c = cache_slices[bi] if cache_slices is not None else None
                h, co = apply_block(bp, h, cfg, spec, mode=mode,
                                    positions=positions, cache=c, pos=pos,
                                    cache_len=cache_len, q_chunk=q_chunk,
                                    exact_causal=exact_causal)
                h = ctx.constrain(h, ("dp", None, None))
                new_caches.append(co)
            return h, tuple(new_caches)

        unit_fn = _remat(unit_fn, remat if mode == "train" else "none")

        def scan_body(h, xs):
            h, cs = unit_fn(h, xs)
            return h, cs

        xs = (scanned, cache_xs, jnp.arange(g.repeat))
        if mode == "train":
            h, _ = jax.lax.scan(lambda hh, x: (unit_fn(hh, x)[0], None), h, xs)
            new_groups.append(None)
        else:
            h, cs = jax.lax.scan(scan_body, h, xs)
            new_groups.append({"blocks": list(cs)})
    if mode == "train":
        return h, None
    return h, {"groups": new_groups}


def forward(params, cfg: ArchConfig, tokens, q_chunk=1024, exact_causal=False,
            remat: Optional[str] = None):
    dt = jnp.dtype(cfg.compute_dtype)
    h = L.embed_tokens(params["embed"], cfg, tokens, dt)
    h = ctx.constrain(h, ("dp", None, None))
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    h, _ = _run_groups(params, cfg, h, mode="train", positions=positions,
                       q_chunk=q_chunk, exact_causal=exact_causal,
                       remat=remat if remat is not None else cfg.remat_policy)
    return L.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)


def loss_fn(params, cfg: ArchConfig, tokens, labels, q_chunk=1024,
            exact_causal=False, remat=None, xent_chunk=256):
    h = forward(params, cfg, tokens, q_chunk, exact_causal, remat)
    return L.chunked_xent(params["embed"], cfg, h, labels, chunk=xent_chunk)


def logits_fn(params, cfg: ArchConfig, tokens, **kw):
    h = forward(params, cfg, tokens, **kw)
    return L.output_logits(params["embed"], cfg, h)


def prefill(params, cfg: ArchConfig, tokens, cache_len, q_chunk=1024):
    dt = jnp.dtype(cfg.compute_dtype)
    h = L.embed_tokens(params["embed"], cfg, tokens, dt)
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    h, caches = _run_groups(params, cfg, h, mode="prefill", positions=positions,
                            cache_len=cache_len, q_chunk=q_chunk)
    h = L.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = L.output_logits(params["embed"], cfg, h[:, -1:])[:, 0]
    return logits, caches


def decode_step(params, cfg: ArchConfig, caches, token, pos):
    """token: (B,) int32 (or (B,CB) multi-codebook); pos: (B,) absolute."""
    dt = jnp.dtype(cfg.compute_dtype)
    tok = token[:, None] if token.ndim == 1 else token[:, None, :]
    h = L.embed_tokens(params["embed"], cfg, tok, dt)
    h, caches = _run_groups(params, cfg, h, mode="decode", caches=caches, pos=pos)
    h = L.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = L.output_logits(params["embed"], cfg, h)[:, 0]
    return logits, caches
