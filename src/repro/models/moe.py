"""Top-k token-choice MoE with sort-based dispatch (capacity-dropping).

Baseline formulation is GSPMD-friendly dense einsums over an (E, C, D)
dispatch buffer; experts shard over the `model` mesh axis (expert
parallelism), tokens over `data` — XLA inserts the all-to-alls.  A
shard_map-based explicit-EP variant is the §Perf beyond-paper optimization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models.layers import ninit


def init_moe(key, cfg):
    E, D, F = cfg.moe_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": ninit(ks[0], (D, E), scale=0.02),
        "wi": ninit(ks[1], (E, D, F), fan_in_axis=1),
        "wd": ninit(ks[2], (E, F, D), fan_in_axis=1),
    }
    if cfg.mlp_gated:
        p["wg"] = ninit(ks[3], (E, D, F), fan_in_axis=1)
    return p


def moe_mlp(params, x, cfg, return_aux=False):
    """Dispatch to the configured implementation (ctx env, §Perf)."""
    from repro.distributed.ctx import get_env
    env = get_env()
    if env is not None and getattr(env, "moe_impl", "gspmd") == "shardmap" \
            and not return_aux and cfg.moe_experts % env.msize == 0:
        return moe_mlp_shardmap(params, x, cfg, env)
    return _moe_mlp_gspmd(params, x, cfg, return_aux)


def _moe_mlp_gspmd(params, x, cfg, return_aux=False):
    """x: (B, S, D) -> (B, S, D). Token-choice top-k with capacity drop."""
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_topk
    T = B * S
    dt = x.dtype
    xf = x.reshape(T, D)

    logits = (xf @ params["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)                            # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = max(int(cfg.moe_capacity_factor * T * K / E), 1)
    flat_e = expert.reshape(-1)                                       # (T*K,)
    flat_g = gate.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)

    # stable sort by expert id; rank within expert = index - segment start
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[se]
    keep = rank < cap
    dest = jnp.where(keep, se * cap + rank, E * cap)                  # drop slot

    # dispatch: (E*C+1, D) buffer, last row = trash for dropped tokens
    buf = jnp.zeros((E * cap + 1, D), dt).at[dest].set(xf[st])
    h = buf[:E * cap].reshape(E, cap, D)
    h = constrain(h, ("model", None, None))      # expert parallelism

    wi, wd = params["wi"].astype(dt), params["wd"].astype(dt)
    a = jnp.einsum("ecd,edf->ecf", h, wi)
    if cfg.mlp_gated:
        a = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, params["wg"].astype(dt))) * a
    else:
        a = jax.nn.gelu(a)
    a = constrain(a, ("model", None, None))
    y = jnp.einsum("ecf,efd->ecd", a, wd).reshape(E * cap, D)

    # combine: gather expert outputs back to token order, weighted by gates
    contrib = jnp.where(keep[:, None], y[jnp.minimum(dest, E * cap - 1)], 0.0)
    out = jnp.zeros((T, D), dt).at[st].add(contrib * sg[:, None].astype(dt))
    out = out.reshape(B, S, D)

    if return_aux:
        # Switch-style load-balance loss
        me = probs.mean(0)                                            # (E,)
        ce = jnp.bincount(flat_e, length=E) / (T * K)
        aux = E * jnp.sum(me * ce)
        return out, aux
    return out


def moe_mlp_shardmap(params, x, cfg, env):
    """Explicit expert-parallel dispatch (§Perf beyond-paper optimization).

    Under pure GSPMD the sort-based scatter/gather dispatch lowers to
    full-size masked scatters + all-reduces — ~14 GiB per MoE layer per
    microbatch on kimi-k2 (measured in the dry-run profile).  Here each
    (data i, model j) device routes its LOCAL token shard to ITS expert
    slice with a local sort (tokens are replicated across the model axis, so
    no dispatch communication at all), computes the expert FFN, and the
    per-expert-shard partial outputs are combined with one psum over
    `model` — the Megatron-style pattern, O(activations) instead of
    O(dispatch-buffer) collectives.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    E, K = cfg.moe_experts, cfg.moe_topk
    mesh = env.mesh
    ms = env.msize
    E_loc = E // ms
    dp = env.dp
    gated = cfg.mlp_gated

    w_specs = {
        "router": P(None, None),
        "wi": P("model", None, None),
        "wd": P("model", None, None),
    }
    if gated:
        w_specs["wg"] = P("model", None, None)

    def local_moe(x_loc, p_loc):
        B_loc, S, D = x_loc.shape
        T = B_loc * S
        dt = x_loc.dtype
        xf = x_loc.reshape(T, D)
        logits = (xf @ p_loc["router"].astype(dt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert = jax.lax.top_k(probs, K)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        cap = max(int(cfg.moe_capacity_factor * T * K / E), 1)

        flat_e = expert.reshape(-1)
        flat_g = gate.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T), K)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(T * K) - starts[se]

        j = jax.lax.axis_index("model")
        e0 = j * E_loc
        mine = (se >= e0) & (se < e0 + E_loc) & (rank < cap)
        dest = jnp.where(mine, (se - e0) * cap + rank, E_loc * cap)

        buf = jnp.zeros((E_loc * cap + 1, D), dt).at[dest].set(xf[st])
        h = buf[:E_loc * cap].reshape(E_loc, cap, D)
        a = jnp.einsum("ecd,edf->ecf", h, p_loc["wi"].astype(dt))
        if gated:
            a = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h,
                                       p_loc["wg"].astype(dt))) * a
        else:
            a = jax.nn.gelu(a)
        y = jnp.einsum("ecf,efd->ecd", a, p_loc["wd"].astype(dt))
        y = y.reshape(E_loc * cap, D)
        contrib = jnp.where(mine[:, None], y[jnp.minimum(dest, E_loc * cap - 1)],
                            0.0)
        out = jnp.zeros((T, D), dt).at[st].add(contrib * sg[:, None].astype(dt))
        # each model shard contributed only its experts: sum across shards
        out = jax.lax.psum(out, "model")
        return out.reshape(B_loc, S, D)

    fn = jax.shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(dp, None, None), w_specs),
        out_specs=P(dp, None, None),
        check_vma=False)
    # cast BEFORE the shard_map boundary: the ZeRO weight all-gather then
    # moves compute-dtype (bf16) bytes, not fp32 masters
    cdt = x.dtype
    return fn(x, {k: params[k].astype(cdt) for k in w_specs})
