"""Shared neural-net layers: norms, RoPE, attention (train/prefill/decode,
global & sliding-window, q-chunked), MLPs, chunked cross-entropy.

Everything is pure-functional: params are plain dict pytrees; all control
flow that must stay compact under `lax.scan` uses jnp/lax only.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.ctx import constrain

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def ninit(key, shape, scale=None, dtype=jnp.float32, fan_in_axis=None):
    """Truncated-normal init; default scale 1/sqrt(fan_in)."""
    if scale is None:
        fan_in = shape[fan_in_axis] if fan_in_axis is not None else shape[0]
        scale = 1.0 / np.sqrt(max(fan_in, 1))
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def zinit(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def init_rms_norm(d):
    return {"scale": zinit((d,))}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, gated):
    ks = jax.random.split(key, 3)
    p = {"wi": ninit(ks[0], (d_model, d_ff)), "wd": ninit(ks[1], (d_ff, d_model))}
    if gated:
        p["wg"] = ninit(ks[2], (d_model, d_ff))
    return p


def mlp(params, x, gated):
    dt = x.dtype
    h = x @ params["wi"].astype(dt)
    if gated:
        h = jax.nn.silu(x @ params["wg"].astype(dt)) * h
    else:
        h = jax.nn.gelu(h)
    return h @ params["wd"].astype(dt)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg, spec):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": ninit(ks[0], (d, h, hd)),
        "wk": ninit(ks[1], (d, k, hd)),
        "wv": ninit(ks[2], (d, k, hd)),
        "wo": ninit(ks[3], (h, hd, d), fan_in_axis=0),
    }
    if spec.qkv_bias:
        p["bq"], p["bk"], p["bv"] = zinit((h, hd)), zinit((k, hd)), zinit((k, hd))
    if spec.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _project_qkv(params, x, spec, cfg, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if spec.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"]["scale"], cfg.norm_eps)
    if spec.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # Pin batch to dp and heads (or head_dim) to model — without this GSPMD
    # replicates the batch inside the q-chunk scan (3x FLOP inflation
    # observed in the dry-run).  Mirror the weight policy: head-TP only if
    # both H and K divide the model axis, else shard head_dim.
    from repro.distributed.ctx import get_env
    env = get_env()
    if env is not None:
        H, K = q.shape[2], k.shape[2]
        ms = env.msize
        if getattr(env, "attn_policy", "v1") == "qtp":
            # Q heads over model whenever divisible; K/V replicated if their
            # head count doesn't divide — no sharded contraction in scores.
            q = constrain(q, ("dp", None, "model", None))
            kv_dims = ("dp", None, "model" if K % ms == 0 else None, None)
            k = constrain(k, kv_dims)
            v = constrain(v, kv_dims)
        else:
            if H % ms == 0 and K % ms == 0:
                dims = ("dp", None, "model", None)
            else:
                dims = ("dp", None, None, "model")
            q = constrain(q, dims)
            k = constrain(k, dims)
            v = constrain(v, dims)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,H,hd) k,v: (B,Sk,K,hd); GQA by head grouping. mask: (B|1,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def attention_train(params, x, spec, cfg, positions, q_chunk=1024,
                    exact_causal_slices=False):
    """Causal (optionally sliding-window) attention for train/prefill.

    q-chunked with `lax.scan` so the score working set is (B,H,chunk,Skv).
    Window layers slice only the (window+chunk) KV band — the paper-faithful
    "touch only what you need" structure applied to attention FLOPs.

    ``exact_causal_slices``: beyond-paper hillclimb mode — python-unrolled
    q-chunks with [0 : (i+1)*chunk] KV slices, halving global-attention FLOPs
    at the cost of a larger (unrolled) HLO.
    """
    B, S, D = x.shape
    scale = cfg.head_dim ** -0.5
    q, k, v = _project_qkv(params, x, spec, cfg, positions)

    if S <= q_chunk:
        qpos = positions if positions.ndim > 1 else positions[None, :]
        mask = qpos[:, :, None] >= qpos[:, None, :]
        if spec.window:
            mask &= qpos[:, :, None] - qpos[:, None, :] < spec.window
        out = _sdpa(q, k, v, mask, scale)
    elif spec.window is not None:
        out = _window_chunked(q, k, v, spec.window, q_chunk, scale)
    elif exact_causal_slices:
        out = _causal_unrolled(q, k, v, q_chunk, scale)
    else:
        out = _causal_chunked(q, k, v, q_chunk, scale)
    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def _causal_chunked(q, k, v, c, scale):
    B, S, H, hd = q.shape
    nc = S // c
    qs = q.reshape(B, nc, c, H, hd).transpose(1, 0, 2, 3, 4)  # (nc,B,c,H,hd)

    def step(_, qi_i):
        qi, i = qi_i
        qpos = i * c + jnp.arange(c)
        kpos = jnp.arange(S)
        mask = (qpos[:, None] >= kpos[None, :])[None]
        return None, _sdpa(qi, k, v, mask, scale)

    _, out = jax.lax.scan(step, None, (qs, jnp.arange(nc)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def _causal_unrolled(q, k, v, c, scale):
    B, S, H, hd = q.shape
    nc = S // c
    outs = []
    for i in range(nc):
        qi = q[:, i * c:(i + 1) * c]
        kv_end = (i + 1) * c
        ki, vi = k[:, :kv_end], v[:, :kv_end]
        qpos = i * c + jnp.arange(c)
        mask = (qpos[:, None] >= jnp.arange(kv_end)[None, :])[None]
        outs.append(_sdpa(qi, ki, vi, mask, scale))
    return jnp.concatenate(outs, axis=1)


def _window_chunked(q, k, v, window, c, scale):
    """Front-pad KV by `window` so each q-chunk reads a fixed (window+c) band."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    w = ((window + c - 1) // c) * c        # pad window to a chunk multiple
    kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    nc = S // c
    qs = q.reshape(B, nc, c, H, hd).transpose(1, 0, 2, 3, 4)

    def step(_, qi_i):
        qi, i = qi_i
        ki = jax.lax.dynamic_slice_in_dim(kp, i * c, w + c, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vp, i * c, w + c, axis=1)
        qpos = i * c + jnp.arange(c)
        kpos = i * c - w + jnp.arange(w + c)
        mask = ((qpos[:, None] >= kpos[None, :])
                & (qpos[:, None] - kpos[None, :] < window)
                & (kpos[None, :] >= 0))[None]
        return None, _sdpa(qi, ki, vi, mask, scale)

    _, out = jax.lax.scan(step, None, (qs, jnp.arange(nc)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


# --- prefill (returns cache) & decode -------------------------------------


def attention_prefill(params, x, spec, cfg, positions, cache_len, q_chunk=1024):
    """Same as train, but also returns the (k,v) cache of size cache_len.

    Window layers keep only the last `window` keys (ring layout, slot =
    pos % window).
    """
    B, S, D = x.shape
    q, k, v = _project_qkv(params, x, spec, cfg, positions)
    scale = cfg.head_dim ** -0.5
    if S <= q_chunk:
        qpos = positions if positions.ndim > 1 else positions[None, :]
        mask = qpos[:, :, None] >= qpos[:, None, :]
        if spec.window:
            mask &= qpos[:, :, None] - qpos[:, None, :] < spec.window
        out = _sdpa(q, k, v, mask, scale)
    elif spec.window is not None:
        out = _window_chunked(q, k, v, spec.window, q_chunk, scale)
    else:
        out = _causal_chunked(q, k, v, q_chunk, scale)

    if spec.window is not None:
        w = min(spec.window, cache_len)
        # ring layout: entry for absolute position p lives at slot p % w.
        tail_k, tail_v = k[:, -w:], v[:, -w:]
        pos_tail = positions[..., -w:] if positions.ndim > 1 else positions[-w:][None]
        slots = (pos_tail % w).astype(jnp.int32)
        ck = jnp.zeros((B, w) + k.shape[2:], k.dtype)
        cv = jnp.zeros((B, w) + v.shape[2:], v.dtype)
        bidx = jnp.arange(B)[:, None]
        ck = ck.at[bidx, slots].set(tail_k)
        cv = cv.at[bidx, slots].set(tail_v)
        cache = {"k": ck, "v": cv}
    else:
        pad = cache_len - S
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        }
    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt)), cache


def attention_decode(params, x, spec, cfg, cache, pos):
    """One-token decode. x: (B,1,D); pos: (B,) absolute positions.

    Global layers: cache (B,Smax,K,hd), write at pos, mask j<=pos.
    Window layers: ring cache (B,w,K,hd), write at pos%w, mask by recency.
    """
    B = x.shape[0]
    q, k, v = _project_qkv(params, x, spec, cfg, pos[:, None])
    scale = cfg.head_dim ** -0.5
    ck, cv = cache["k"], cache["v"]
    bidx = jnp.arange(B)
    if spec.window is not None:
        w = ck.shape[1]
        slot = (pos % w).astype(jnp.int32)
        ck = ck.at[bidx, slot].set(k[:, 0])
        cv = cv.at[bidx, slot].set(v[:, 0])
        # slot s holds abs position: the largest p' <= pos with p' % w == s.
        valid = jnp.arange(w)[None, :] <= jnp.minimum(pos, w - 1)[:, None]
    else:
        Smax = ck.shape[1]
        ck = ck.at[bidx, pos].set(k[:, 0])
        cv = cv.at[bidx, pos].set(v[:, 0])
        valid = jnp.arange(Smax)[None, :] <= pos[:, None]
    out = _sdpa(q, ck, cv, valid[:, None, :], scale)
    dt = x.dtype
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, {"k": ck, "v": cv}


def init_attn_cache(cfg, spec, batch, cache_len, dtype):
    w = min(spec.window, cache_len) if spec.window is not None else cache_len
    shape = (batch, w, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------


def init_embed(key, cfg):
    ks = jax.random.split(key, 2)
    cb = cfg.num_codebooks
    shape = (cb, cfg.vocab_size, cfg.d_model) if cb > 1 else (cfg.vocab_size, cfg.d_model)
    p = {"tok": ninit(ks[0], shape, scale=0.02, fan_in_axis=-1)}
    if not cfg.tie_embeddings:
        oshape = (cfg.d_model, cb * cfg.vocab_size) if cb > 1 else (cfg.d_model, cfg.vocab_size)
        p["out"] = ninit(ks[1], oshape)
    return p


def embed_tokens(params, cfg, tokens, dtype):
    """tokens: (B,S) or (B,S,CB) for multi-codebook archs."""
    tok = params["tok"].astype(dtype)
    if cfg.num_codebooks > 1:
        # sum of per-codebook embeddings
        out = 0.0
        for c in range(cfg.num_codebooks):
            out = out + jnp.take(tok[c], tokens[..., c], axis=0)
        return out
    return jnp.take(tok, tokens, axis=0)


def output_logits(params, cfg, h):
    """h: (B,S,D) -> logits (B,S,V) or (B,S,CB,V)."""
    dt = h.dtype
    if cfg.tie_embeddings:
        tok = params["tok"].astype(dt)
        if cfg.num_codebooks > 1:
            return jnp.einsum("bsd,cvd->bscv", h, tok)
        return jnp.einsum("bsd,vd->bsv", h, tok)
    out = params["out"].astype(dt)
    logits = h @ out
    if cfg.num_codebooks > 1:
        B, S = h.shape[:2]
        return logits.reshape(B, S, cfg.num_codebooks, cfg.vocab_size)
    return logits


def chunked_xent(params, cfg, h, labels, chunk=256):
    """Cross-entropy without materializing (B,S,V) logits: scan over seq
    chunks, recompute logits in the backward pass (jax.checkpoint)."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    nc = S // chunk
    rem = S - nc * chunk
    hs = h[:, :nc * chunk].reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    if cfg.num_codebooks > 1:
        ls = labels[:, :nc * chunk].reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    else:
        ls = labels[:, :nc * chunk].reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(hc, lc):
        logits = output_logits(params, cfg, hc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def step(acc, xs):
        hc, lc = xs
        return acc + chunk_loss(hc, lc), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hs, ls))
    if rem:
        total = total + chunk_loss(h[:, nc * chunk:], labels[:, nc * chunk:])
    denom = B * S * (cfg.num_codebooks if cfg.num_codebooks > 1 else 1)
    return total / denom
