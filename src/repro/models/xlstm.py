"""xLSTM blocks: mLSTM (matrix-memory, chunked-parallel) and sLSTM
(scalar-memory, true recurrence via lax.scan).

Faithful to the xLSTM block structure (up-proj -> conv -> q/k/v -> cell ->
group-norm -> gated down-proj). One documented simplification: we use
bounded sigmoid input/forget gates rather than the exponential-gate +
max-stabilizer form — identical state-update structure, FLOPs and memory
(what the roofline sees), but unconditionally stable in bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_rms_norm, ninit, rms_norm, zinit


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mdims(cfg, spec):
    d_inner = spec.expand * cfg.d_model
    H = spec.num_heads
    return d_inner, H, d_inner // H


def init_mlstm(key, cfg, spec):
    d = cfg.d_model
    d_inner, H, _ = _mdims(cfg, spec)
    ks = jax.random.split(key, 8)
    return {
        "w_up": ninit(ks[0], (d, 2 * d_inner)),
        "conv_w": ninit(ks[1], (4, d_inner), scale=0.1),
        "conv_b": zinit((d_inner,)),
        "wq": ninit(ks[2], (d_inner, d_inner)),
        "wk": ninit(ks[3], (d_inner, d_inner)),
        "wv": ninit(ks[4], (d_inner, d_inner)),
        "w_gates": ninit(ks[5], (d_inner, 2 * H), scale=0.02),
        "b_gates": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "norm": init_rms_norm(d_inner),
        "w_down": ninit(ks[6], (d_inner, d)),
    }


def _mlstm_qkv(params, x, cfg, spec):
    dt = x.dtype
    d_inner, H, dh = _mdims(cfg, spec)
    up = x @ params["w_up"].astype(dt)
    xm, z = up[..., :d_inner], up[..., d_inner:]
    # causal depthwise conv(4)
    w = params["conv_w"].astype(dt)
    pad = jnp.pad(xm, ((0, 0), (w.shape[0] - 1, 0), (0, 0)))
    xc = sum(pad[:, i:i + xm.shape[1]] * w[i] for i in range(w.shape[0]))
    xc = jax.nn.silu(xc + params["conv_b"].astype(dt))
    B, S = x.shape[:2]
    q = (xc @ params["wq"].astype(dt)).reshape(B, S, H, dh)
    k = (xc @ params["wk"].astype(dt)).reshape(B, S, H, dh) / jnp.sqrt(dh).astype(dt)
    v = (xm @ params["wv"].astype(dt)).reshape(B, S, H, dh)
    gates = xc @ params["w_gates"].astype(dt) + params["b_gates"].astype(dt)
    lf = jax.nn.log_sigmoid(gates[..., H:].astype(jnp.float32))      # (B,S,H)
    ig = jax.nn.sigmoid(gates[..., :H].astype(jnp.float32))
    return q, k, v, z, xm, lf, ig


def mlstm_forward(params, x, cfg, spec, chunk=256, return_state=False):
    B, S, D = x.shape
    d_inner, H, dh = _mdims(cfg, spec)
    dt = x.dtype
    q, k, v, z, xm, lf, ig = _mlstm_qkv(params, x, cfg, spec)

    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S

    def r(t):
        return t.reshape((B, nc, chunk) + t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    def chunk_step(carry, xs_i):
        C, n = carry                                    # (B,H,dh,dh), (B,H,dh)
        q_i, k_i, v_i, lf_i, ig_i = xs_i
        qf, kf, vf = (t.astype(jnp.float32) for t in (q_i, k_i, v_i))
        cum = jnp.cumsum(lf_i, axis=1)                  # (B,c,H)
        seg = cum[:, :, None, :] - cum[:, None, :, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        # clamp masked entries before exp (0*inf NaN in the where-grad)
        decay = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
        att = jnp.einsum("bshd,bjhd->bsjh", qf, kf) * decay * ig_i[:, None, :, :]
        num = jnp.einsum("bsjh,bjhd->bshd", att, vf)
        den = att.sum(axis=2)                           # (B,c,H)
        # carried state contribution
        dec_s = jnp.exp(cum)                            # (B,c,H)
        num = num + jnp.einsum("bshd,bhdw,bsh->bshw", qf, C, dec_s)
        den = den + jnp.einsum("bshd,bhd,bsh->bsh", qf, n, dec_s)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # state update
        dec_end = jnp.exp(cum[:, -1, None, :] - cum) * ig_i   # (B,c,H)
        C = jnp.exp(cum[:, -1])[:, :, None, None] * C + jnp.einsum(
            "bjh,bjhd,bjhw->bhdw", dec_end, kf, vf)
        n = jnp.exp(cum[:, -1])[:, :, None] * n + jnp.einsum(
            "bjh,bjhd->bhd", dec_end, kf)
        return (C, n), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    (C, n), hs = jax.lax.scan(chunk_step, (C0, n0),
                              (r(q), r(k), r(v), r(lf), r(ig)))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, d_inner).astype(dt)
    h = rms_norm(h, params["norm"]["scale"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["w_down"].astype(dt)
    if return_state:
        d_conv = params["conv_w"].shape[0]
        up = x @ params["w_up"].astype(dt)
        conv_state = jnp.pad(up[..., :d_inner], ((0, 0), (d_conv - 1, 0), (0, 0)))[:, -(d_conv - 1):]
        return out, {"C": C, "n": n, "conv": conv_state}
    return out


def init_mlstm_cache(cfg, spec, batch, dtype):
    d_inner, H, dh = _mdims(cfg, spec)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "conv": jnp.zeros((batch, 3, d_inner), dtype),
    }


def mlstm_decode(params, x, cfg, spec, cache):
    """x: (B,1,D) single-step."""
    B = x.shape[0]
    d_inner, H, dh = _mdims(cfg, spec)
    dt = x.dtype
    up = x @ params["w_up"].astype(dt)                   # (B,1,2*d_inner)
    xm, z = up[..., :d_inner], up[..., d_inner:]
    hist = jnp.concatenate([cache["conv"], xm], axis=1)  # (B,4,d_inner)
    w = params["conv_w"].astype(dt)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w) + params["conv_b"].astype(dt))
    q = (xc @ params["wq"].astype(dt)).reshape(B, H, dh).astype(jnp.float32)
    k = ((xc @ params["wk"].astype(dt)).reshape(B, H, dh) / jnp.sqrt(dh).astype(dt)).astype(jnp.float32)
    v = (xm[:, 0] @ params["wv"].astype(dt)).reshape(B, H, dh).astype(jnp.float32)
    gates = xc @ params["w_gates"].astype(dt) + params["b_gates"].astype(dt)
    f = jax.nn.sigmoid(gates[..., H:].astype(jnp.float32))
    i = jax.nn.sigmoid(gates[..., :H].astype(jnp.float32))
    C = cache["C"] * f[:, :, None, None] + i[:, :, None, None] * jnp.einsum(
        "bhd,bhw->bhdw", k, v)
    n = cache["n"] * f[:, :, None] + i[:, :, None] * k
    num = jnp.einsum("bhd,bhdw->bhw", q, C)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    h = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None]).reshape(B, 1, d_inner).astype(dt)
    h = rms_norm(h, params["norm"]["scale"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["w_down"].astype(dt)
    return out, {"C": C, "n": n, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, spec):
    d = cfg.d_model
    H = spec.num_heads
    dh = d // H
    p_dim = int(spec.proj_factor * d)
    ks = jax.random.split(key, 10)
    return {
        "w": ninit(ks[0], (d, 4 * d)),                  # i,f,z,o input projections
        "r": ninit(ks[1], (4, H, dh, dh), fan_in_axis=2),  # recurrent (block-diag)
        "b": jnp.concatenate([zinit((d,)), 3.0 * jnp.ones((d,)), zinit((2 * d,))]),
        "norm": init_rms_norm(d),
        "w_up": ninit(ks[2], (d, 2 * p_dim)),
        "w_down": ninit(ks[3], (p_dim, d)),
    }


def _slstm_cell(params, xt, state, H):
    """xt: (B, 4d) pre-projected inputs; state: dict of (B, d)."""
    c, n, h = state["c"], state["n"], state["h"]
    B, d = c.shape
    dh = d // H
    hr = h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,ghde->bghe", hr, params["r"].astype(h.dtype))  # (B,4,H,dh)
    gates = xt.reshape(B, 4, d) + rec.reshape(B, 4, d) + params["b"].astype(h.dtype).reshape(4, d)
    i = jax.nn.sigmoid(gates[:, 0])
    f = jax.nn.sigmoid(gates[:, 1])
    zv = jnp.tanh(gates[:, 2])
    o = jax.nn.sigmoid(gates[:, 3])
    c = f * c + i * zv
    n = f * n + i
    h = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h}


def slstm_forward(params, x, cfg, spec, return_state=False):
    B, S, D = x.shape
    H = spec.num_heads
    dt = x.dtype
    xg = x @ params["w"].astype(dt)                     # (B,S,4d)
    state0 = {k: jnp.zeros((B, D), dt) for k in ("c", "n", "h")}

    def step(state, xt):
        state = _slstm_cell(params, xt, state, H)
        return state, state["h"]

    state, hs = jax.lax.scan(step, state0, xg.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)
    h = rms_norm(h, params["norm"]["scale"], cfg.norm_eps)
    up = h @ params["w_up"].astype(dt)
    p = up.shape[-1] // 2
    out = (jax.nn.gelu(up[..., :p]) * up[..., p:]) @ params["w_down"].astype(dt)
    if return_state:
        return out, state
    return out


def init_slstm_cache(cfg, spec, batch, dtype):
    return {k: jnp.zeros((batch, cfg.d_model), dtype) for k in ("c", "n", "h")}


def slstm_decode(params, x, cfg, spec, cache):
    dt = x.dtype
    xt = (x[:, 0] @ params["w"].astype(dt))
    state = _slstm_cell(params, xt, cache, spec.num_heads)
    h = rms_norm(state["h"][:, None], params["norm"]["scale"], cfg.norm_eps)
    up = h @ params["w_up"].astype(dt)
    p = up.shape[-1] // 2
    out = (jax.nn.gelu(up[..., :p]) * up[..., p:]) @ params["w_down"].astype(dt)
    return out, state
