"""stablelm-3b [dense]: 32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.

[hf:stabilityai/stablelm-2-1_6b; unverified] — config as assigned.
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

CONFIG = register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=32),),
    mlp_gated=True,
    tie_embeddings=False,
    subquadratic=False,
    microbatches=2,
))
