"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks. [arXiv:2405.04517; unverified]

xLSTM[7:1] ratio: each group of 8 = 7 mLSTM + 1 sLSTM; 6 groups = 48 blocks.
mLSTM uses the chunked-parallel (linear-attention) form; sLSTM is a true
recurrence lowered with lax.scan. No separate FFN (blocks carry their own
up/down projections), per the paper.
"""
from repro.configs.base import ArchConfig, GroupSpec, MLSTMSpec, SLSTMSpec, register

_M = MLSTMSpec(expand=2, num_heads=4)
_S = SLSTMSpec(num_heads=4)

CONFIG = register(ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    groups=(GroupSpec(unit=(_M, _M, _M, _M, _M, _M, _M, _S), repeat=6),),
    mlp_gated=True,
    tie_embeddings=True,
    subquadratic=True,
    microbatches=2,
))
