"""Architecture & shape configuration for the MITOSIS-JAX model zoo.

Every assigned architecture is expressed as an ``ArchConfig`` whose layer
stack is a list of ``GroupSpec``s: a *unit* (ordered tuple of block specs)
repeated ``repeat`` times.  The unified LM (models/lm.py) scans over the
repeat axis, so HLO size is independent of depth — essential for AOT
compiles of 61–88 layer models on 512 logical devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Self-attention block (pre-norm, residual, followed by MLP unless
    ``mlp_dim == 0``)."""

    kind: str = "attn"
    window: Optional[int] = None        # sliding-window size; None = global
    shared: bool = False                # zamba2: one param set for all repeats
    qk_norm: bool = False               # chameleon-style
    qkv_bias: bool = False              # qwen2-style
    rope: bool = True


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    """Mamba2 (SSD) block."""

    kind: str = "mamba"
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                  # SSD head dim (P)
    shared: bool = False


@dataclasses.dataclass(frozen=True)
class MLSTMSpec:
    kind: str = "mlstm"
    expand: int = 2
    num_heads: int = 4
    shared: bool = False


@dataclasses.dataclass(frozen=True)
class SLSTMSpec:
    kind: str = "slstm"
    num_heads: int = 4
    proj_factor: float = 4.0 / 3.0
    shared: bool = False


BlockSpec = object  # union of the above


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    unit: Tuple[BlockSpec, ...]
    repeat: int


# ---------------------------------------------------------------------------
# Arch config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                          # dense | moe | hybrid | ssm | audio | vlm
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int                            # dense MLP hidden (0 = no MLP in block)
    vocab_size: int
    groups: Tuple[GroupSpec, ...]
    # --- MLP style ---
    mlp_gated: bool = True               # SwiGLU vs plain GELU
    # --- MoE ---
    moe_experts: int = 0                 # 0 = dense
    moe_topk: int = 0
    moe_d_ff: int = 0                    # per-expert hidden
    moe_capacity_factor: float = 1.25
    # --- embeddings / io ---
    num_codebooks: int = 1               # musicgen: 4 summed codebooks
    tie_embeddings: bool = True
    max_seq_len: int = 131072
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # --- dtype policy ---
    param_dtype: str = "float32"         # master params
    compute_dtype: str = "bfloat16"
    # --- applicability ---
    subquadratic: bool = False           # eligible for long_500k
    # --- training knobs (overridable per shape at launch) ---
    remat_policy: str = "full"           # none | full | dots
    microbatches: int = 1

    @property
    def num_layers(self) -> int:
        return sum(g.repeat * len(g.unit) for g in self.groups)

    def block_specs(self) -> Sequence[BlockSpec]:
        out = []
        for g in self.groups:
            for _ in range(g.repeat):
                out.extend(g.unit)
        return out

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.moe_experts:
            assert self.moe_topk > 0 and self.moe_d_ff > 0


# ---------------------------------------------------------------------------
# Shapes (assigned, shared by all 10 archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str                            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Per assignment: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k skipped (see DESIGN.md §Arch-applicability)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    cfg.validate()
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from repro.configs import (  # noqa: F401
        stablelm_3b, gemma3_1b, granite_34b, qwen2_7b, zamba2_2_7b,
        kimi_k2_1t_a32b, moonshot_v1_16b_a3b, musicgen_large, xlstm_1_3b,
        chameleon_34b, micro,
    )


# ---------------------------------------------------------------------------
# Reduced configs for smoke tests: same family, tiny dims.
# ---------------------------------------------------------------------------


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Shrink a config to CPU-smoke scale, preserving block structure family."""
    groups = []
    for g in cfg.groups[:2]:
        unit = tuple(_shrink_block(b) for b in g.unit[:3])
        groups.append(GroupSpec(unit=unit, repeat=min(g.repeat, 2)))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) or 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        groups=tuple(groups),
        moe_experts=min(cfg.moe_experts, 4),
        moe_topk=min(cfg.moe_topk, 2),
        moe_d_ff=64 if cfg.moe_experts else 0,
        moe_capacity_factor=8.0,   # no drops at smoke scale: keeps decode == forward
        max_seq_len=512,
        microbatches=1,
        param_dtype="float32",
        compute_dtype="float32",
    )


def _shrink_block(b):
    if isinstance(b, AttnSpec):
        return dataclasses.replace(b, window=min(b.window, 32) if b.window else None)
    if isinstance(b, MambaSpec):
        return dataclasses.replace(b, d_state=8, head_dim=16)
    if isinstance(b, MLSTMSpec):
        return dataclasses.replace(b, num_heads=2)
    if isinstance(b, SLSTMSpec):
        return dataclasses.replace(b, num_heads=2)
    return b
