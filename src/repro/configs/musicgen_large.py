"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only per assignment; the EnCodec frontend is a STUB: inputs are the
4 codebook token streams (delay pattern omitted), embeddings are summed, and
the head predicts 4 × 2048 logits. Non-gated GELU MLP (musicgen uses a plain
transformer decoder).
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

CONFIG = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=48),),
    mlp_gated=False,
    num_codebooks=4,
    tie_embeddings=False,
    subquadratic=False,
    microbatches=2,
))
