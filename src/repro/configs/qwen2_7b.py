"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

GQA with QKV bias. [arXiv:2407.10671; hf]
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

CONFIG = register(ArchConfig(
    name="qwen2-7b",
    family="dense",
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    groups=(GroupSpec(unit=(AttnSpec(qkv_bias=True),), repeat=28),),
    mlp_gated=True,
    tie_embeddings=False,
    rope_theta=1000000.0,
    subquadratic=False,
    microbatches=4,
))
