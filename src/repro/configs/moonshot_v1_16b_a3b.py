"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (MHA kv=16) d_ff=1408(expert)
vocab=163840, MoE 64 experts top-6 (kimi/moonlight lineage).
[hf:moonshotai/Moonlight-16B-A3B; hf]

Assigned dims followed literally (all-MoE, gated experts).
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=163840,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=48),),
    mlp_gated=True,
    moe_experts=64,
    moe_topk=6,
    moe_d_ff=1408,
    tie_embeddings=True,
    subquadratic=False,
    microbatches=4,
))
