"""Micro configs used by the paper-analogue benchmarks and examples.

These play the role of the paper's evaluated "functions" (hello, json,
pyaes, ..., recognition): model instances of increasing state size, so that
fork/startup/state-transfer costs span the same relative range.
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

# "hello" — minimal instance (≈1 MB state)
MICRO_HELLO = register(ArchConfig(
    name="micro-hello",
    family="dense",
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=256, vocab_size=512,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=2),),
    tie_embeddings=True, max_seq_len=1024, microbatches=1,
))

# "json" — small instance (≈10 MB state)
MICRO_SMALL = register(ArchConfig(
    name="micro-small",
    family="dense",
    d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
    d_ff=1024, vocab_size=2048,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=4),),
    tie_embeddings=True, max_seq_len=2048, microbatches=1,
))

# "image" — medium instance (≈50 MB state)
MICRO_MEDIUM = register(ArchConfig(
    name="micro-medium",
    family="dense",
    d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=8192,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=6),),
    tie_embeddings=True, max_seq_len=4096, microbatches=1,
))

# "recognition" — large instance (≈150+ MB state); the paper's worst case.
MICRO_LARGE = register(ArchConfig(
    name="micro-large",
    family="dense",
    d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=16384,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=12),),
    tie_embeddings=True, max_seq_len=4096, microbatches=1,
))

# ~100M-param config for examples/train driver presets.
TRAIN_100M = register(ArchConfig(
    name="train-100m",
    family="dense",
    d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=32768,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=12),),
    tie_embeddings=True, max_seq_len=2048, microbatches=1,
))
