"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global sliding-window pattern (window=512), 128k context.
[hf:google/gemma-3-1b-pt; unverified]

26 layers = 4 × (5 local + 1 global) + 2 local tail.
Sub-quadratic eligible for long_500k: 22/26 layers have window-512 caches;
the 4 global layers decode linearly against the full cache.
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

_LOCAL = AttnSpec(window=512)
_GLOBAL = AttnSpec(window=None)

CONFIG = register(ArchConfig(
    name="gemma3-1b",
    family="dense",
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    groups=(
        GroupSpec(unit=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL), repeat=4),
        GroupSpec(unit=(_LOCAL, _LOCAL), repeat=1),
    ),
    mlp_gated=True,
    tie_embeddings=True,
    max_seq_len=131072,
    rope_theta=1000000.0,
    subquadratic=True,
    microbatches=2,
))
