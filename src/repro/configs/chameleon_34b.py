"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion VQ image tokens. [arXiv:2405.09818; unverified]

Backbone only: chameleon's early fusion means images arrive as discrete VQ
codes *inside the unified 65536 vocab*, so the frontend stub is simply the
token stream (input_specs yields token ids; the VQ-GAN encoder is out of
scope per the assignment). QK-norm enabled, as chameleon requires for
stability at this scale.
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    groups=(GroupSpec(unit=(AttnSpec(qk_norm=True),), repeat=48),),
    mlp_gated=True,
    tie_embeddings=False,
    subquadratic=False,
    microbatches=16,
))
