"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 backbone + ONE shared attention(+MLP) block applied
every 6 mamba blocks (weights shared across applications, as in the paper).
[arXiv:2411.15242; hf]

Simplifications noted in DESIGN.md: per-invocation LoRA deltas on the shared
block are omitted; single shared block rather than two alternating.
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, MambaSpec, register

_M = MambaSpec(d_state=64, d_conv=4, expand=2, head_dim=64)
_SHARED_ATTN = AttnSpec(shared=True, rope=True)

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    groups=(
        # 9 × (6 mamba + shared attention) = 54 mamba layers + 9 shared-attn
        # applications (one parameter set).
        GroupSpec(unit=(_M, _M, _M, _M, _M, _M, _SHARED_ATTN), repeat=9),
    ),
    mlp_gated=True,
    tie_embeddings=True,
    subquadratic=True,
    microbatches=2,
))
