"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert)
vocab=163840, MoE 384 experts top-8 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

We follow the assigned spec (GQA kv=8); the production K2 uses MLA — noted
in DESIGN.md. All layers MoE; ~1.03T total, ~32B active parameters.
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=0,                      # no dense MLP; MoE FFN instead
    vocab_size=163840,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=61),),
    mlp_gated=True,
    moe_experts=384,
    moe_topk=8,
    moe_d_ff=2048,
    tie_embeddings=False,
    param_dtype="bfloat16",      # 1T fp32 master + Adam does not fit any pod
    subquadratic=False,
    microbatches=16,
))
