"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

Code model, GPT-BigCode-style: MQA + non-gated (2-matrix) GELU MLP — the
non-gated MLP is what makes the assigned dims total ~34B parameters.
[arXiv:2405.04324; hf]
"""
from repro.configs.base import ArchConfig, AttnSpec, GroupSpec, register

CONFIG = register(ArchConfig(
    name="granite-34b",
    family="dense",
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    groups=(GroupSpec(unit=(AttnSpec(),), repeat=88),),
    mlp_gated=False,
    tie_embeddings=True,
    subquadratic=False,
    microbatches=16,
))
