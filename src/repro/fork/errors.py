"""Control-plane exceptions.

Defined next to the transport layer (``repro.net``) so the node runtime and
every backend can raise them without importing this package; re-exported
here as the public names of the fork API.
"""
from repro.net import AccessRevoked, LeaseExpired

__all__ = ["AccessRevoked", "LeaseExpired"]
