"""Control-plane exceptions.

Defined next to the transport (``repro.core.network``) so the node runtime
can raise them without importing this package; re-exported here as the
public names of the fork API.
"""
from repro.core.network import AccessRevoked, LeaseExpired

__all__ = ["AccessRevoked", "LeaseExpired"]
