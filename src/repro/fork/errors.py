"""Control-plane exceptions.

Defined next to the transport layer (``repro.net``) so the node runtime and
every backend can raise them without importing this package; re-exported
here as the public names of the fork API.  The whole taxonomy derives from
:class:`ReproError` with a machine-readable ``.kind`` — see
``repro/net/errors.py``.
"""
from repro.net import (AccessRevoked, AuthError, HandleUnbound, LeaseExpired,
                       NoNodesAvailable, NodeDown, ReadTimeout, RecoveryFailed,
                       ReproError, RetriesExhausted, SeedGone, SeedUnavailable,
                       TransportError)

__all__ = [
    "AccessRevoked",
    "AuthError",
    "HandleUnbound",
    "LeaseExpired",
    "NoNodesAvailable",
    "NodeDown",
    "ReadTimeout",
    "RecoveryFailed",
    "ReproError",
    "RetriesExhausted",
    "SeedGone",
    "SeedUnavailable",
    "TransportError",
]
