"""ForkTree — the §6.3 fork tree built by ``ForkHandle.fan_out``.

To fork N children from one seed without serializing on the root parent,
children are re-prepared as short-lived seeds once the current serving seed
has handed out ``tree_degree`` descriptors; later children then fork from
those re-seeds (BFS order, so the tree stays as shallow as possible).  The
coordinator closes the whole tree — every re-seed reclaimed, the root left
alone — in one call.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fork.policy import ForkPolicy


@dataclasses.dataclass
class ForkTree:
    """Fan-out result: children (BFS order), the short-lived re-seed handles
    (root excluded), per-child depth, and edges (serving handle -> child)."""

    root: "ForkHandle"
    degree: int
    children: List[object] = dataclasses.field(default_factory=list)
    seeds: List["ForkHandle"] = dataclasses.field(default_factory=list)
    levels: List[int] = dataclasses.field(default_factory=list)
    edges: List[Tuple["ForkHandle", object]] = dataclasses.field(default_factory=list)
    closed: bool = False

    def __len__(self) -> int:
        return len(self.children)

    def depth(self) -> int:
        return max(self.levels, default=0)

    def served_by(self) -> Dict[Tuple[str, int], int]:
        """(parent_node, handler_id) -> number of children that seed served.
        Keyed by the pair because handler ids are per-node counters."""
        count: Dict[Tuple[str, int], int] = {}
        for handle, _ in self.edges:
            key = (handle.parent_node, handle.handler_id)
            count[key] = count.get(key, 0) + 1
        return count

    def close(self, free_instances: bool = False) -> None:
        """Reclaim every short-lived re-seed in the tree (never the root);
        idempotent.  ``free_instances`` additionally frees the children."""
        if not self.closed:
            for handle in self.seeds:
                handle.reclaim(free_instance=False)
            self.closed = True
        if free_instances:
            for child in self.children:
                child.free()

    def __enter__(self) -> "ForkTree":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


def build_fork_tree(root: "ForkHandle", nodes: Sequence,
                    policy: Optional[ForkPolicy] = None,
                    tree_degree: int = 8,
                    child_lease: Optional[float] = None,
                    root_quota: Optional[int] = None,
                    promote=None) -> ForkTree:
    """Fork one child per entry of ``nodes`` (NodeRuntime targets; repeats
    allowed) through a degree-bounded tree rooted at ``root``.

    Children are promoted to servers lazily — a child only pays the
    re-prepare cost when the frontier of existing seeds is exhausted.

    ``root_quota`` is how many children the root itself serves before the
    first promotion (default ``tree_degree``; a sharded root with S parent
    NICs passes ``tree_degree * S``).  ``promote`` picks which pending
    child to re-seed next: a callable from the promotable list of
    (child instance, level) pairs to an index (default 0 = FIFO/BFS; the
    placement-aware sharded fan-out promotes the least-loaded side)."""
    if tree_degree < 1:
        raise ValueError(f"tree_degree must be >= 1, got {tree_degree}")
    policy = ForkPolicy.coerce(policy)
    tree = ForkTree(root=root, degree=tree_degree)
    # [handle, children_served, level, serve quota]
    servers = deque([[root, 0, 0, root_quota or tree_degree]])
    promotable = []                     # (child instance, its level)
    try:
        for node in nodes:
            while servers and servers[0][1] >= servers[0][3]:
                servers.popleft()
            if not servers:
                i = promote(promotable) if promote is not None else 0
                inst, level = promotable.pop(i)
                reseed = inst.node.prepare_fork(inst, lease=child_lease)
                tree.seeds.append(reseed)
                servers.append([reseed, 0, level, tree_degree])
            server = servers[0]
            child = server[0].resume_on(node, policy)
            server[1] += 1
            tree.children.append(child)
            tree.levels.append(server[2] + 1)
            tree.edges.append((server[0], child))
            promotable.append((child, server[2] + 1))
    except BaseException:
        # a failed fan-out must not leak re-seeds (SeedEntry + DC targets)
        # or orphaned children the caller has no handle on — reclaim the
        # partial tree (never the root) before surfacing the error
        tree.close(free_instances=True)
        raise
    return tree
