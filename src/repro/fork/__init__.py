"""Capability-style fork control plane (lease-based, rFaaS-inspired).

This package replaces the raw ``(handler_id, auth_key)`` tuple surface of
``repro.core.fork`` with typed, self-reclaiming handles:

``ForkHandle``
    Serializable capability for one prepared seed: parent node, handler id,
    auth key, lease deadline, generation.  Lifecycle methods ``resume_on``,
    ``renew``, ``revoke``, ``reclaim``, ``fan_out``; usable as a context
    manager (auto-``reclaim()`` on exit).
``ForkPolicy``
    Consolidates the resume knobs (``lazy``/``prefetch``/``descriptor_fetch``/
    sibling-cache participation) with validation.
``ForkTree``
    Result of ``ForkHandle.fan_out``: the §6.3 fork tree, closed (all
    short-lived re-seeds reclaimed) in one call.

Leases and revocation generations are enforced AT THE PARENT during the
authentication RPC: an expired lease raises ``LeaseExpired``, a stale
generation raises ``AccessRevoked`` — children never see a half-valid seed.

Entry point: ``NodeRuntime.prepare_fork(instance, lease=...) -> ForkHandle``.
The old ``fork_prepare``/``fork_resume``/``fork_reclaim`` tuple shims have
been removed; descriptor and page traffic both dispatch through the
``repro.net`` transport registry (``ForkPolicy.descriptor_fetch`` /
``page_fetch`` select backends by name — see ``docs/transport.md``).
"""
from repro.fork.errors import AccessRevoked, LeaseExpired
from repro.fork.handle import DEFAULT_TREE_DEGREE, ForkHandle, prepare_fork
from repro.fork.policy import ForkPolicy
from repro.fork.tree import ForkTree

__all__ = [
    "AccessRevoked",
    "LeaseExpired",
    "ForkHandle",
    "ForkPolicy",
    "ForkTree",
    "prepare_fork",
    "DEFAULT_TREE_DEGREE",
]
