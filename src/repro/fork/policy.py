"""ForkPolicy — one validated object for every resume-time knob.

Replaces the kwargs (``lazy``, ``prefetch``, descriptor/page transport
selection and the node-level sibling-cache flag) that callers used to
re-thread by hand.  Transport choices are names resolved against the
:mod:`repro.net` registry, so the same fork protocol runs over any
registered fabric (``dct``, ``rc``, ``rpc``, ``tpu_ici``, ``shared_fs``,
or a custom backend).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.net import resolve_transport


@dataclasses.dataclass(frozen=True)
class ForkPolicy:
    """How a child resumes from a seed.

    lazy             : map pages on demand (COW) instead of eager full copy
    prefetch         : adjacent pages pulled per fault (0 = none) — these
                       widen the *blocking* read
    async_prefetch   : lookahead window issued as BACKGROUND fetches by the
                       child's PrefetchEngine (0 = off); transfers overlap
                       execution and the clock only waits when a page is
                       touched before its transfer completes
    descriptor_fetch : transport name for the descriptor transfer (repro.net
                       registry); None = the child network's default backend.
                       One-sided backends read the blob RNIC-style behind its
                       DC key; two-sided backends RPC the parent daemon.
    page_fetch       : transport name for first-touch paging; None = the
                       network's default backend
    sibling_cache    : True/False toggles the child node's sibling page
                       cache for this and later forks; None keeps the
                       node's current setting
    reroute_backlog  : seconds of planned-owner link backlog
                       (``Network.link_backlog``) above which the child's
                       Router re-routes VMAs to a cooler sibling replica
                       holding the same bytes (``RoutePlan.reroute``);
                       None = static routes only.  Takes effect on sharded
                       (multi-replica) resumes, where alternates exist.
    """

    lazy: bool = True
    prefetch: int = 0
    async_prefetch: int = 0
    descriptor_fetch: Optional[str] = None
    page_fetch: Optional[str] = None
    sibling_cache: Optional[bool] = None
    reroute_backlog: Optional[float] = None

    def __post_init__(self):
        self.validate()

    def validate(self) -> "ForkPolicy":
        if not isinstance(self.lazy, bool):
            raise ValueError(f"lazy must be a bool, got {self.lazy!r}")
        for field in ("prefetch", "async_prefetch"):
            v = getattr(self, field)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                raise ValueError(f"{field} must be an int >= 0, got {v!r}")
        for field in ("descriptor_fetch", "page_fetch"):
            name = getattr(self, field)
            if name is None:
                continue
            if not isinstance(name, str):
                raise ValueError(
                    f"{field} must be None or a transport name, got {name!r}")
            try:
                resolve_transport(name)
            except ValueError as e:
                raise ValueError(f"{field}: {e}") from None
        if self.sibling_cache is not None and not isinstance(self.sibling_cache, bool):
            raise ValueError(
                f"sibling_cache must be None or a bool, got {self.sibling_cache!r}")
        rb = self.reroute_backlog
        if rb is not None and (isinstance(rb, bool)
                               or not isinstance(rb, (int, float)) or rb < 0):
            raise ValueError(
                f"reroute_backlog must be None or seconds >= 0, got {rb!r}")
        return self

    @classmethod
    def coerce(cls, policy=None) -> "ForkPolicy":
        """Accept None (defaults), a ForkPolicy, or a kwargs dict."""
        if policy is None:
            return cls()
        if isinstance(policy, cls):
            return policy
        if isinstance(policy, dict):
            return cls(**policy)
        raise TypeError(f"cannot build a ForkPolicy from {policy!r}")
