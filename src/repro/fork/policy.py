"""ForkPolicy — one validated object for every resume-time knob.

Replaces the four kwargs (``lazy``, ``prefetch``, ``descriptor_fetch`` and
the node-level sibling-cache flag) that callers used to re-thread by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

DESCRIPTOR_FETCH_MODES = ("rdma", "rpc")


@dataclasses.dataclass(frozen=True)
class ForkPolicy:
    """How a child resumes from a seed.

    lazy             : map pages on demand (COW) instead of eager full copy
    prefetch         : adjacent pages pulled per fault (0 = none)
    descriptor_fetch : "rdma" one-sided read (fast path) | "rpc" (ablation)
    sibling_cache    : True/False toggles the child node's sibling page
                       cache for this and later forks; None keeps the
                       node's current setting
    """

    lazy: bool = True
    prefetch: int = 0
    descriptor_fetch: str = "rdma"
    sibling_cache: Optional[bool] = None

    def __post_init__(self):
        self.validate()

    def validate(self) -> "ForkPolicy":
        if not isinstance(self.lazy, bool):
            raise ValueError(f"lazy must be a bool, got {self.lazy!r}")
        if not isinstance(self.prefetch, int) or isinstance(self.prefetch, bool) \
                or self.prefetch < 0:
            raise ValueError(f"prefetch must be an int >= 0, got {self.prefetch!r}")
        if self.descriptor_fetch not in DESCRIPTOR_FETCH_MODES:
            raise ValueError(
                f"descriptor_fetch must be one of {DESCRIPTOR_FETCH_MODES}, "
                f"got {self.descriptor_fetch!r}")
        if self.sibling_cache is not None and not isinstance(self.sibling_cache, bool):
            raise ValueError(
                f"sibling_cache must be None or a bool, got {self.sibling_cache!r}")
        return self

    @classmethod
    def coerce(cls, policy=None) -> "ForkPolicy":
        """Accept None (defaults), a ForkPolicy, or a kwargs dict."""
        if policy is None:
            return cls()
        if isinstance(policy, cls):
            return policy
        if isinstance(policy, dict):
            return cls(**policy)
        raise TypeError(f"cannot build a ForkPolicy from {policy!r}")
