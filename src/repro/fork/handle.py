"""ForkHandle — the leased capability for one prepared seed.

``prepare_fork`` builds the KB-sized descriptor (page tables + registers,
no memory copy), assigns one DC key per VMA from the pooled targets, and
registers the seed under a fresh (handler_id, auth_key) pair guarded by a
lease deadline and a revocation generation.  The returned handle is the
only thing a child (or the coordinator) needs: it serializes to a small
dict/JSON record and travels over the control plane instead of loose ints.

Enforcement lives at the parent: ``NodeRuntime.auth_seed`` rejects stale
generations with ``AccessRevoked`` and expired leases with ``LeaseExpired``
during the authentication RPC, before any descriptor bytes move.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Optional, Sequence

from repro.core.descriptor import Descriptor
from repro.core.instance import ModelInstance
from repro.core.pagetable import VMA
from repro.fork.policy import ForkPolicy
from repro.net import HandleUnbound, NodeDown

DEFAULT_TREE_DEGREE = 8

_WIRE_FIELDS = ("parent_node", "handler_id", "auth_key", "lease_deadline",
                "generation", "created")


@dataclasses.dataclass
class ForkHandle:
    """Serializable capability: everything a child needs to resume a seed.

    ``runtime`` is the parent NodeRuntime when the handle was minted (or
    rebound) in-process; it is excluded from serialization and only needed
    for the parent-side lifecycle calls (renew / revoke / reclaim).
    ``resume_on`` never needs it — the child reaches the parent through its
    own network, exactly like the RPC in the paper.
    """

    parent_node: str
    handler_id: int
    auth_key: int
    lease_deadline: float = math.inf     # absolute seconds on the parent clock
    generation: int = 0
    created: float = 0.0
    runtime: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        d = {k: getattr(self, k) for k in _WIRE_FIELDS}
        if math.isinf(d["lease_deadline"]):
            d["lease_deadline"] = None      # RFC 8259 JSON has no Infinity
        return d

    @classmethod
    def from_dict(cls, d: dict, runtime=None) -> "ForkHandle":
        d = {k: d[k] for k in _WIRE_FIELDS}
        if d["lease_deadline"] is None:
            d["lease_deadline"] = math.inf
        return cls(runtime=runtime, **d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str, runtime=None) -> "ForkHandle":
        return cls.from_dict(json.loads(s), runtime=runtime)

    def bind(self, runtime) -> "ForkHandle":
        """Re-attach a deserialized handle to its parent runtime."""
        if runtime.node_id != self.parent_node:
            raise ValueError(
                f"handle belongs to {self.parent_node!r}, not {runtime.node_id!r}")
        self.runtime = runtime
        return self

    # -- lease bookkeeping (advisory; the parent is authoritative) ----------

    def _now(self, now: Optional[float] = None) -> float:
        if now is not None:
            return now
        if self.runtime is not None:
            return self.runtime.clock()
        # sim-ok: wall-clock -- only unbound (deserialized) handles outside a
        # sim reach this; bound handles read the parent's clock above
        return time.monotonic()

    def remaining(self, now: Optional[float] = None) -> float:
        """Seconds of lease left (inf for unbounded leases).

        Advisory only: ``lease_deadline`` is absolute on the PARENT's clock.
        Bound handles read that clock; an unbound (deserialized) handle
        falls back to this process's ``time.monotonic()``, which is only
        meaningful when producer and consumer share it (the in-process
        simulation norm) — pass ``now`` explicitly otherwise.  The parent's
        check at auth is always authoritative."""
        if math.isinf(self.lease_deadline):
            return math.inf
        return self.lease_deadline - self._now(now)

    @property
    def expired(self) -> bool:
        return self.remaining() <= 0

    @property
    def alive(self) -> bool:
        """True while the seed is still registered at the (bound) parent —
        False once reclaimed (e.g. by GC), or when the handle is unbound."""
        return (self.runtime is not None
                and self.handler_id in self.runtime.seeds)

    # -- lifecycle ----------------------------------------------------------

    def fetch_descriptor(self, child_node,
                         policy: Optional[ForkPolicy] = None) -> Descriptor:
        """Steps 1–2 of a fork: authentication RPC (lease + generation
        checked at the parent, §5.2) and the descriptor transfer through the
        policy's named transport.  Shared by ``resume_on`` and the sharded
        multi-parent resume (``repro.placement.ShardedSeed``), which fetches
        one descriptor per replica it routes VMAs to."""
        policy = ForkPolicy.coerce(policy)
        net = child_node.network
        if self.parent_node not in net.nodes:
            raise NodeDown(f"parent {self.parent_node} is down")
        parent = net.nodes[self.parent_node]

        # 1) authentication RPC (malformed ids/keys, revoked generations and
        #    expired leases are all rejected here, §5.2)
        info = net.rpc(child_node.node_id, self.parent_node, 64,
                       parent.auth_seed, self.handler_id, self.auth_key,
                       self.generation)

        # 2) descriptor fetch through the named transport: one-sided backends
        #    read the blob RNIC-style behind its own DC key (a reclaimed
        #    seed's descriptor is unreadable, like any VMA); two-sided
        #    backends RPC the parent daemon
        dt = net.transport_obj(policy.descriptor_fetch)
        if dt.one_sided:
            net.read_blob(child_node.node_id, self.parent_node,
                          info["nbytes"], info["desc_key"], transport=dt.name)
            blob = parent.seed_blob(self.handler_id)
        else:
            blob = net.rpc(child_node.node_id, self.parent_node,
                           info["nbytes"], parent.seed_blob, self.handler_id,
                           info["desc_key"], transport=dt.name)
        return Descriptor.from_bytes(blob)

    def resume_on(self, child_node, policy: Optional[ForkPolicy] = None,
                  placement=None) -> ModelInstance:
        """Fork a child onto ``child_node``: authentication RPC (lease +
        generation checked at the parent), one-sided descriptor fetch, child
        page tables shifted one hop up, then lazy paging per ``policy``.

        ``placement`` (a ``repro.placement`` PlacementPolicy) optionally
        routes each VMA over its own transport (e.g. hot weights on ``dct``,
        cold optimizer state on ``shared_fs``); with a single parent every
        route's owner is this handle's parent."""
        policy = ForkPolicy.coerce(policy)
        desc = self.fetch_descriptor(child_node, policy)
        plan = None
        if placement is not None:
            plan = placement.plan_for(desc, [self.parent_node])

        # 3) child address space: page tables shifted one hop up, each VMA
        #    stamped with its owner chain (and plan transport, if routed)
        prepared = desc.extra["prepared_keys"]
        aspace = {}
        for vd in desc.vmas:
            vma = VMA.from_table_dict(vd)
            vma = vma.child_view(prepared[vma.name],
                                 parent_node=self.parent_node,
                                 default_ancestry=desc.ancestry)
            if plan is not None and vma.name in plan:
                vma.transport = plan[vma.name].transport or vma.transport
            aspace[vma.name] = vma
        ancestry = [self.parent_node] + list(desc.ancestry)
        return instantiate_child(child_node, policy, desc, aspace, ancestry)

    def renew(self, extend: Optional[float] = None) -> "ForkHandle":
        """Extend the lease at the parent by ``extend`` seconds (default:
        the original lease duration).  Returns self with the new deadline."""
        self.lease_deadline = self._require_runtime().renew_seed(
            self.handler_id, extend)
        return self

    def revoke(self) -> "ForkHandle":
        """Invalidate every outstanding copy of this handle by bumping the
        seed's generation at the parent.  Returns a fresh handle for the new
        generation (the seed itself stays prepared)."""
        gen = self._require_runtime().revoke_seed(self.handler_id)
        return dataclasses.replace(self, generation=gen)

    def reclaim(self, free_instance: bool = False) -> None:
        """Destroy the seed's DC targets and unregister it; idempotent.
        Subsequent child reads are rejected by the RNIC-analogue and surface
        as AccessRevoked (served via the fallback daemon if pages live)."""
        self._require_runtime().reclaim_seed(self.handler_id,
                                             free_instance=free_instance)

    def fan_out(self, nodes: Sequence, policy: Optional[ForkPolicy] = None,
                tree_degree: int = DEFAULT_TREE_DEGREE,
                child_lease: Optional[float] = None):
        """Fork one child per entry of ``nodes`` through a §6.3 fork tree:
        each seed (the root, then children re-prepared as short-lived seeds)
        serves at most ``tree_degree`` children, so descriptor fan-out load
        spreads over the tree instead of hammering one parent NIC.  Returns
        a ForkTree; ``close()`` reclaims every re-seed in one call."""
        from repro.fork.tree import build_fork_tree
        return build_fork_tree(self, nodes, policy=policy,
                               tree_degree=tree_degree,
                               child_lease=child_lease)

    def _require_runtime(self):
        if self.runtime is None:
            raise HandleUnbound(
                "handle is not bound to its parent runtime; call "
                "handle.bind(parent_node_runtime) after deserializing")
        return self.runtime

    # -- context manager: auto-reclaim on exit ------------------------------

    def __enter__(self) -> "ForkHandle":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.reclaim()


def instantiate_child(child_node, policy: ForkPolicy, desc: Descriptor,
                      aspace, ancestry) -> ModelInstance:
    """Build and policy-configure the child instance from an assembled
    address space — the tail every resume path shares (single-parent
    ``resume_on`` and the sharded multi-parent resume), so prefetch/eager/
    cache semantics cannot drift between them."""
    if policy.sibling_cache is not None:
        child_node.cache_enabled = policy.sibling_cache
    inst = ModelInstance(child_node, desc.arch, desc.kind, aspace,
                         desc.leaf_paths, desc.extra["leaf_names"],
                         ancestry, dict(desc.registers))
    inst.page_transport = policy.page_fetch
    if policy.async_prefetch:
        from repro.core.prefetch import PrefetchEngine
        inst.prefetch_engine = PrefetchEngine(inst, policy.async_prefetch)
    if not policy.lazy:
        # eager restore pipelines through the engine when one is attached:
        # the next VMA's pages transfer while this one assembles
        inst.ensure_all(prefetch=0)
    inst.default_prefetch = policy.prefetch
    return inst


def prepare_fork(node, instance, lease: Optional[float] = None) -> ForkHandle:
    """Prepare ``instance`` as a seed on ``node`` (paper Figure 7
    fork_prepare, plus a lease): descriptor build, DC-key assignment from the
    pooled targets, registration under a fresh (handler_id, auth_key).

    ``lease`` is a duration in seconds; None means unbounded (legacy
    semantics).  Prefer calling this as ``node.prepare_fork(instance, ...)``.
    """
    from repro.platform.node import SeedEntry, make_auth_key

    if lease is not None and lease <= 0:
        raise ValueError(f"lease must be positive seconds or None, got {lease!r}")
    handler_id = next(node._hid)
    auth_key = make_auth_key()
    now = node.clock()
    deadline = math.inf if lease is None else now + lease
    prepared_keys = {name: node.take_dc_target() for name in instance.aspace}
    desc_key = node.take_dc_target()    # guards the descriptor blob itself
    instance.frames_published = True    # remote nodes may now cache our frames
    desc = Descriptor(
        arch=instance.arch,
        kind=instance.kind,
        parent_node=node.node_id,
        handler_id=handler_id,
        ancestry=list(instance.ancestry),
        leaf_paths=instance.leaf_paths,
        vmas=[v.table_dict() for v in instance.aspace.values()],
        registers=dict(instance.registers),
        extra={"prepared_keys": prepared_keys,
               "leaf_names": list(instance.leaf_names)},
        routes={name: {"owner": node.node_id, "transport": v.transport}
                for name, v in instance.aspace.items()},
    )
    blob = desc.to_bytes()
    node.register_seed(handler_id, SeedEntry(
        descriptor=desc, blob=blob, auth_key=auth_key, instance=instance,
        keys=prepared_keys, created=now, lease_deadline=deadline,
        lease_duration=lease, desc_key=desc_key))
    return ForkHandle(parent_node=node.node_id, handler_id=handler_id,
                      auth_key=auth_key, lease_deadline=deadline,
                      generation=0, created=now, runtime=node)
