"""The jit-able training step: microbatch gradient accumulation (lax.scan),
remat policy from the arch config, optional gradient "compression" (bf16
accumulators -> bf16 cross-replica all-reduces, visible in the dry-run's
collective bytes), AdamW + clip + schedule.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.training.schedule import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    microbatches: int = 1
    grad_dtype: str = "float32"      # "bfloat16" = compressed grad collectives
    remat: Optional[str] = None      # None -> cfg.remat_policy
    q_chunk: int = 1024
    exact_causal: bool = False
    xent_chunk: int = 512
    adamw: AdamWConfig = AdamWConfig()


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, tokens, labels) -> (params,
    opt_state, metrics). tokens/labels: (B, S) int32 (or (B,S,CB))."""

    def loss_of(p, tok, lab):
        return lm.loss_fn(p, cfg, tok, lab, q_chunk=tcfg.q_chunk,
                          exact_causal=tcfg.exact_causal, remat=tcfg.remat,
                          xent_chunk=tcfg.xent_chunk)

    grad_fn = jax.value_and_grad(loss_of)
    gdt = jnp.dtype(tcfg.grad_dtype)

    def train_step(params, opt_state, tokens, labels):
        mb = tcfg.microbatches
        B = tokens.shape[0]
        assert B % mb == 0, (B, mb)

        if mb == 1:
            loss, grads = grad_fn(params, tokens, labels)
            grads = jax.tree.map(lambda g: g.astype(gdt), grads)
        else:
            tok_mb = tokens.reshape((mb, B // mb) + tokens.shape[1:])
            lab_mb = labels.reshape((mb, B // mb) + labels.shape[1:])

            def micro(acc, xs):
                tok, lab = xs
                l, g = grad_fn(params, tok, lab)
                acc_l, acc_g = acc
                acc_g = jax.tree.map(lambda a, b: a + b.astype(gdt), acc_g, g)
                return (acc_l + l, acc_g), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero_g), (tok_mb, lab_mb))
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)

        lr = warmup_cosine(opt_state["count"], peak_lr=tcfg.peak_lr,
                           warmup=tcfg.warmup, total=tcfg.total_steps)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr,
                                                tcfg.adamw)
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_serve_prefill(cfg: ArchConfig, cache_len: int, q_chunk: int = 1024):
    def serve_prefill(params, tokens):
        return lm.prefill(params, cfg, tokens, cache_len, q_chunk=q_chunk)
    return serve_prefill


def make_serve_decode(cfg: ArchConfig):
    def serve_decode(params, caches, token, pos):
        return lm.decode_step(params, cfg, caches, token, pos)
    return serve_decode
