"""Data pipeline: deterministic synthetic token stream (seeded, resumable)
with host-side background prefetch and per-host sharding.

Synthetic data is structured (Zipfian unigrams + local bigram correlations)
so cross-entropy actually decreases — good enough to validate end-to-end
training dynamics without shipping a corpus.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import numpy as np


class TokenStream:
    """Deterministic, seekable stream of (tokens, labels) batches."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, num_hosts: int = 1, host_id: int = 0,
                 codebooks: int = 1):
        assert batch % num_hosts == 0
        self.vocab = vocab_size
        self.batch = batch // num_hosts
        self.seq = seq_len
        self.seed = seed
        self.host = host_id
        self.num_hosts = num_hosts
        self.codebooks = codebooks
        # Zipf-ish unigram table + a deterministic "grammar" matrix
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = rng.integers(1, vocab_size, size=64)

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed, step, self.host))
        shape = (self.batch, self.seq + 1)
        if self.codebooks > 1:
            shape = shape + (self.codebooks,)
        toks = rng.choice(self.vocab, size=shape, p=self._probs).astype(np.int32)
        # bigram correlation: every odd position continues the previous token
        cont = (toks[:, :-1] + self._shift[step % 64]) % self.vocab
        mask = (np.arange(self.seq + 1)[1:] % 2 == 1)
        if self.codebooks > 1:
            toks[:, 1:][:, mask] = cont[:, mask]
        else:
            toks[:, 1:][:, mask] = cont[:, mask]
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread double buffering over a TokenStream."""

    def __init__(self, stream: TokenStream, start_step: int = 0, depth: int = 2):
        self.stream = stream
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put(self.stream.batch_at(s), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def next(self):
        item = self._q.get()
        self.step += 1
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._t.join(timeout=1.0)
