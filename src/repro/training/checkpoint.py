"""Fault-tolerant checkpointing: per-leaf .npy + msgpack manifest, atomic
rename commit, optional async save thread, keep-last-k GC.

This is also the COLDSTART / C-R baseline of the paper's Table 1: restoring
from a checkpoint is what remote fork avoids.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import msgpack
import numpy as np

from repro.core.descriptor import flatten_with_names, unflatten_from_paths


def _save_tree(d: str, name: str, tree) -> dict:
    names, paths, leaves = flatten_with_names(tree)
    meta = {"paths": paths, "dtypes": [], "shapes": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        meta["dtypes"].append(str(arr.dtype))
        meta["shapes"].append(list(arr.shape))
        np.save(os.path.join(d, f"{name}.{i}.npy"), arr)
    return meta


def _load_tree(d: str, name: str, meta) -> Any:
    leaves = []
    for i, (dt, sh) in enumerate(zip(meta["dtypes"], meta["shapes"])):
        arr = np.load(os.path.join(d, f"{name}.{i}.npy"))
        leaves.append(arr)
    return unflatten_from_paths(meta["paths"], leaves)


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state=None,
                    extra: Optional[dict] = None, keep: int = 3,
                    async_save: bool = False):
    """Atomic: write into <dir>/tmp-<step>, fsync-free rename to step-<step>."""

    def _do():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f"tmp-{step}")
        final = os.path.join(ckpt_dir, f"step-{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra or {}, "time": time.time()}
        manifest["params"] = _save_tree(tmp, "params", params)
        if opt_state is not None:
            manifest["opt"] = _save_tree(tmp, "opt", opt_state)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        _gc(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=_do, daemon=True)
        t.start()
        return t
    _do()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step-"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step-"))
    return int(steps[-1].split("-")[1]) if steps else None


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None
                    ) -> Tuple[int, Any, Any, dict]:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step-{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read(), strict_map_key=False)
    params = _load_tree(d, "params", manifest["params"])
    opt = _load_tree(d, "opt", manifest["opt"]) if "opt" in manifest else None
    return manifest["step"], params, opt, manifest.get("extra", {})


def checkpoint_nbytes(ckpt_dir: str, step: int) -> int:
    d = os.path.join(ckpt_dir, f"step-{step:08d}")
    return sum(os.path.getsize(os.path.join(d, f)) for f in os.listdir(d))
