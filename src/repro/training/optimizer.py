"""In-house AdamW with global-norm clipping and decoupled weight decay.

Optimizer state mirrors param sharding (ZeRO-style: m/v shard exactly like
their params under the FSDP rules), and its dtype follows the param dtype so
trillion-parameter configs can run bf16 states.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(params, grads, state, lr, cfg: AdamWConfig):
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_n = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_n = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        step = (m_n / c1) / (jnp.sqrt(v_n / c2) + cfg.eps)
        p_n = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return p_n.astype(p.dtype), m_n.astype(m.dtype), v_n.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm
