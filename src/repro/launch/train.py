"""Training driver.

Runs real steps on the local device(s); the same step function is what the
dry-run lowers for the production meshes.  Supports checkpoint/restart
(--resume), simulated failure (--fail-at), gradient compression, and the
fork-based elastic/recovery path exercised by examples/train_elastic.py.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch train-100m --steps 200 \
      --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, reduce_for_smoke
from repro.models import lm
from repro.models.flops import param_counts
from repro.training import checkpoint as ckpt
from repro.training.data import Prefetcher, TokenStream
from repro.training.optimizer import init_opt_state
from repro.training.train_step import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="train-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduce the arch config to smoke scale")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", choices=["none", "bf16"],
                    default="none")
    ap.add_argument("--remat", choices=["none", "full", "dots"], default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="simulate a crash after N steps (tests restart)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              microbatches=args.microbatches)
    N, Na, _ = param_counts(cfg)
    print(f"[train] arch={cfg.name} params={N/1e6:.1f}M active={Na/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    tcfg = TrainConfig(
        peak_lr=args.lr, warmup=args.warmup, total_steps=args.steps,
        microbatches=args.microbatches,
        grad_dtype="bfloat16" if args.grad_compression == "bf16" else "float32",
        remat=args.remat, q_chunk=max(256, args.seq // 4),
        xent_chunk=min(256, args.seq))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        start, params, opt_state, extra = ckpt.load_checkpoint(args.ckpt_dir)
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        print(f"[train] resumed from step {start}")
    else:
        params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
        opt_state = init_opt_state(params)

    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=args.seed,
                         codebooks=cfg.num_codebooks)
    pf = Prefetcher(stream, start_step=start)
    losses = []
    t0 = time.perf_counter()
    try:
        for step in range(start, args.steps):
            tok, lab = pf.next()
            params, opt_state, metrics = step_fn(
                params, opt_state, jnp.asarray(tok), jnp.asarray(lab))
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.perf_counter() - t0
                tput = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} tok/s {tput_fmt(tput)}")
            if args.ckpt_dir and args.save_every and \
                    (step + 1) % args.save_every == 0:
                ckpt.save_checkpoint(args.ckpt_dir, step + 1, params,
                                     opt_state, extra={"loss": losses[-1]})
            if args.fail_at >= 0 and step + 1 >= args.fail_at:
                print(f"[train] simulated crash at step {step + 1}")
                raise SystemExit(42)
    finally:
        pf.close()
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


def tput_fmt(x: float) -> str:
    return f"{x/1e3:.1f}k" if x > 1e3 else f"{x:.0f}"


if __name__ == "__main__":
    main()
