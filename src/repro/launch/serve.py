"""Serving driver: spin up a mini cluster, deploy a seed, serve requests via
remote fork, demo KV-prefix forking.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch micro-small --requests 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core.instance import ModelInstance
from repro.net import Network
from repro.fork import ForkPolicy
from repro.models import lm
from repro.platform.node import NodeRuntime
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="micro-small")
    ap.add_argument("--nodes", type=int, default=3)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--fork-demo", action="store_true")
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(get_arch(args.arch), compute_dtype="float32")
    net = Network()
    nodes = [NodeRuntime(f"node{i}", net, cache_enabled=True)
             for i in range(args.nodes)]

    # Seed replica on node0 — the single provisioned instance (O(1))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    seed_inst = ModelInstance.create(nodes[0], cfg.name, params)
    handle = nodes[0].prepare_fork(seed_inst)
    print(f"[serve] seed on node0: {seed_inst.total_bytes()/2**20:.1f} MiB, "
          f"descriptor {len(nodes[0].seeds[handle.handler_id].blob)/1024:.1f} KiB")

    # Scale out: each remaining node forks the seed and serves
    policy = ForkPolicy(lazy=True, prefetch=1)
    engines = []
    for node in nodes[1:]:
        t0 = time.perf_counter()
        child = handle.resume_on(node, policy)
        child_params = child.materialize_pytree()
        dt = time.perf_counter() - t0
        print(f"[serve] {node.node_id}: forked replica in {dt*1e3:.1f} ms "
              f"({child.stats['pages_rdma']} pages via RDMA)")
        engines.append(ServingEngine(cfg, child_params, backend="ref"))

    rng = jax.random.PRNGKey(1)
    for i in range(args.requests):
        eng = engines[i % len(engines)]
        prompt = jax.random.randint(jax.random.fold_in(rng, i), (6,), 0,
                                    cfg.vocab_size).tolist()
        rid = eng.submit(prompt, max_tokens=args.max_tokens)
        out = eng.run_to_completion()[rid]
        print(f"[serve] req{i} -> {out}")

    if args.fork_demo:
        eng = engines[0]
        r0 = eng.submit([1, 2, 3, 4], max_tokens=6)
        eng.step()
        eng.step()      # prefill + two decode steps, request still live
        kids = [eng.fork_request(r0, max_tokens=4) for _ in range(3)]
        res = eng.run_to_completion()
        print(f"[serve] fork-demo parent={res[r0]} children="
              f"{[res[k] for k in kids]} (shared prefix pages, COW)")
    print("[serve] network:", net.snapshot())


if __name__ == "__main__":
    main()
