import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, get_arch, shape_applicable
from repro.distributed import hlo_analysis
from repro.distributed.roofline import HBM_PER_CHIP, roofline
from repro.distributed.sharding import (batch_pspec, cache_shardings,
                                        make_axis_env, params_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models import flops as flops_mod
from repro.models import lm
from repro.training.optimizer import init_opt_state
from repro.training.train_step import (TrainConfig, make_serve_decode,
                                       make_serve_prefill, make_train_step)

ARCHS = [
    "stablelm-3b", "gemma3-1b", "granite-34b", "qwen2-7b", "zamba2-2.7b",
    "kimi-k2-1t-a32b", "moonshot-v1-16b-a3b", "musicgen-large", "xlstm-1.3b",
    "chameleon-34b",
]

OUT_DIR = os.environ.get("DRYRUN_OUT", "artifacts/dryrun")


def _sds(tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def _tok_shape(cfg: ArchConfig, B: int, S: int):
    if cfg.num_codebooks > 1:
        return (B, S, cfg.num_codebooks)
    return (B, S)


def input_specs(arch: str, shape_name: str, multi_pod: bool = False,
                opts: dict = None):
    """ShapeDtypeStruct stand-ins (weak-type-correct, sharded, no alloc) for
    every input of the lowered step, plus the step callable itself.

    `opts` — hillclimb levers (EXPERIMENTS.md §Perf):
      tp_only_params : replicate params over data (serving sharding)
      remat          : none|full|dots
      exact_causal   : python-unrolled exact causal KV slices
      grad_dtype     : float32|bfloat16 (compressed grad collectives)
      microbatches, q_chunk, xent_chunk : ints
      arch overrides : any ArchConfig field, e.g. moe_capacity_factor
    """
    opts = dict(opts or {})
    cfg = get_arch(arch)
    arch_fields = {f.name for f in __import__("dataclasses").fields(cfg)}
    arch_over = {k: v for k, v in opts.items() if k in arch_fields}
    if arch_over:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **arch_over)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    env = make_axis_env(mesh,
                        attn_policy=opts.get("attn_policy", "v1"),
                        moe_impl=opts.get("moe_impl", "gspmd"),
                        mamba_tp=bool(opts.get("mamba_tp", False)))
    B, S = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)

    p_shapes = jax.eval_shape(functools.partial(lm.init_params, cfg=cfg), key)
    if opts.get("tp_only_params") and shape.step != "train":
        import dataclasses as _dc
        p_env = _dc.replace(env, fsdp=())
    else:
        p_env = env
    p_sh = params_shardings(cfg, p_shapes, p_env)
    params = _sds(p_shapes, p_sh)

    if shape.step == "train":
        mb = int(opts.get("microbatches", cfg.microbatches))
        while mb > 1 and (B // mb) % env.dpsize != 0:
            mb //= 2
        mb = max(1, min(mb, B // env.dpsize))
        tcfg = TrainConfig(microbatches=mb,
                           remat=opts.get("remat"),
                           grad_dtype=opts.get("grad_dtype", "float32"),
                           q_chunk=int(opts.get("q_chunk", 1024)),
                           exact_causal=bool(opts.get("exact_causal", False)),
                           xent_chunk=int(opts.get("xent_chunk", 512)))
        step_fn = make_train_step(cfg, tcfg)
        o_shapes = jax.eval_shape(init_opt_state, p_shapes)
        o_sh = {"m": p_sh, "v": p_sh,
                "count": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        opt = _sds(o_shapes, o_sh)
        tok_sh = jax.sharding.NamedSharding(mesh, batch_pspec(B, env))
        tokens = jax.ShapeDtypeStruct(_tok_shape(cfg, B, S), jnp.int32, sharding=tok_sh)
        labels = jax.ShapeDtypeStruct(_tok_shape(cfg, B, S), jnp.int32, sharding=tok_sh)
        return dict(step="train", fn=step_fn, args=(params, opt, tokens, labels),
                    mesh=mesh, env=env, cfg=cfg, shape=shape, donate=(0, 1),
                    meta={"microbatches": mb})

    if shape.step == "prefill":
        step_fn = make_serve_prefill(cfg, cache_len=S,
                                     q_chunk=int(opts.get("q_chunk", 1024)))
        tok_sh = jax.sharding.NamedSharding(mesh, batch_pspec(B, env))
        tokens = jax.ShapeDtypeStruct(_tok_shape(cfg, B, S), jnp.int32, sharding=tok_sh)
        return dict(step="prefill", fn=step_fn, args=(params, tokens),
                    mesh=mesh, env=env, cfg=cfg, shape=shape, donate=(),
                    meta={})

    # decode: one new token against a KV cache of S
    step_fn = make_serve_decode(cfg)
    c_shapes = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, B, S, dtype=jnp.bfloat16))
    c_sh = cache_shardings(cfg, c_shapes, env, B)
    caches = _sds(c_shapes, c_sh)
    tok_sh = jax.sharding.NamedSharding(mesh, batch_pspec(B, env))
    tshape = (B, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B,)
    token = jax.ShapeDtypeStruct(tshape, jnp.int32, sharding=tok_sh)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=tok_sh)
    return dict(step="decode", fn=step_fn, args=(params, caches, token, pos),
                mesh=mesh, env=env, cfg=cfg, shape=shape, donate=(1,),
                meta={})


def _mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "host_generated_code_size_in_bytes",
              "host_argument_size_in_bytes", "host_output_size_in_bytes",
              "host_temp_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: dict = None, tag: str = "") -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_tag = "pod512" if multi_pod else "pod256"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_tag, "tag": tag,
            "opts": opts or {}}
    if not ok:
        cell.update(status="skipped", reason=why)
        return cell

    t0 = time.time()
    spec = input_specs(arch, shape_name, multi_pod, opts)
    fn = jax.jit(spec["fn"], donate_argnums=spec["donate"])
    from repro.distributed import ctx as _ctx
    with _ctx.use_env(spec["env"]):
        lowered = fn.lower(*spec["args"])
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    chips = int(np.prod(list(spec["mesh"].shape.values())))
    ca = compiled.cost_analysis() or {}

    # Loop-aware extraction from the partitioned module (per-device), then
    # normalized to global. XLA's own cost_analysis counts while bodies once;
    # we keep it for reference only.
    hlo = compiled.as_text()
    an = hlo_analysis.analyze(hlo)
    flops_dev = an["dot_flops"]
    bytes_dev = an["traffic_bytes"]
    coll = an["collectives"]
    coll_dev = hlo_analysis.total_collective_bytes(coll)
    flops_global = flops_dev * chips
    bytes_global = bytes_dev * chips
    rl = roofline(flops_global, bytes_global, coll_dev * chips, chips)

    mf = flops_mod.model_flops(spec["cfg"], shape)
    mem = _mem_analysis_dict(compiled)
    arg_b = mem.get("argument_size_in_bytes", 0)
    tmp_b = mem.get("temp_size_in_bytes", 0)
    cell.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        cost_analysis={"flops_per_device": flops_dev,
                       "bytes_per_device": bytes_dev,
                       "xla_flops_raw": float(ca.get("flops", 0.0)),
                       "xla_bytes_raw": float(ca.get("bytes accessed", 0.0))},
        memory_analysis=mem,
        bytes_per_device_total=arg_b + tmp_b,
        fits_hbm=bool((arg_b + tmp_b) <= HBM_PER_CHIP) if (arg_b or tmp_b) else None,
        collectives=coll,
        collective_bytes_per_device=coll_dev,
        roofline=rl.to_dict(),
        model_flops=mf,
        useful_flops_ratio=(mf / flops_global) if flops_global else None,
        roofline_fraction=rl.fraction_of_roofline(mf),
        hlo_bytes=len(hlo),
        meta=spec["meta"],
    )
    return cell


def cell_path(arch, shape_name, multi_pod, tag=""):
    mesh_tag = "pod512" if multi_pod else "pod256"
    t = f"--{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}--{shape_name}--{mesh_tag}{t}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", action="append", default=[],
                    help="hillclimb lever key=value (repeatable)")
    args = ap.parse_args()

    opts = {}
    for kv in args.opt:
        k, v = kv.split("=", 1)
        try:
            import ast
            opts[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            opts[k] = v

    os.makedirs(OUT_DIR, exist_ok=True)
    cells = []
    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        path = cell_path(a, s, mp, args.tag)
        if args.skip_done and os.path.exists(path):
            print(f"[skip] {path}")
            continue
        print(f"[dryrun] {a} x {s} x {'pod512' if mp else 'pod256'} "
              f"{opts or ''}...", flush=True)
        try:
            res = run_cell(a, s, mp, opts=opts, tag=args.tag)
        except Exception as e:
            res = {"arch": a, "shape": s,
                   "mesh": "pod512" if mp else "pod256", "tag": args.tag,
                   "status": "error", "error": repr(e),
                   "trace": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"  -> {res['status']}"
              + (f" compile={res.get('compile_s')}s dominant="
                 f"{res.get('roofline', {}).get('dominant')}"
                 if res["status"] == "ok" else f" {res.get('error','')[:200]}"),
              flush=True)


if __name__ == "__main__":
    main()
