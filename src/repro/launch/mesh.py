"""Production meshes. Functions, not module constants — importing this file
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, pod: int = 0):
    """Small mesh for CI-grade tests (requires forced host device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
