"""Data-/control-plane exceptions shared by every transport backend.

Everything operational raised by the fork / paging / transport paths
derives from one :class:`ReproError` base carrying a machine-readable
``.kind`` — fault handlers, autoscalers and the chaos harness dispatch on
the kind string instead of matching exception classes or message text.

The taxonomy dual-inherits from the builtin exception each error used to
be (``ConnectionError``, ``PermissionError``, ``RuntimeError``,
``KeyError``) so every pre-taxonomy ``except`` clause keeps catching what
it caught before; new code should catch :class:`ReproError` /
:class:`TransportError` and branch on ``.kind``.
"""
from typing import ClassVar


class ReproError(Exception):
    """Base of every typed operational error in the stack.

    ``kind`` is a stable machine-readable discriminator (telemetry keys,
    chaos-test assertions); ``str(e)`` stays the human-readable detail.
    """

    kind: ClassVar[str] = "error"


# -- transport / fabric ------------------------------------------------------

class TransportError(ReproError, ConnectionError):
    """A data-plane operation failed at the fabric: peer unreachable,
    timed out, or retries exhausted.  The recovery chain (sibling replica
    -> seed re-replication -> coldstart degradation) starts here."""

    kind = "transport"


class NodeDown(TransportError):
    """The target node left the network (crash / unregister) — membership
    is authoritative, so this is raised without retrying."""

    kind = "node_down"


class ReadTimeout(TransportError):
    """One op attempt exceeded ``NetModel.op_timeout_s`` (injected NIC
    flap or per-op fault).  Retried up to the backend's ``max_retries``."""

    kind = "read_timeout"


class RetriesExhausted(TransportError):
    """Every retry attempt of an op timed out — the backend gives up and
    the caller must fail over (RC additionally tore its connection down)."""

    kind = "retries_exhausted"


class SeedUnavailable(TransportError):
    """A (sharded) seed has no live replica left to serve from."""

    kind = "seed_unavailable"


class RecoveryFailed(TransportError):
    """The fault-handler recovery chain ran out of options (no usable
    sibling, no re-replicable seed) — callers degrade to coldstart."""

    kind = "recovery_failed"


# -- capability / lease control plane ----------------------------------------

class AccessRevoked(ReproError, PermissionError):
    """One-sided access rejected: the DC target is gone or the handle's
    generation was revoked at the parent (§5.2 connection-based control)."""

    kind = "access_revoked"


class LeaseExpired(ReproError, PermissionError):
    """The seed's lease ran out before the child authenticated — the parent
    refuses resume, mirroring rFaaS-style leased capabilities."""

    kind = "lease_expired"


class AuthError(ReproError, PermissionError):
    """Bad fork credentials: unknown handler id or wrong auth key."""

    kind = "bad_credentials"


class SeedGone(ReproError, KeyError):
    """The seed entry no longer exists at the parent (reclaimed, or the
    parent restarted) — renew/reclaim against it cannot proceed."""

    kind = "seed_gone"


# -- control-plane preconditions ---------------------------------------------

class HandleUnbound(ReproError, RuntimeError):
    """A local-only ForkHandle operation needs the parent runtime bound."""

    kind = "handle_unbound"


class NoNodesAvailable(ReproError, RuntimeError):
    """The scheduler found no live node to place on."""

    kind = "no_nodes"
