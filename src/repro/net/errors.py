"""Data-/control-plane exceptions shared by every transport backend."""


class AccessRevoked(PermissionError):
    """One-sided access rejected: the DC target is gone or the handle's
    generation was revoked at the parent (§5.2 connection-based control)."""


class LeaseExpired(PermissionError):
    """The seed's lease ran out before the child authenticated — the parent
    refuses resume, mirroring rFaaS-style leased capabilities."""
