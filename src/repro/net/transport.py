"""Transport — the pluggable data-plane interface (§5.3, Fig. 18 ablation).

MITOSIS's core claim is that remote-fork speed comes from the *choice* of
data path: one-sided RDMA reads vs two-sided RPC vs distributed-FS
checkpoints.  A ``Transport`` makes that choice a first-class, name-keyed
object instead of string flags scattered through the data plane.  One
interface sits behind all three traffic classes:

``read_pages``   one VMA page gather out of the owner pool (paging fast path)
``read_blob``    an opaque blob fetch (descriptor transfer)
``rpc``          a two-sided call executed by the destination (control plane,
                 fallback daemon, message baselines)

Every backend declares capability flags (``one_sided``: reads bypass the
owner's CPU, like an RNIC/DMA engine; ``connection_oriented``: pays a
per-(src, dst) setup cost) and derives its per-op latency and per-byte
bandwidth from the shared :class:`~repro.net.model.NetModel`.  Access
control is identical across backends: every read — page or descriptor —
is admitted iff its DC key is a live target at the network, so a reclaimed
seed is unreadable over *any* fabric, not just RDMA.

Metering is aggregated at the :class:`~repro.net.network.Network` but tagged
per backend: each op charges ``{name}.bytes`` / ``{name}.ops`` (plus
``{name}.setups`` / ``{name}.setup_s`` for connection-oriented backends)
alongside the legacy category aggregates (``rdma_*``, ``rpc_*``, ``ici_*``,
``dfs_*``) that benchmarks and examples report.

Registering a custom backend::

    from repro.net import Transport, register_transport

    @register_transport
    class CxlTransport(Transport):
        name = "cxl"
        one_sided = True
        legacy_meter = "rdma"
        def op_latency(self):  return 300e-9
        def bandwidth(self):   return 64e9

``Network(transport="cxl")`` / ``ForkPolicy(page_fetch="cxl")`` then resolve
it by name; unknown names raise ``ValueError`` listing what is registered.
"""
from __future__ import annotations

import abc
from typing import ClassVar, Dict, List, Optional, Type


_REGISTRY: Dict[str, Type["Transport"]] = {}


def register_transport(cls: Type["Transport"]) -> Type["Transport"]:
    """Class decorator: key ``cls`` by its ``name`` in the global registry.
    The required ClassVars are checked here so a malformed backend fails at
    registration, not deep inside its first resume_on."""
    name = getattr(cls, "name", None)
    if not isinstance(name, str) or not name:
        raise ValueError(f"transport class {cls!r} must define a `name` string")
    if not isinstance(getattr(cls, "one_sided", None), bool):
        raise ValueError(
            f"transport {name!r} must define the `one_sided` bool ClassVar")
    if not isinstance(getattr(cls, "legacy_meter", None), str):
        raise ValueError(
            f"transport {name!r} must define the `legacy_meter` str ClassVar "
            "(aggregate category, e.g. 'rdma' or 'rpc')")
    _REGISTRY[name] = cls
    return cls


def transport_names() -> List[str]:
    """Sorted names of every registered transport backend."""
    return sorted(_REGISTRY)


def resolve_transport(name: str) -> Type["Transport"]:
    """Look a backend class up by name; unknown names fail loudly."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; registered transports: "
            f"{', '.join(transport_names())}") from None


class Transport(abc.ABC):
    """One data-plane fabric: cost model + data movement + capability flags.

    Instances are created per :class:`Network` (``net.transport_obj(name)``)
    and charge all traffic back into the network's meter/sim clock.
    """

    name: ClassVar[str]
    one_sided: ClassVar[bool]                  # reads bypass the owner's CPU
    connection_oriented: ClassVar[bool] = False  # pays setup per (src, dst)
    legacy_meter: ClassVar[str]                # aggregate category: rdma|rpc|ici|dfs

    def __init__(self, net):
        self.net = net
        self.model = net.model

    # -- cost model ---------------------------------------------------------

    def setup_cost(self) -> float:
        """Seconds to bring up one (src, dst) connection (0 = connectionless)."""
        return 0.0

    @abc.abstractmethod
    def op_latency(self) -> float:
        """Seconds of fixed latency per read op."""

    @abc.abstractmethod
    def bandwidth(self) -> float:
        """Bytes/second for bulk payload movement."""

    def rpc_latency(self) -> float:
        """Seconds of fixed latency per two-sided round trip."""
        return self.model.rpc_lat

    # -- data plane ---------------------------------------------------------

    def read_pages(self, src: str, dst: str, dtype, frames, dc_key: int):
        """Read ``frames`` out of dst's pool.  Admitted iff (dst, dc_key) is
        a live DC target — revoking the target kills access on EVERY backend."""
        node = self.net.require_node(dst)
        self.net.check_target(dst, dc_key)
        self._setup(src, dst)
        pages = node.pool.read_pages(dtype, frames)
        nbytes = pages.size * pages.dtype.itemsize
        self._charge("read", nbytes,
                     self.op_latency() + nbytes / self.bandwidth())
        return pages

    def read_blob(self, src: str, dst: str, nbytes: int, dc_key: int) -> None:
        """Metered fetch of an opaque blob (descriptor transfer).  Guarded by
        the blob's own DC key, exactly like a VMA."""
        self.net.require_node(dst)
        self.net.check_target(dst, dc_key)
        self._setup(src, dst)
        self._charge("read", nbytes,
                     self.op_latency() + nbytes / self.bandwidth())

    def rpc(self, src: str, dst: str, nbytes: int, fn, *args, **kwargs):
        """Two-sided call executed by the destination node (FaSST-style)."""
        self.net.require_node(dst)
        self._charge("rpc", nbytes,
                     self.rpc_latency() + nbytes / self.bandwidth())
        return fn(*args, **kwargs)

    # -- metering -----------------------------------------------------------

    def _setup(self, src: str, dst: str) -> None:
        if not self.connection_oriented:
            return
        if not self.net.note_connection(self.name, src, dst):
            return
        cost = self.setup_cost()
        meter = self.net.meter
        meter["conn_setups"] += 1
        meter[f"{self.name}.setups"] += 1
        meter[f"{self.name}.setup_s"] += cost
        self.net.sim_time += cost

    def _charge(self, kind: str, nbytes: int, seconds: float) -> None:
        meter = self.net.meter
        meter[f"{self.name}.bytes"] += nbytes
        meter[f"{self.name}.ops"] += 1
        category = "rpc" if kind == "rpc" else self.legacy_meter
        meter[f"{category}_bytes"] += nbytes
        meter[f"{category}_ops"] += 1
        self.net.sim_time += seconds
