"""Transport — the pluggable data-plane interface (§5.3, Fig. 18 ablation).

MITOSIS's core claim is that remote-fork speed comes from the *choice* of
data path: one-sided RDMA reads vs two-sided RPC vs distributed-FS
checkpoints.  A ``Transport`` makes that choice a first-class, name-keyed
object instead of string flags scattered through the data plane.  One
interface sits behind all three traffic classes:

``read_pages``   one VMA page gather out of the owner pool (paging fast path)
``read_blob``    an opaque blob fetch (descriptor transfer)
``rpc``          a two-sided call executed by the destination (control plane,
                 fallback daemon, message baselines)

Every backend declares capability flags (``one_sided``: reads bypass the
owner's CPU, like an RNIC/DMA engine; ``connection_oriented``: pays a
per-(src, dst) setup cost) and derives its per-op latency and per-byte
bandwidth from the shared :class:`~repro.net.model.NetModel`.  Access
control is identical across backends: every read — page or descriptor —
is admitted iff its DC key is a live target at the network, so a reclaimed
seed is unreadable over *any* fabric, not just RDMA.

Metering is aggregated at the :class:`~repro.net.network.Network` but tagged
per backend: each op charges ``{name}.bytes`` / ``{name}.ops`` (plus
``{name}.setups`` / ``{name}.setup_s`` for connection-oriented backends, and
``{name}.sges`` / ``{name}.async_ops`` on the paging path) alongside the
legacy category aggregates (``rdma_*``, ``rpc_*``, ``ici_*``, ``dfs_*``)
that benchmarks and examples report.

Page reads are *doorbell-batched*: the frame list is split into maximal
contiguous runs (one scatter-gather entry each), and one posted op carries
up to ``max_sge`` runs — so fragmentation and tiny faults show up in
``sim_time`` while extent-packed VMAs move in a handful of ops (see
``docs/paging.md``).

Registering a custom backend::

    from repro.net import Transport, register_transport

    @register_transport
    class CxlTransport(Transport):
        name = "cxl"
        one_sided = True
        legacy_meter = "rdma"
        def op_latency(self):  return 300e-9
        def bandwidth(self):   return 64e9

``Network(transport="cxl")`` / ``ForkPolicy(page_fetch="cxl")`` then resolve
it by name; unknown names raise ``ValueError`` listing what is registered.
"""
from __future__ import annotations

import abc
import math
from typing import ClassVar, Dict, List, Optional, Type

import numpy as np

from repro.net.errors import RetriesExhausted


_REGISTRY: Dict[str, Type["Transport"]] = {}


def contiguous_runs(frames) -> int:
    """Number of maximal contiguous ascending runs in ``frames`` — the
    scatter-gather entry (SGE) count a doorbell-batched read needs.  A
    fully contiguous gather is 1 run; a fully scattered one is len(frames)."""
    idx = np.asarray(frames, np.int64).ravel()
    if idx.size == 0:
        return 0
    return 1 + int(np.count_nonzero(np.diff(idx) != 1))


def register_transport(cls: Type["Transport"]) -> Type["Transport"]:
    """Class decorator: key ``cls`` by its ``name`` in the global registry.
    The required ClassVars are checked here so a malformed backend fails at
    registration, not deep inside its first resume_on."""
    name = getattr(cls, "name", None)
    if not isinstance(name, str) or not name:
        raise ValueError(f"transport class {cls!r} must define a `name` string")
    if not isinstance(getattr(cls, "one_sided", None), bool):
        raise ValueError(
            f"transport {name!r} must define the `one_sided` bool ClassVar")
    if not isinstance(getattr(cls, "legacy_meter", None), str):
        raise ValueError(
            f"transport {name!r} must define the `legacy_meter` str ClassVar "
            "(aggregate category, e.g. 'rdma' or 'rpc')")
    max_sge = getattr(cls, "max_sge", None)
    if not isinstance(max_sge, int) or isinstance(max_sge, bool) or max_sge < 1:
        raise ValueError(
            f"transport {name!r} must define `max_sge` as an int >= 1 "
            f"(scatter-gather entries per doorbell op), got {max_sge!r}")
    kind = getattr(cls, "conn_kind", None)
    if getattr(cls, "connection_oriented", False):
        if kind not in ("peer", "dc"):
            raise ValueError(
                f"connection-oriented transport {name!r} must declare "
                f"`conn_kind` as 'peer' (per-pair QP, slots at both "
                f"endpoints) or 'dc' (one initiator/target context per "
                f"node), got {kind!r}")
    elif kind is not None:
        raise ValueError(
            f"connectionless transport {name!r} must leave `conn_kind` as "
            f"None, got {kind!r}")
    _REGISTRY[name] = cls
    return cls


def transport_names() -> List[str]:
    """Sorted names of every registered transport backend."""
    return sorted(_REGISTRY)


def resolve_transport(name: str) -> Type["Transport"]:
    """Look a backend class up by name; unknown names fail loudly."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; registered transports: "
            f"{', '.join(transport_names())}") from None


class Transport(abc.ABC):
    """One data-plane fabric: cost model + data movement + capability flags.

    Instances are created per :class:`Network` (``net.transport_obj(name)``)
    and charge all traffic back into the network's meter/sim clock.
    """

    name: ClassVar[str]
    one_sided: ClassVar[bool]                  # reads bypass the owner's CPU
    connection_oriented: ClassVar[bool] = False  # pays setup per (src, dst)
    # pool shape for connection-oriented fabrics: "peer" = one QP per
    # (src, dst) occupying a slot at BOTH endpoints (RC); "dc" = one
    # initiator context per src + one target context per dst, each a
    # single slot shared across every peer (DCT).  None = connectionless.
    conn_kind: ClassVar[Optional[str]] = None
    legacy_meter: ClassVar[str]                # aggregate category: rdma|rpc|ici|dfs
    max_sge: ClassVar[int] = 16                # SGEs per doorbell-batched op
    # how many times one op is re-posted after a timeout before the backend
    # surfaces RetriesExhausted; 0 = fail over immediately (the rpc path's
    # "fall back" semantics).  Only consulted when a FaultInjector is
    # installed on the network — the fault-free path never checks.
    max_retries: ClassVar[int] = 2

    def __init__(self, net):
        self.net = net
        self.model = net.model

    # -- cost model ---------------------------------------------------------

    def setup_cost(self) -> float:
        """Seconds to bring up one (src, dst) connection (0 = connectionless)."""
        return 0.0

    @abc.abstractmethod
    def op_latency(self) -> float:
        """Seconds of fixed latency per read op."""

    @abc.abstractmethod
    def bandwidth(self) -> float:
        """Bytes/second for bulk payload movement."""

    def rpc_latency(self) -> float:
        """Seconds of fixed latency per two-sided round trip."""
        return self.model.rpc_lat

    # -- fault plane --------------------------------------------------------

    def op_timeout(self) -> float:
        """Seconds one attempt holds its lane before it is declared lost."""
        return self.model.op_timeout_s

    def _penalty(self, src: str, dst: str) -> float:
        """Degradation multiplier (>= 1.0) on this transfer's wire time —
        1.0 exactly when no fault injector is installed or neither endpoint
        NIC is degraded, so the fault-free cost model is bit-identical."""
        inj = self.net.faults
        if inj is None:
            return 1.0
        return inj.penalty(src, dst)

    def _admit(self, op: str, src: str, dst: str, sync: bool = True) -> None:
        """Fault-injection gate ahead of every data-plane op.

        No-op without an installed injector.  A faulted attempt models an
        initiator-side completion timeout: the op held a lane at both
        endpoints for ``NetModel.op_timeout_s`` moving ZERO payload bytes
        (metered ``{name}.timeouts``), then — for per-pair fabrics (RC) —
        the QP transitioned to the error state, so the connection is torn
        down and the retry re-pays establishment through the pool, charged
        on the link clock by ``_setup`` like any cold pair.  Between
        attempts the initiator backs off linearly
        (``attempt * retry_backoff_s``, metered ``backoff_wait_s``); after
        ``max_retries`` re-posts the backend gives up with a typed
        :class:`RetriesExhausted`.  Async callers meter identically but
        never block the sim clock (their issue loop absorbs the failure)."""
        inj = self.net.faults
        if inj is None:
            return
        net = self.net
        meter = net.meter
        san = net.sanitizer
        # SimSan: faulted attempts hold lanes but move ZERO payload bytes
        bytes_before = meter.get(f"{self.name}.bytes", 0) \
            if san is not None else 0
        attempt = 0
        while inj.op_fault(self.name, op, src, dst):
            attempt += 1
            meter["timeouts"] += 1
            meter[f"{self.name}.timeouts"] += 1
            if sync:
                timeout = self.op_timeout()
                start = max(net.sim_time, net.link_free(src),
                            net.link_free(dst))
                end = start + timeout
                if san is not None:
                    opdesc = f"{self.name} {op} timeout {src}->{dst}"
                    san.link_hold(src, start, end, opdesc)
                    if dst != src:
                        san.link_hold(dst, start, end, opdesc)
                net.occupy_link(src, end)
                if dst != src:
                    net.occupy_link(dst, end)
                net.sim_time = end
            if self.conn_kind == "peer":
                net.conns.fault_pair(self.name, src, dst)
            if san is not None:
                san.retry_conserved(
                    self.name, bytes_before,
                    f"{self.name} {op} retry {src}->{dst}")
            if attempt > self.max_retries:
                raise RetriesExhausted(
                    f"{self.name} {op} {src}->{dst}: "
                    f"{attempt} attempt(s) timed out")
            meter["retries"] += 1
            meter[f"{self.name}.retries"] += 1
            backoff = self.model.retry_backoff_s * attempt
            if sync and backoff > 0:
                meter["backoff_wait_s"] += backoff
                net.sim_time += backoff

    # -- data plane ---------------------------------------------------------

    def read_pages(self, src: str, dst: str, dtype, frames, dc_key: int,
                   async_read: bool = False, user: Optional[str] = None):
        """Read ``frames`` out of dst's pool.  Admitted iff (dst, dc_key) is
        a live DC target — revoking the target kills access on EVERY backend.

        The gather is doorbell-batched: each maximal contiguous frame run is
        one scatter-gather entry, and one posted op carries up to ``max_sge``
        of them — so a contiguous 64-page fault is ONE op while 64 scattered
        pages cost ``ceil(64/max_sge)`` ops plus 64 SGEs.  ``async_read=True``
        occupies the (src, dst) channel without blocking the sim clock; the
        caller learns the completion time from ``net.channel_busy(src, dst)``
        and waits only when it actually needs the pages (overlap, rFaaS-style).
        """
        node = self.net.require_node(dst)
        self.net.check_target(dst, dc_key)
        # the fault gate: times out / retries / raises typed BEFORE any
        # payload byte is charged, so a failed read moves nothing (and an
        # RC timeout tears the pair down so _setup below re-pays it)
        self._admit("read", src, dst, sync=not async_read)
        # an async read must not stall the child's clock on a cold
        # connection: the setup cost is folded into the transfer's channel
        # time instead of charged to sim_time (the sync path pays it up
        # front, exactly as before)
        setup = self._setup(src, dst, defer=async_read, user=user)
        # the wire payload is HOST memory (the RNIC DMAs physical frames);
        # device materialization happens at tensor assembly, not per fault
        pages = node.pool.read_pages_host(dtype, frames)
        nbytes = pages.size * pages.dtype.itemsize
        sges = contiguous_runs(frames)
        ops = max(1, math.ceil(sges / self.max_sge))
        seconds = ops * self.op_latency() + nbytes / self.bandwidth()
        seconds *= self._penalty(src, dst)
        self.net.meter["page_pages_moved"] += int(np.asarray(frames).size)
        self._charge("read", src, dst, nbytes, seconds,
                     ops=ops, sges=sges, async_read=async_read, setup=setup)
        san = self.net.sanitizer
        if san is not None:
            # the wire payload must reach PagePool.write_pages whole —
            # the adopter (ModelInstance._adopt_pages) closes this tag
            san.tag_payload(pages, self.name, rows=int(pages.shape[0]),
                            nbytes=nbytes)
        return pages

    def read_blob(self, src: str, dst: str, nbytes: int, dc_key: int,
                  user: Optional[str] = None) -> None:
        """Metered fetch of an opaque blob (descriptor transfer).  Guarded by
        the blob's own DC key, exactly like a VMA."""
        self.net.require_node(dst)
        self.net.check_target(dst, dc_key)
        self._admit("read", src, dst)
        self._setup(src, dst, user=user)
        self._charge("read", src, dst, nbytes,
                     (self.op_latency() + nbytes / self.bandwidth())
                     * self._penalty(src, dst))

    def rpc(self, src: str, dst: str, nbytes: int, fn, *args, **kwargs):
        """Two-sided call executed by the destination node (FaSST-style).
        Connection-oriented backends acquire the (src, dst) connection
        from the pool here too — a two-sided call over RC still rides a
        QP, so the control plane can no longer get free connections the
        data plane would have had to pay for."""
        self.net.require_node(dst)
        self._admit("rpc", src, dst)
        self._setup(src, dst)
        self._charge("rpc", src, dst, nbytes,
                     (self.rpc_latency() + nbytes / self.bandwidth())
                     * self._penalty(src, dst))
        return fn(*args, **kwargs)

    # -- metering -----------------------------------------------------------

    def _setup(self, src: str, dst: str, defer: bool = False,
               user: Optional[str] = None) -> float:
        """Acquire the (src, dst) connection from the pool, paying the
        establishment cost if it is still owed.

        The pool (``net.conns``) decides whether a handshake is needed:
        a warm slot (RC reuse, DCT amortization, sibling sharing) costs
        nothing; a cold or evicted path owes the backend's setup cost and
        the pair is re-admitted (possibly evicting an LRU slot under
        ``NetModel.conn_cap``).

        A synchronous caller is clocked here (``defer=False``, returns
        0.0): establishment is a control-plane exchange on the wire, so
        it occupies a link lane at both endpoints — a setup storm queues
        on the NIC like payload traffic — and any stall behind busy lanes
        is metered as ``channel_wait_s``.  An async caller gets the owed
        seconds back instead (``defer=True``) and folds them into the
        transfer's channel time — a cold connection must not stall the
        clock the async path exists to keep moving.  Metering is
        identical either way."""
        if not self.connection_oriented:
            return 0.0
        net = self.net
        owed = net.conns.acquire(self, src, dst, user=user)
        if owed is None:
            return 0.0
        meter = net.meter
        meter["conn_setups"] += 1
        meter[f"{self.name}.setups"] += 1
        meter[f"{self.name}.setup_s"] += owed
        if defer:
            return owed
        start = max(net.sim_time, net.link_free(src), net.link_free(dst))
        end = start + owed
        san = net.sanitizer
        if san is not None:
            opdesc = f"{self.name} setup {src}->{dst}"
            san.link_hold(src, start, end, opdesc)
            if dst != src:
                san.link_hold(dst, start, end, opdesc)
        net.occupy_link(src, end)
        if dst != src:
            net.occupy_link(dst, end)
        net.note_conn_busy(src, end)
        net.note_conn_busy(dst, end)
        if start > net.sim_time:
            meter["channel_wait_s"] += start - net.sim_time
        net.sim_time = end
        return 0.0

    def _charge(self, kind: str, src: str, dst: str, nbytes: int,
                seconds: float, ops: int = 1, sges: Optional[int] = None,
                async_read: bool = False, setup: float = 0.0) -> float:
        """Meter one transfer and account its time on the (src, dst) channel
        and both endpoints' links.

        The transfer starts when the caller (sim clock), the channel AND a
        link lane at each endpoint are all free — per-node link capacity
        (``NetModel.node_links``) is a clocked resource, so a K-way fan-in
        visibly queues on the parent NIC instead of overlapping for free.
        A synchronous charge blocks the sim clock to the completion and
        meters any stall behind a busy channel/link as ``channel_wait_s``;
        an async charge leaves the clock alone.  ``setup`` is deferred
        connection-setup time (async cold connections) served ahead of the
        payload on the same channel.  Returns the completion time."""
        net = self.net
        meter = net.meter
        meter[f"{self.name}.bytes"] += nbytes
        meter[f"{self.name}.ops"] += ops
        if sges is not None:        # page reads only — blob/rpc have no SGEs
            meter[f"{self.name}.sges"] += sges
        category = "rpc" if kind == "rpc" else self.legacy_meter
        meter[f"{category}_bytes"] += nbytes
        meter[f"{category}_ops"] += ops
        start = max(net.sim_time, net.channel_busy(src, dst),
                    net.link_free(src), net.link_free(dst))
        end = start + setup + seconds
        san = net.sanitizer
        if san is not None:
            opdesc = f"{self.name} {kind} {src}->{dst}"
            san.channel_hold(src, dst, start, end, opdesc)
            san.link_hold(src, start, end, opdesc)
            if dst != src:
                san.link_hold(dst, start, end, opdesc)
            san.charged(self.name, nbytes, opdesc)
        if setup > 0:
            # deferred establishment rides the channel ahead of the
            # payload: stamp it on both endpoints' conn-backlog clocks so
            # setup-aware schedulers see the in-flight handshake
            net.note_conn_busy(src, start + setup)
            net.note_conn_busy(dst, start + setup)
        net.set_channel_busy(src, dst, end)
        net.occupy_link(src, end)
        if dst != src:
            net.occupy_link(dst, end)
        net.account_node_busy(src, dst, seconds)
        if async_read:
            meter[f"{self.name}.async_ops"] += ops
        else:
            if start > net.sim_time:
                # the caller's stall behind a busy channel or link — fan-in
                # queueing at a hot parent surfaces here, not just in
                # async_wait_s (which only meters explicit wait_until)
                meter["channel_wait_s"] += start - net.sim_time
            net.sim_time = end
        return end
