"""repro.net — the pluggable data plane.

One :class:`Transport` interface (``read_pages`` / ``read_blob`` / ``rpc``,
capability flags, per-backend metering) behind a name-keyed registry, with
:class:`Network` as a thin router (membership + DC-target access control +
meter aggregation).  Built-in backends: ``dct``, ``rc``, ``rpc``,
``tpu_ici``, ``shared_fs`` — see ``docs/transport.md``.
"""
from repro.net.conn import (ConnManager, ConnPool, Connection, DCTInitiator,
                            DCTTarget, RCConnection)
from repro.net.errors import (AccessRevoked, AuthError, HandleUnbound,
                              LeaseExpired, NoNodesAvailable, NodeDown,
                              ReadTimeout, RecoveryFailed, ReproError,
                              RetriesExhausted, SeedGone, SeedUnavailable,
                              TransportError)
from repro.net.model import NetModel
from repro.net.network import Network
from repro.net.transport import (Transport, contiguous_runs,
                                 register_transport, resolve_transport,
                                 transport_names)
from repro.net.backends import (DctTransport, RcTransport, RpcTransport,
                                SharedFsTransport, TpuIciTransport)

__all__ = [
    "AccessRevoked",
    "AuthError",
    "HandleUnbound",
    "NoNodesAvailable",
    "NodeDown",
    "ReadTimeout",
    "RecoveryFailed",
    "ReproError",
    "RetriesExhausted",
    "SeedGone",
    "SeedUnavailable",
    "TransportError",
    "ConnManager",
    "ConnPool",
    "Connection",
    "DCTInitiator",
    "DCTTarget",
    "LeaseExpired",
    "RCConnection",
    "NetModel",
    "Network",
    "Transport",
    "contiguous_runs",
    "register_transport",
    "resolve_transport",
    "transport_names",
    "DctTransport",
    "RcTransport",
    "RpcTransport",
    "TpuIciTransport",
    "SharedFsTransport",
]
