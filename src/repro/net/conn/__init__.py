"""repro.net.conn — the clocked connection control plane.

Swift (arxiv 2501.19051) argues the RDMA *control plane* — connection
establishment — is the real bottleneck of elastic computing: a 10k-child
fan-out over RC pays a QP connect per (child, parent) pair, while DCT
amortizes one initiator context across every peer.  This package makes
that cost structural instead of a scalar: typed connection objects
(:class:`RCConnection` vs :class:`DCTInitiator`/:class:`DCTTarget`) live
in bounded per-node :class:`ConnPool` tables with LRU eviction, sibling
children *share* a warm connection through per-user refcounts, and every
establishment is charged on the link clock — a setup storm queues on the
NIC like any other traffic.  See ``docs/connection.md``.
"""
from repro.net.conn.types import (Connection, DCTInitiator, DCTTarget,
                                  RCConnection)
from repro.net.conn.pool import ConnManager, ConnPool

__all__ = [
    "Connection",
    "RCConnection",
    "DCTInitiator",
    "DCTTarget",
    "ConnPool",
    "ConnManager",
]
