"""Bounded per-node connection pools and the network-wide manager.

A :class:`ConnPool` is one node's connection table (QP / DC contexts):
an LRU-ordered, capacity-bounded set of :class:`~repro.net.conn.types.
Connection` slots.  ``NetModel.conn_cap`` bounds every pool (0 =
unbounded, the legacy behavior); overflowing a pool evicts the
least-recently-used *unreferenced* connection first and only tears a
connection out from under live users as a last resort.

The :class:`ConnManager` owns all pools for one :class:`~repro.net.
network.Network` and is the single place connection state changes:

* ``acquire`` — ensure a live (src, dst) path over a backend, returning
  the owed establishment seconds (``None`` when the path is warm).  RC
  acquires a per-peer QP in both pools; DCT acquires/reuses one
  initiator at src and one target at dst and pays only the per-new-pair
  piggyback handshake.
* eviction — cascades structurally: evicting a DCT target invalidates
  every initiator's handshake to it (they re-pay the piggyback on next
  use), evicting an RC QP frees the slot at both endpoints.
* churn meters — ``{backend}.conn_evicted`` counts slots torn down and
  ``{backend}.conn_reestablished`` counts pairs that pay setup *again*
  after having been warm before: the Swift-style setup-storm signal the
  fig18 churn rows pin.
"""
from __future__ import annotations

import contextlib
from collections import OrderedDict
from typing import Dict, Optional, Set

from repro.net.conn.types import (Connection, DCTInitiator, DCTTarget,
                                  RCConnection)


class ConnPool:
    """One node's LRU-ordered, capacity-bounded connection table."""

    def __init__(self, node_id: str, manager: "ConnManager"):
        self.node_id = node_id
        self.manager = manager
        self._order: "OrderedDict[tuple, Connection]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._order)

    def __contains__(self, key: tuple) -> bool:
        return key in self._order

    def connections(self):
        """Connections in LRU -> MRU order (a snapshot list)."""
        return list(self._order.values())

    def touch(self, key: tuple) -> None:
        if key in self._order:
            self._order.move_to_end(key)

    def insert(self, conn: Connection) -> None:
        self._order[conn.key] = conn
        self._order.move_to_end(conn.key)

    def remove(self, key: tuple) -> None:
        self._order.pop(key, None)

    def enforce_cap(self, protect: tuple) -> int:
        """Evict until the pool fits ``NetModel.conn_cap``; never evicts
        ``protect`` (the entry being acquired).  Unreferenced connections
        go first in LRU order; if every other slot is held by a live
        user, the LRU one is torn down anyway (forced churn under
        pressure — the QP table is a hard hardware bound).  Returns the
        number of evictions."""
        cap = self.manager.cap
        if cap <= 0:
            return 0
        evicted = 0
        while len(self._order) > cap:
            victim = None
            for key, conn in self._order.items():
                if key != protect and not conn.users:
                    victim = conn
                    break
            if victim is None:
                for key, conn in self._order.items():
                    if key != protect:
                        victim = conn
                        break
            if victim is None:      # only the protected entry remains
                break
            self.manager.evict(victim)
            evicted += 1
        return evicted


class ConnManager:
    """All connection state for one Network: pools, live entries, churn."""

    def __init__(self, net):
        self.net = net
        self.pools: Dict[str, ConnPool] = {}
        self.conns: Dict[tuple, Connection] = {}
        # (backend, src, dst) pairs that have EVER paid setup: a pair
        # paying again after eviction is re-establishment churn
        self._seen_pairs: Set[tuple] = set()
        # user -> connection keys it holds a reference on
        self._user_index: Dict[str, Set[tuple]] = {}

    @property
    def cap(self) -> int:
        return getattr(self.net.model, "conn_cap", 0)

    def pool(self, node_id: str) -> ConnPool:
        p = self.pools.get(node_id)
        if p is None:
            p = self.pools[node_id] = ConnPool(node_id, self)
        return p

    # -- acquisition ---------------------------------------------------------

    def acquire(self, transport, src: str, dst: str,
                user: Optional[str] = None) -> Optional[float]:
        """Ensure a live (src, dst) path over ``transport``.  Returns the
        owed establishment seconds when a handshake is needed, or None
        when the path is already warm (slot reuse / DCT amortization).
        The caller decides how the owed time lands on the clock (sync
        stall vs folded into async channel time)."""
        kind = transport.conn_kind
        name = transport.name
        if kind == "peer":
            key = (name, "peer", src, dst)
            conn = self.conns.get(key)
            fresh = conn is None
            if fresh:
                conn = RCConnection(name, src, dst)
                self._admit(conn)
            self._touch(conn, user)
            if not fresh:
                return None
            return self._established(name, src, dst, transport)
        if kind != "dc":
            raise ValueError(
                f"transport {name!r} has unsupported conn_kind {kind!r}")
        dci = self.conns.get((name, "dci", src))
        if dci is None:
            dci = DCTInitiator(name, src)
            self._admit(dci)
        tgt = self.conns.get((name, "tgt", dst))
        if tgt is None:
            tgt = DCTTarget(name, dst)
            self._admit(tgt)
        self._touch(dci, user)
        self._touch(tgt, user)
        if dst in dci.peers and src in tgt.initiators:
            return None             # handshake already piggybacked
        dci.peers.add(dst)
        tgt.initiators.add(src)
        return self._established(name, src, dst, transport)

    def _established(self, name: str, src: str, dst: str,
                     transport) -> float:
        pair = (name, src, dst)
        if pair in self._seen_pairs:
            self.net.meter[f"{name}.conn_reestablished"] += 1
        else:
            self._seen_pairs.add(pair)
        return transport.setup_cost()

    def _admit(self, conn: Connection) -> None:
        self.conns[conn.key] = conn
        # slot the connection everywhere BEFORE enforcing caps: eviction
        # scans the whole control plane, so it must never observe a conn
        # half-inserted (cap victims only depend on each pool's own LRU
        # order, so splitting the loop changes nothing behaviorally)
        for nid in conn.nodes:
            self.pool(nid).insert(conn)
        for nid in conn.nodes:
            self.pools[nid].enforce_cap(protect=conn.key)
        san = self.net.sanitizer
        if san is not None:
            san.check_conns(self, f"admit {conn.key}")

    def _touch(self, conn: Connection, user: Optional[str]) -> None:
        san = self.net.sanitizer
        if san is not None:
            san.touch_live(conn, self, f"touch {conn.key}")
        for nid in conn.nodes:
            pool = self.pools.get(nid)
            if pool is not None:
                pool.touch(conn.key)
        if user is not None:
            conn.users.add(user)
            self._user_index.setdefault(user, set()).add(conn.key)

    # -- teardown ------------------------------------------------------------

    def evict(self, conn: Connection, meter: bool = True) -> None:
        """Tear ``conn`` down everywhere: drop its pool slots, release its
        users' references, and structurally invalidate DCT handshakes
        that rode the evicted context."""
        self.conns.pop(conn.key, None)
        for nid in conn.nodes:
            pool = self.pools.get(nid)
            if pool is not None:
                pool.remove(conn.key)
        # sim-ok: set-iter -- pure per-user discards; order cannot matter
        for u in conn.users:
            keys = self._user_index.get(u)
            if keys is not None:
                keys.discard(conn.key)
        conn.users.clear()
        if isinstance(conn, DCTInitiator):
            # sim-ok: set-iter -- independent handshake invalidations
            for d in conn.peers:
                tgt = self.conns.get((conn.backend, "tgt", d))
                if tgt is not None:
                    tgt.initiators.discard(conn.src)
            conn.peers.clear()
        elif isinstance(conn, DCTTarget):
            # sim-ok: set-iter -- independent handshake invalidations
            for s in conn.initiators:
                dci = self.conns.get((conn.backend, "dci", s))
                if dci is not None:
                    dci.peers.discard(conn.dst)
            conn.initiators.clear()
        if meter:
            self.net.meter[f"{conn.backend}.conn_evicted"] += 1
        san = self.net.sanitizer
        if san is not None:
            san.check_conns(self, f"evict {conn.key}")

    def release_user(self, user: str) -> None:
        """Drop every reference ``user`` holds (instance free): the
        connections stay warm in their pools but become first in line
        for eviction under cap pressure."""
        for key in self._user_index.pop(user, ()):
            conn = self.conns.get(key)
            if conn is not None:
                conn.users.discard(user)
        san = self.net.sanitizer
        if san is not None:
            san.check_conns(self, f"release_user {user}")

    def fault_pair(self, name: str, src: str, dst: str) -> None:
        """An op on the (src, dst) QP over backend ``name`` timed out: RC
        semantics move the QP to the error state, so the connection is
        torn down at both endpoints (``{name}.conn_faulted``) and the
        retry re-pays establishment through ``acquire`` — metered as
        re-establishment churn because the pair was seen before."""
        conn = self.conns.get((name, "peer", src, dst))
        if conn is not None:
            self.evict(conn)
            self.net.meter[f"{name}.conn_faulted"] += 1

    def drop_node(self, node_id: str) -> None:
        """A node left the network (crash/unregister): every connection
        with a slot in its pool dies — peers will re-pay setup if the
        node comes back."""
        pool = self.pools.pop(node_id, None)
        if pool is None:
            return
        san = self.net.sanitizer
        # the cascade is inconsistent by construction (the pool is gone
        # while its conns still exist), so scan once at the end instead
        # of after each evict
        with (san.bulk() if san is not None else contextlib.nullcontext()):
            for conn in pool.connections():
                self.evict(conn)
        if san is not None:
            san.check_conns(self, f"drop_node {node_id}")

    def reset(self) -> None:
        """Forget ALL connection state (tests/diagnostics): pairs re-pay
        setup as if never connected, with no churn metered."""
        self.pools.clear()
        self.conns.clear()
        self._seen_pairs.clear()
        self._user_index.clear()

    # -- observed state (what schedulers/telemetry read) ---------------------

    def has(self, name: str, src: str, dst: str) -> bool:
        """True iff the (src, dst) path over backend ``name`` is warm in
        the pools right now (observed state, not history)."""
        from repro.net.transport import resolve_transport
        kind = resolve_transport(name).conn_kind
        if kind == "peer":
            return (name, "peer", src, dst) in self.conns
        if kind == "dc":
            dci = self.conns.get((name, "dci", src))
            tgt = self.conns.get((name, "tgt", dst))
            return (dci is not None and dst in dci.peers
                    and tgt is not None and src in tgt.initiators)
        return False

    def setup_owed(self, name: str, src: str, dst: str) -> float:
        """Seconds the NEXT (src, dst) op over ``name`` will owe for
        establishment, from observed pool state: 0 for connectionless
        fabrics and warm paths, the backend's setup cost otherwise."""
        from repro.net.transport import resolve_transport
        if not resolve_transport(name).connection_oriented:
            return 0.0
        if self.has(name, src, dst):
            return 0.0
        return self.net.transport_obj(name).setup_cost()

    def live(self, name: str) -> int:
        """Live pool entries (slots, not pairs) for backend ``name``."""
        return sum(1 for c in self.conns.values() if c.backend == name)
