"""Typed connection objects — RC vs DCT modeled structurally.

The paper's DCT-vs-RC ablation (§5.3) is usually summarized as two setup
*constants* (4 ms QP connect vs <1 us piggyback).  The structural
difference matters just as much under bounded pools:

* an RC connection is a per-(src, dst) queue pair — it occupies one slot
  in **both** endpoints' connection tables, so a K-way fan-out costs the
  parent K slots;
* a DCT initiator is one DC context at the source that can reach *any*
  target, and a DCT target is one context at the destination serving
  *any* initiator — a node fanning out to (or in from) K peers holds one
  slot, not K.  Each new (src, dst) pair still pays the piggybacked
  handshake once, but the slot footprint is O(1) per node.

Every connection tracks its ``users`` (instance-scoped refcounts): a
connection still referenced by a live child is only evicted as a last
resort, so siblings landed on one node keep sharing a warm path.
"""
from __future__ import annotations

from typing import List, Set, Tuple


class Connection:
    """One live connection-table entry at one or two nodes' pools."""

    kind = "conn"

    __slots__ = ("backend", "key", "nodes", "users")

    def __init__(self, backend: str, key: tuple, nodes: Tuple[str, ...]):
        self.backend = backend
        self.key = key
        self.nodes = nodes          # node ids whose pool holds a slot
        self.users: Set[str] = set()  # instance-scoped refcounts (sharing)

    def pairs(self) -> List[Tuple[str, str]]:
        """(src, dst) pairs this entry keeps warm."""
        raise NotImplementedError

    def __repr__(self):
        return (f"<{type(self).__name__} {self.key} "
                f"users={len(self.users)}>")


class RCConnection(Connection):
    """A reliable-connected queue pair: exactly one (src, dst) peer,
    occupying a slot at BOTH endpoints."""

    kind = "peer"

    __slots__ = ("src", "dst")

    def __init__(self, backend: str, src: str, dst: str):
        nodes = (src, dst) if src != dst else (src,)
        super().__init__(backend, (backend, "peer", src, dst), nodes)
        self.src = src
        self.dst = dst

    def pairs(self):
        return [(self.src, self.dst)]


class DCTInitiator(Connection):
    """One DC initiator context at ``src``: a single slot that reaches
    every target it has handshaken with (``peers``)."""

    kind = "dci"

    __slots__ = ("src", "peers")

    def __init__(self, backend: str, src: str):
        super().__init__(backend, (backend, "dci", src), (src,))
        self.src = src
        self.peers: Set[str] = set()    # dst nodes with a live handshake

    def pairs(self):
        return [(self.src, d) for d in sorted(self.peers)]


class DCTTarget(Connection):
    """One DC target context at ``dst``: a single slot serving every
    initiator (``initiators`` is the reverse index used to invalidate
    peers' handshakes when this slot is evicted)."""

    kind = "tgt"

    __slots__ = ("dst", "initiators")

    def __init__(self, backend: str, dst: str):
        super().__init__(backend, (backend, "tgt", dst), (dst,))
        self.dst = dst
        self.initiators: Set[str] = set()

    def pairs(self):
        return [(s, self.dst) for s in sorted(self.initiators)]
