"""Concrete transport backends (§5.3 ablation axes + two new fabrics).

============  =========  ===========  ============================  ==========
name          one-sided  setup        read cost                     rpc cost
============  =========  ===========  ============================  ==========
``dct``       yes        dct_setup    rdma_lat + B/rdma_bw          rpc_lat
``rc``        yes        rc_setup     rdma_lat + B/rdma_bw          rpc_lat
``rpc``       no         —            rpc_lat  + B/rdma_bw          rpc_lat
``tpu_ici``   yes        —            ici_lat  + B/ici_bw           rpc_lat
``shared_fs`` no         —            dfs_lat  + B/disk_bw          dfs_lat
============  =========  ===========  ============================  ==========

``dct`` vs ``rc`` is the paper's DCT-vs-RC ablation: identical wire costs,
but RC pays a 4 ms QP connect per (src, dst) pair while DCT's setup is
piggybacked (<1 us).  ``rpc`` is the two-sided ablation path — the owner's
CPU serves every read.  ``tpu_ici`` models descriptor/page movement over a
TPU ICI link (static mesh: no connection setup, DMA-style one-sided).
``shared_fs`` is the CRIU-over-distributed-FS baseline: every read is a DFS
request plus checkpoint-disk bandwidth, two-sided and slow — the thing the
paper beats.
"""
from __future__ import annotations

from repro.net.transport import Transport, register_transport


@register_transport
class DctTransport(Transport):
    """Connectionless RDMA (DC): one-sided reads, setup piggybacked."""

    name = "dct"
    one_sided = True
    connection_oriented = True
    conn_kind = "dc"               # one initiator/target context per node
    legacy_meter = "rdma"
    max_sge = 16                   # SGEs per doorbell-batched work request
    max_retries = 3                # DC re-posts are cheap: no QP to rebuild

    def setup_cost(self) -> float:
        return self.model.dct_setup

    def op_latency(self) -> float:
        return self.model.rdma_lat

    def bandwidth(self) -> float:
        return self.model.rdma_bw


@register_transport
class RcTransport(Transport):
    """Reliable-connected RDMA: one-sided reads behind a per-pair QP connect."""

    name = "rc"
    one_sided = True
    connection_oriented = True
    conn_kind = "peer"             # one QP per (src, dst), slots both ends
    legacy_meter = "rdma"
    max_sge = 16
    max_retries = 2                # each retry re-pays the 4 ms QP connect
                                   # (timeout moves the QP to error state)

    def setup_cost(self) -> float:
        return self.model.rc_setup

    def op_latency(self) -> float:
        return self.model.rdma_lat

    def bandwidth(self) -> float:
        return self.model.rdma_bw


@register_transport
class RpcTransport(Transport):
    """Two-sided ablation path: the owner's CPU serves every read.  Reads are
    still DC-key checked — the serving daemon refuses reclaimed VMAs — so
    revocation behaves identically to the one-sided backends."""

    name = "rpc"
    one_sided = False
    legacy_meter = "rpc"
    max_sge = 8                    # the daemon batches extents per request
    max_retries = 0                # the fallback path does not retry: a
                                   # timed-out daemon call fails over at
                                   # once (the caller picks another serve)

    def op_latency(self) -> float:
        return self.model.rpc_lat

    def bandwidth(self) -> float:
        return self.model.rdma_bw


@register_transport
class TpuIciTransport(Transport):
    """TPU ICI link: DMA-style one-sided movement over the static mesh —
    no connection setup, ici_bw per link."""

    name = "tpu_ici"
    one_sided = True
    legacy_meter = "ici"
    max_sge = 32                   # DMA descriptor ring, deep batching
    max_retries = 2

    def op_latency(self) -> float:
        return self.model.ici_lat

    def bandwidth(self) -> float:
        return self.model.ici_bw


@register_transport
class SharedFsTransport(Transport):
    """CRIU-over-distributed-FS baseline: reads and round trips both pay the
    DFS request latency and checkpoint-disk bandwidth."""

    name = "shared_fs"
    one_sided = False
    legacy_meter = "dfs"
    max_sge = 1                    # every extent is a separate DFS request
    max_retries = 1                # one slow re-read of the checkpoint file

    def op_latency(self) -> float:
        return self.model.dfs_lat

    def bandwidth(self) -> float:
        return self.model.disk_bw

    def rpc_latency(self) -> float:
        return self.model.dfs_lat
