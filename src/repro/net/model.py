"""NetModel — latency/bandwidth constants for the calibrated cost model.

Every transport backend derives its per-op and per-byte costs from these
constants (defaults ~ConnectX-4 100Gb/s, paper §7); benchmarks report the
derived ("sim") column next to measured wall time because this container's
single CPU core is not representative of RNIC/ICI-attached hosts.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class NetModel:
    rdma_lat: float = 2e-6          # one-sided READ latency
    rdma_bw: float = 12.5e9         # 100 Gb/s
    rpc_lat: float = 8e-6           # two-sided RPC round trip
    rc_setup: float = 4e-3          # RC QP connect (paper: 4 ms)
    dct_setup: float = 1e-6         # DCT: piggybacked, <1 us
    dfs_lat: float = 100e-6         # distributed-FS request (CRIU-remote)
    disk_bw: float = 2e9            # checkpoint "disk" (tmpfs-ish)
    ici_lat: float = 1e-6           # TPU ICI hop (static mesh, no QP setup)
    ici_bw: float = 50e9            # TPU ICI per link (for TPU-mode derivations)
    node_links: int = 1             # wire transfers one node's NIC carries at
                                    # full bandwidth; every transfer occupies
                                    # one lane at EACH endpoint, so a K-way
                                    # fan-in queues on the parent link in
                                    # sim_time itself (<= 0 disables the link
                                    # clock: ledger-only legacy accounting)
    conn_cap: int = 0               # per-node connection-table slots (QP/DC
                                    # contexts a NIC holds); overflow evicts
                                    # LRU and the pair re-pays setup on next
                                    # use (<= 0 = unbounded, legacy behavior)
    op_timeout_s: float = 1e-3      # how long one op attempt holds its lane
                                    # before the initiator declares it lost
                                    # (injected fault / flapped peer NIC)
    retry_backoff_s: float = 5e-4   # linear backoff unit between attempts:
                                    # attempt k waits k * retry_backoff_s
