"""Network — a thin router over the transport registry.

The network now owns exactly three things:

* **membership** — which nodes are up (``register`` / ``unregister``);
* **DC-target access control** — the (node, key) registry every read is
  admitted against, one key per VMA *and per descriptor blob*;
* **meter aggregation** — one Counter + sim clock that all transports
  charge into, with per-backend ``{name}.bytes`` / ``{name}.ops`` keys next
  to the legacy category aggregates.

All data movement dispatches through a named :class:`~repro.net.transport.
Transport` from the registry: ``read_pages`` (paging fast path),
``read_blob`` (descriptor fetch) and ``rpc`` (two-sided control plane /
fallback daemon).  ``transport=None`` means the network's default backend.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, Optional

from repro.analysis import simsan
from repro.net import backends as _backends   # noqa: F401  (registers built-ins)
from repro.net.conn import ConnManager
from repro.net.errors import AccessRevoked, NodeDown
from repro.net.model import NetModel
from repro.net.transport import Transport, resolve_transport, transport_names


class Network:
    def __init__(self, model: Optional[NetModel] = None, transport: str = "dct",
                 sanitize: Optional[bool] = None):
        resolve_transport(transport)        # unknown name -> ValueError
        self.model = model or NetModel()
        self.transport = transport          # default backend name
        self.nodes: Dict[str, "object"] = {}
        self.meter = Counter()
        self.sim_time = 0.0
        self._transports: Dict[str, Transport] = {}
        # the connection control plane: bounded per-node pools of typed
        # connection objects (RC per-peer QPs vs DCT contexts), LRU
        # eviction under NetModel.conn_cap, sibling sharing via per-user
        # refcounts — see repro.net.conn / docs/connection.md
        self.conns = ConnManager(self)
        # per-node establishment busy-until stamps: how far ahead of the
        # clock each node's control plane is committed (conn_backlog)
        self._conn_busy: Dict[str, float] = {}
        # per-(src, dst) channel busy-until timestamps: overlapped (async)
        # transfers serialize against each other on their channel, not
        # against the sim clock
        self._channel_busy: Dict[tuple, float] = {}
        # per-node link lanes (model.node_links busy-until stamps): a
        # transfer holds one lane at EACH endpoint, so K children gathering
        # from one parent queue on the parent NIC in sim_time itself — the
        # contention the node_busy ledger only recorded passively
        self._link_busy: Dict[str, list] = {}
        # per-node cumulative link occupancy (seconds of wire time on either
        # end of a transfer): the parent-NIC contention ledger that fan-out
        # benchmarks and the transport-aware scheduler read
        self._node_busy: Counter = Counter()
        # DC targets: (node_id, dc_key) -> True while valid
        self._dc_targets: Dict[tuple, bool] = {}
        self._next_key = 1
        # fault plane: a repro.sim.faults.FaultInjector when a replay (or
        # test) installs one; None on the fault-free path, in which case
        # transports skip every fault check and charge identically to a
        # pre-fault-plane build (digest-stable by construction)
        self.faults = None
        # SimSan: the opt-in runtime invariant sanitizer (lane/channel
        # monotonicity, meter conservation, conn-pool consistency, lease
        # edges).  None by default — every hook in the data plane sits
        # behind a None guard, mirroring the fault plane's pattern — and a
        # sanitized run of a correct build is digest-identical because the
        # sanitizer only reads.  ``sanitize=None`` defers to REPRO_SIMSAN.
        if sanitize is None:
            sanitize = simsan.enabled()
        self.sanitizer = simsan.Sanitizer(self) if sanitize else None

    # -- transport registry ----------------------------------------------------

    def transport_obj(self, name: Optional[str] = None) -> Transport:
        """The (lazily instantiated) backend for ``name`` (None = default)."""
        name = name or self.transport
        t = self._transports.get(name)
        if t is None:
            t = resolve_transport(name)(self)
            self._transports[name] = t
        return t

    # -- membership -----------------------------------------------------------

    def register(self, node) -> None:
        self.nodes[node.node_id] = node
        if self.sanitizer is not None:
            # a (re-)registered node is a fresh incarnation for the
            # exactly-once parent_lost accounting
            self.sanitizer.node_registered(node.node_id)

    def unregister(self, node_id: str) -> None:
        self.nodes.pop(node_id, None)
        for k in [k for k in self._dc_targets if k[0] == node_id]:
            del self._dc_targets[k]
        # the node's connection table dies with it: every QP/DC context
        # holding a slot there is torn down and peers re-pay setup
        self.conns.drop_node(node_id)

    def require_node(self, node_id: str):
        node = self.nodes.get(node_id)
        if node is None:
            raise NodeDown(f"node {node_id} is down")
        return node

    def drop_cached_frames(self, owner: str, dtype: str, frames) -> None:
        """Broadcast sibling-cache invalidation: ``owner`` is freeing these
        frames, so every node must forget (owner, dtype, frame) entries —
        the reused frame indices would otherwise serve stale data.  Modeled
        as free kernel-level coherence traffic (unmetered)."""
        for node in self.nodes.values():
            drop = getattr(node, "page_cache_drop_owner_frames", None)
            if drop is not None:
                drop(owner, dtype, frames)

    # -- DC targets (access control) -------------------------------------------

    def create_dc_target(self, node_id: str) -> int:
        """Allocate a DC key guarding one VMA or blob (paper: 12 B child-side)."""
        key = self._next_key
        self._next_key += 1
        self._dc_targets[(node_id, key)] = True
        self.meter["dc_targets"] += 1
        return key

    def destroy_dc_target(self, node_id: str, key: int) -> None:
        self._dc_targets.pop((node_id, key), None)

    def target_valid(self, node_id: str, key: int) -> bool:
        return self._dc_targets.get((node_id, key), False)

    def check_target(self, node_id: str, key: int) -> None:
        if not self.target_valid(node_id, key):
            raise AccessRevoked(f"DC target {key}@{node_id} destroyed")

    # -- channel busy-time accounting (transfer/execution overlap) ---------------

    def channel_busy(self, src: str, dst: str) -> float:
        """Absolute sim time until which the (src, dst) channel is occupied.
        Right after an async read this is that transfer's completion time."""
        return self._channel_busy.get((src, dst), 0.0)

    def set_channel_busy(self, src: str, dst: str, until: float) -> None:
        self._channel_busy[(src, dst)] = until

    def channel_backlog(self, src: str, dst: str) -> float:
        """Seconds of queued transfer still ahead of ``sim_time`` on the
        (src, dst) channel — the load signal schedulers weigh."""
        return max(0.0, self.channel_busy(src, dst) - self.sim_time)

    # -- per-node link capacity (the contention *clock*) -------------------------

    def _lanes(self, node_id: str) -> list:
        lanes = self._link_busy.get(node_id)
        if lanes is None:
            lanes = self._link_busy[node_id] = [0.0] * self.model.node_links
        return lanes

    def link_free(self, node_id: str) -> float:
        """Absolute sim time at which ``node_id``'s NIC next has a free
        lane.  With the link clock disabled (``node_links <= 0``) this is
        always 0.0 — transfers serialize per channel only."""
        if self.model.node_links <= 0:
            return 0.0
        lanes = self._link_busy.get(node_id)
        return min(lanes) if lanes else 0.0

    def link_busy_until(self, node_id: str) -> float:
        """Absolute sim time at which ``node_id``'s NIC drains completely
        (its LAST busy lane) — the fan-in makespan stamp.  Equal to
        ``link_free`` at ``node_links=1``; with wider links the two
        diverge (next-free lane vs last-busy lane).  0.0 while the link
        clock is disabled."""
        if self.model.node_links <= 0:
            return 0.0
        lanes = self._link_busy.get(node_id)
        return max(lanes) if lanes else 0.0

    def link_backlog(self, node_id: str) -> float:
        """Seconds of queued wire time ahead of ``sim_time`` on ``node_id``'s
        link — the hot-spot signal the Router and schedulers act on."""
        return max(0.0, self.link_free(node_id) - self.sim_time)

    def backlog_snapshot(self) -> Dict[str, float]:
        """{node_id: seconds of queued wire time} for every node that has a
        lane ledger — the per-node hot-spot view replay timelines sample.
        Nodes that never moved a byte have no ledger and are omitted (the
        lane dicts are lazy precisely so fleet-scale clusters stay cheap)."""
        return {nid: self.link_backlog(nid) for nid in self._link_busy}

    def occupy_link(self, node_id: str, until: float) -> None:
        """Hold ``node_id``'s earliest-free lane until ``until`` (absolute).
        Transports call this for both endpoints of every transfer; a no-op
        while the link clock is disabled."""
        if self.model.node_links <= 0:
            return
        lanes = self._lanes(node_id)
        i = min(range(len(lanes)), key=lanes.__getitem__)
        if until > lanes[i]:
            lanes[i] = until

    def account_node_busy(self, src: str, dst: str, seconds: float) -> None:
        """Charge ``seconds`` of wire occupancy to both endpoints' links.
        Summed per node this is the NIC-time ledger: a parent serving a
        K-way fan-out accumulates the whole working set here while each
        child accumulates only its own share."""
        self._node_busy[src] += seconds
        self._node_busy[dst] += seconds

    def node_busy(self, node_id: str) -> float:
        """Cumulative link-busy seconds charged to ``node_id`` since the
        last ``reset_meter``."""
        return self._node_busy.get(node_id, 0.0)

    def advance(self, seconds: float) -> None:
        """Model ``seconds`` of child-side *execution* on the critical path.
        Channel busy-until stamps are absolute, so in-flight async transfers
        keep draining while the clock moves — this is where overlap pays."""
        if seconds > 0:
            self.sim_time += seconds

    def wait_until(self, t: float) -> None:
        """Block the sim clock until ``t`` (awaiting an async completion);
        time already covered by execution costs nothing extra."""
        if t > self.sim_time:
            self.meter["async_wait_s"] += t - self.sim_time
            self.sim_time = t

    # -- connections (the clocked control plane) --------------------------------

    def note_connection(self, transport: str, src: str, dst: str) -> bool:
        """Admit the (src, dst) pair into the pools warm, without charging
        the clock (an externally established connection); True if it was
        new.  Tests and warm-import paths use this to pre-pay setup."""
        return self.conns.acquire(self.transport_obj(transport),
                                  src, dst) is not None

    def has_connection(self, transport: str, src: str, dst: str) -> bool:
        """True iff the (src, dst) path over ``transport`` is warm in the
        pools *right now* — observed state, so an LRU-evicted pair reads
        False again (and ``setup_owed`` prices its re-establishment)."""
        return self.conns.has(transport, src, dst)

    def setup_owed(self, transport: str, src: str, dst: str) -> float:
        """Seconds the next (src, dst) op over ``transport`` will owe for
        connection establishment, from observed pool state — what the
        transport-aware scheduler and Router charge a candidate."""
        return self.conns.setup_owed(transport or self.transport, src, dst)

    def conn_release_user(self, user: str) -> None:
        """Release every connection reference ``user`` (an instance)
        holds: warm slots survive but become first in line for LRU
        eviction under ``NetModel.conn_cap``."""
        self.conns.release_user(user)

    def reset_connections(self) -> None:
        """Forget all connection state (tests/diagnostics): every pair
        re-pays setup as if never connected."""
        self.conns.reset()

    def note_conn_busy(self, node_id: str, until: float) -> None:
        """Stamp ``node_id``'s control plane busy until ``until`` —
        establishment work committed ahead of (or at) the clock."""
        if until > self._conn_busy.get(node_id, 0.0):
            self._conn_busy[node_id] = until

    def conn_backlog(self, node_id: str) -> float:
        """Seconds of connection-establishment work still ahead of
        ``sim_time`` at ``node_id`` — the setup-storm signal setup-aware
        placement scores alongside ``link_backlog``."""
        return max(0.0, self._conn_busy.get(node_id, 0.0) - self.sim_time)

    # -- data plane ---------------------------------------------------------------

    def read_pages(self, src: str, dst: str, dtype, frames, dc_key: int,
                   transport: Optional[str] = None, async_read: bool = False,
                   user: Optional[str] = None):
        """Read of `frames` from dst's pool over the named backend.
        ``async_read=True`` issues the read without blocking the sim clock
        (it occupies the channel; completion = ``channel_busy(src, dst)``).
        ``user`` (an instance identity) takes a refcount on the connection
        so siblings on one node share a warm slot until freed."""
        return self.transport_obj(transport).read_pages(
            src, dst, dtype, frames, dc_key, async_read=async_read,
            user=user)

    def read_blob(self, src: str, dst: str, nbytes: int, dc_key: int,
                  transport: Optional[str] = None,
                  user: Optional[str] = None) -> None:
        """Metered blob fetch (descriptor transfer), DC-key guarded."""
        return self.transport_obj(transport).read_blob(src, dst, nbytes,
                                                       dc_key, user=user)

    def rpc(self, src: str, dst: str, nbytes: int, fn, *args,
            transport: Optional[str] = None, **kwargs):
        """Two-sided RPC executed by the destination node (FaSST-style)."""
        return self.transport_obj(transport).rpc(src, dst, nbytes, fn,
                                                 *args, **kwargs)

    # -- reporting -----------------------------------------------------------------

    def snapshot(self) -> dict:
        return dict(self.meter) | {"sim_time": self.sim_time}

    def per_backend(self) -> Dict[str, dict]:
        """{backend: {bytes, ops, sges, async_ops, setups, setup_s,
        conn_live, conn_evicted, conn_reestablished}} for every registered
        backend (zeros for backends this network never used).
        ``conn_live`` is observed pool state (slots currently held);
        the churn counters accumulate since the last ``reset_meter``."""
        out: Dict[str, dict] = {}
        for name in transport_names():
            out[name] = {k: self.meter.get(f"{name}.{k}", 0)
                         for k in ("bytes", "ops", "sges", "async_ops",
                                   "setups", "setup_s", "conn_evicted",
                                   "conn_reestablished")}
            out[name]["conn_live"] = self.conns.live(name)
        return out

    def reset_meter(self) -> None:
        self.meter.clear()
        if self.sanitizer is not None:
            self.sanitizer.reset_meters()   # the shadow ledger follows
        self.sim_time = 0.0
        self._channel_busy.clear()   # busy stamps are absolute on the clock
        self._link_busy.clear()
        self._node_busy.clear()
        self._conn_busy.clear()      # ...and so are establishment stamps
        # NOTE: connection pools survive a meter reset on purpose (warm
        # state is not a meter); use reset_connections() to forget them
