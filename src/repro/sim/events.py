"""Deterministic discrete-event core of ``repro.sim``.

The :class:`EventLoop` owns one sim-time heap and one seeded RNG.  No
wall-clock anywhere: "now" is whatever event is being dispatched, and the
platform's notion of time is the network's resource clock
(:attr:`Network.sim_time`), which the loop synchronizes at every dispatch.

Clock semantics
---------------
The network clock is the *current handler's local time*, not a global
frontier.  At each dispatch the loop rewinds/advances ``net.sim_time`` to
the event's timestamp; the handler then drives real platform calls (fork,
demand paging, RPCs) that push the clock forward as they charge wire time.
Rewinding between handlers is safe — and is precisely how two concurrent
invocations contend — because every shared resource (per-(src, dst)
channels, per-node link lanes) is stamped with *absolute* busy-until times
that only move forward: a transfer issued at t=5.0 by one handler starts
no earlier than the lane stamps a t=4.9 handler left behind, so FCFS
queueing falls out of the reservations rather than from handler ordering.

A handler's end-to-end latency is simply ``net.sim_time - arrival_time``
after it returns.

Determinism
-----------
Ties in the heap break on a declared ``priority`` first (lower runs
earlier) and then on schedule order (a monotone sequence number), the
only randomness is the loop's own ``random.Random(seed)`` (arrival jitter),
and the loop keeps a structured event log — ``(time, label)`` per dispatch
— whose canonical digest is byte-identical across runs of the same trace
and seed (``tests/test_sim_engine.py`` pins this).

Priorities exist so that same-time ordering is *intent*, not an accident
of scheduling order: the replay engine runs arrivals/completions/crashes
at priority 0, GC sweeps at 10 and timeline sampling at 20 — exactly the
order the sequence numbers happened to produce before, so pinned digests
are unchanged.  What priorities leave untied is by definition
order-independent, and ``tiebreak_seed`` makes that claim testable: a
non-None seed shuffles dispatch order *within* each (time, priority)
class, and the race detector (``repro.analysis.races``) diffs the
resulting digests to find handlers that secretly depended on incidental
ordering.
"""
from __future__ import annotations

import heapq
import itertools
import random
from typing import Callable, List, Optional, Tuple

from .metrics import canonical_digest


class SimClock:
    """A callable clock that reads the network's sim time.

    Hand this to ``NodeRuntime(clock=...)`` / ``Coordinator(clock=...)``
    (or ``make_cluster(clock="sim")``) so lease deadlines, renewals, cache
    keepalive and GC all tick in replayed seconds instead of host
    ``time.monotonic()`` — the end-to-end lease wiring the replay engine
    relies on.
    """

    def __init__(self, network):
        self.network = network

    def __call__(self) -> float:
        return self.network.sim_time


class EventLoop:
    """Single-heap discrete-event scheduler, synchronized with a Network."""

    def __init__(self, network=None, seed: int = 0,
                 tiebreak_seed: Optional[int] = None):
        self.network = network
        self.rng = random.Random(seed)
        self.seed = seed
        self.now = 0.0
        self._heap: List[tuple] = []
        self._seq = itertools.count()
        self.events_run = 0
        self.log: List[Tuple[float, str]] = []
        # race-detector mode: shuffle dispatch order WITHIN each
        # (time, priority) tie class.  None (the default) keeps the
        # monotone schedule-order tiebreak, bit-identical to before.
        self.tiebreak_seed = tiebreak_seed
        self._tiebreak_rng = (None if tiebreak_seed is None
                              else random.Random(tiebreak_seed))

    # -- scheduling ----------------------------------------------------------

    def at(self, when: float, fn: Callable, *args,
           label: Optional[str] = None, priority: int = 0):
        """Schedule ``fn(*args)`` at absolute sim time ``when``.  Same-time
        events dispatch in ``priority`` order (lower first), then schedule
        order — declare ordering intent with ``priority`` instead of
        leaning on scheduling sequence."""
        if when < 0:
            raise ValueError(f"cannot schedule at negative sim time {when}")
        tie = (0.0 if self._tiebreak_rng is None
               else self._tiebreak_rng.random())
        heapq.heappush(self._heap,
                       (when, priority, tie, next(self._seq),
                        label or getattr(fn, "__name__", "event"), fn, args))

    def after(self, delay: float, fn: Callable, *args,
              label: Optional[str] = None, priority: int = 0):
        """Schedule ``fn(*args)`` ``delay`` seconds after the current event."""
        self.at(self.now + delay, fn, *args, label=label, priority=priority)

    def every(self, interval: float, fn: Callable, *,
              until: float, start: Optional[float] = None,
              label: Optional[str] = None, priority: int = 0):
        """Recurring event at ``start, start+interval, ...`` up to ``until``
        inclusive — bounded so periodic housekeeping (GC sweeps, timeline
        sampling) cannot keep an otherwise-drained replay alive forever."""
        if interval <= 0:
            raise ValueError(f"interval must be positive, got {interval}")
        lbl = label or getattr(fn, "__name__", "tick")

        def fire(when: float):
            fn()
            nxt = when + interval
            if nxt <= until:
                self.at(nxt, fire, nxt, label=lbl, priority=priority)

        first = interval if start is None else start
        if first <= until:
            self.at(first, fire, first, label=lbl, priority=priority)

    # -- dispatch ------------------------------------------------------------

    def run(self, until: Optional[float] = None) -> int:
        """Dispatch events in time order (schedule order on ties) until the
        heap drains or the next event is past ``until``.  Returns the number
        of events dispatched by this call."""
        ran = 0
        while self._heap and (until is None or self._heap[0][0] <= until):
            when, _prio, _tie, _seq, label, fn, args = heapq.heappop(self._heap)
            self.now = when
            if self.network is not None:
                # the handler's local time — see the module docstring for
                # why rewinding between handlers is safe (absolute,
                # monotone resource stamps carry the contention)
                self.network.sim_time = when
            self.log.append((round(when, 9), label))
            fn(*args)
            ran += 1
            self.events_run += 1
        if until is not None and until > self.now:
            self.now = until
        return ran

    def pending(self) -> int:
        return len(self._heap)

    def next_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def log_digest(self) -> str:
        """sha256 over the canonical event log — the byte-identity witness
        for 'same trace + same seed => same replay'."""
        return canonical_digest(self.log)
