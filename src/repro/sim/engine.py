"""ReplayEngine — trace-driven replay through the real fork/placement stack.

The engine turns a :class:`~repro.sim.trace.Trace` into arrival events on a
:class:`~repro.sim.events.EventLoop` and serves each arrival through the
actual platform: ``Coordinator`` seed store and GC, ``ForkHandle`` /
``ShardedSeed`` resume paths, demand paging and prefetch over the metered
``Network`` with per-node link lanes.  There is **no analytical latency
model** for the fork path — an invocation's latency is whatever the data
plane charges between its arrival and its completion event.  The only
modeled constants are container lifecycle costs the repo does not simulate
(cold boot, warm unpause) plus the function's own ``exec_sim_time``.

Per invocation the engine:

1. dispatches the arrival event (``net.sim_time`` = arrival time),
2. asks the autoscaler policy for a container (warm / fork / cold — fork
   runs the real descriptor-fetch + auth + paging machinery),
3. runs the function behavior (page touches charge wire time on contended
   lanes) and advances by ``exec_sim_time``,
4. schedules a completion event at the resulting clock, at which point the
   policy releases the container (back to the warm pool, or freed).

Housekeeping rides the same loop: ``Coordinator.gc()`` fires every
``gc_every`` sim seconds (lease expiry, cache keepalive, dangling-seed
reclamation — all on the sim clock via :class:`~repro.sim.events.SimClock`)
and memory/backlog timelines sample every ``sample_every`` seconds.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from typing import Dict, List, Optional

import numpy as np

from repro.net import ReproError
from repro.net.model import NetModel
from repro.net.network import Network
from repro.placement.scheduler import RoundRobinScheduler
from repro.platform.coordinator import Coordinator, FunctionDef
from repro.platform.node import NodeRuntime

from .autoscaler import AutoscalePolicy
from .events import EventLoop, SimClock
from .faults import FaultInjector, FaultPlan
from .metrics import (TelemetryStream, Timeline, canonical_digest,
                      latency_row)
from .trace import Invocation, Trace

SIM_PAGE_ELEMS = 4096          # 16 KiB fp32 pages — matches benchmarks

# Pristine container state is immutable (zeros) and containers copy it into
# their own pool frames at boot, so the host-side source array can be shared
# across every boot of the same function shape.  Allocating it fresh per
# coldstart costs an mmap/munmap pair plus first-touch faults per container —
# measured ~0.35 ms per 256 KiB on this class of VM, which dominates replays
# that cold-boot thousands of containers.
_PARAMS_TEMPLATES: Dict[tuple, dict] = {}


@dataclasses.dataclass(frozen=True)
class SimFunction:
    """A synthetic serverless function for replay: state size/layout plus
    the lifecycle costs the platform does not itself simulate."""

    name: str
    state_bytes: int = 1 << 20      # pristine container state
    vmas: int = 1                   # leaves the state is split across
    touch_frac: float = 0.5         # fraction of pages the handler touches
    exec_s: float = 0.030           # pure execution time (paper fig20: 30 ms)
    coldstart_s: float = 0.167      # local cold boot (paper §2: 167 ms)
    warm_start_s: float = 0.0005    # unpause of a cached container
    # container occupancy per invocation (checkout -> return-to-pool /
    # teardown), >= exec_s; None means exec_s.  FaaS containers serve one
    # request at a time and platforms hold them well past raw exec
    # (routing, repause, agent overhead) — fig20 sets this to the trace's
    # 60 s minute granularity, which is exactly the legacy analytical
    # model's occupancy assumption (one call per cached container per
    # minute), now enforced by completion events instead of bookkeeping.
    hold_s: Optional[float] = None

    def make_params(self):
        key = (self.state_bytes, self.vmas)
        if key not in _PARAMS_TEMPLATES:
            elems = max(1, self.state_bytes // 4 // max(1, self.vmas))
            _PARAMS_TEMPLATES[key] = {f"v{i}": np.zeros(elems, np.float32)
                                      for i in range(self.vmas)}
        return _PARAMS_TEMPLATES[key]

    def behavior(self, inst, inputs):
        """Touch ``touch_frac`` of every VMA — on a forked child this is
        demand paging over the wire; on warm/cold containers the pages are
        local and cost nothing."""
        for name, vma in inst.aspace.items():
            n = max(1, int(round(vma.npages * self.touch_frac)))
            inst.fetch_pages(name, np.arange(n))
        return {}

    def to_fdef(self) -> FunctionDef:
        return FunctionDef(name=self.name, arch=f"sim/{self.name}",
                           make_params=self.make_params,
                           behavior=self.behavior,
                           exec_sim_time=self.exec_s)


@dataclasses.dataclass
class ReplayResult:
    """Everything one replay produced, deterministically."""

    policy: dict
    trace: str
    seed: int
    nodes: int
    invocations: int
    decisions: Dict[str, int]
    latency: Dict[str, Dict[str, int]]       # end-to-end, per function + "all"
    startup: Dict[str, Dict[str, int]]       # arrival -> container ready
    memory: Timeline
    backlog: Timeline
    telemetry: TelemetryStream
    meter: Dict[str, float]
    conn: Dict[str, Dict[str, float]]        # per-backend pool counters
    lease: Dict[str, Dict[str, int]]
    payload_pages: Dict[str, int]            # rdma/rpc/cached page counts
    end_time: float
    events_run: int
    event_log_digest: str
    # fault-plane roll-up: None when the replay ran without a FaultPlan, so
    # fault-free summaries (and their digests) are byte-identical to
    # pre-fault-plane replays
    faults: Optional[dict] = None

    def summary(self) -> dict:
        """Deterministic, JSON-able digest (what benchmarks pin)."""
        gc_sweeps = self.telemetry.of_kind("gc")
        reclaimed = sum(r["seeds"] for r in gc_sweeps)
        rereplicated = sum(r["rereplicated"] for r in gc_sweeps)
        cache_expired = sum(r["cached"] for r in gc_sweeps)
        return {
            "policy": self.policy,
            "trace": self.trace,
            "seed": self.seed,
            "nodes": self.nodes,
            "invocations": self.invocations,
            "decisions": dict(sorted(self.decisions.items())),
            "latency": {k: dict(v) for k, v in sorted(self.latency.items())},
            "startup": {k: dict(v) for k, v in sorted(self.startup.items())},
            "mem_peak_node_mb": round(self.memory.peak_node() / 2**20, 3),
            "mem_peak_total_mb": round(self.memory.peak_total() / 2**20, 3),
            "mem_final_total_mb": round(self.memory.final_total() / 2**20, 3),
            "backlog_peak_s": round(self.backlog.peak_node(), 9),
            "gc": {"sweeps": len(gc_sweeps), "seeds_reclaimed": reclaimed,
                   "cached_expired": cache_expired,
                   "rereplicated": rereplicated},
            "lease": {f: dict(sorted(c.items()))
                      for f, c in sorted(self.lease.items())},
            "payload_pages": dict(sorted(self.payload_pages.items())),
            # connection control plane: per-backend pool state at replay
            # end (live slots) + churn counters — backends that never
            # connected are omitted so connectionless replays stay stable
            "conn": {name: dict(sorted(c.items()))
                     for name, c in sorted(self.conn.items())},
            "end_time_s": round(self.end_time, 9),
            "events": self.events_run,
            "event_log_digest": self.event_log_digest,
            **({"faults": self.faults} if self.faults is not None else {}),
        }

    def digest(self) -> str:
        return canonical_digest(self.summary())

    def to_json(self) -> str:
        return json.dumps(self.summary(), sort_keys=True, indent=1)


def build_cluster(n_nodes: int, transport: str = "dct",
                  page_elems: int = SIM_PAGE_ELEMS,
                  model: Optional[NetModel] = None,
                  pool_frames: int = 4096,
                  sanitize: Optional[bool] = None):
    """(network, nodes) wired to the sim clock: every node's lease clock
    reads ``net.sim_time``, so renewals and expiries happen in replayed
    seconds.  Construction is O(n): channel and link-lane state is lazy
    per pair/node, and each node pre-reserves ``pool_frames`` of lazily
    zeroed frame capacity so container churn never pays growth copies.
    ``sanitize=True`` runs the cluster under SimSan (None defers to the
    ``REPRO_SIMSAN`` environment switch, see repro.analysis.simsan)."""
    net = Network(model=model, transport=transport, sanitize=sanitize)
    clock = SimClock(net)
    nodes = [NodeRuntime(f"n{i}", net, page_elems=page_elems, clock=clock,
                         pool_frames=pool_frames)
             for i in range(n_nodes)]
    return net, nodes


class ReplayEngine:
    """Drive one (trace, policy) pair through the platform."""

    def __init__(self, trace: Trace, policy: AutoscalePolicy,
                 functions: List[SimFunction], *, n_nodes: int = 64,
                 seed: int = 0, transport: str = "dct",
                 page_elems: int = SIM_PAGE_ELEMS,
                 network: Optional[Network] = None,
                 nodes: Optional[List[NodeRuntime]] = None,
                 scheduler=None, reroute_backlog: Optional[float] = None,
                 gc_every: float = 30.0, sample_every: float = 30.0,
                 drain_margin: float = 120.0, keep_node_timelines: bool = False,
                 faults: Optional[FaultPlan] = None,
                 tiebreak_seed: Optional[int] = None):
        self.trace = trace
        self.policy = policy
        self.seed = seed
        if network is None or nodes is None:
            network, nodes = build_cluster(n_nodes, transport=transport,
                                           page_elems=page_elems)
        self.net = network
        self.nodes = nodes
        # tiebreak_seed is the race detector's knob (repro.analysis.races):
        # it shuffles same-(time, priority) dispatch order and must leave
        # every digest untouched on a race-free engine
        self.loop = EventLoop(network, seed=seed, tiebreak_seed=tiebreak_seed)
        self.coord = Coordinator(
            network, nodes, clock=SimClock(network),
            scheduler=scheduler or RoundRobinScheduler(),
            reroute_backlog=reroute_backlog)
        self.functions = {f.name: f for f in functions}
        for fname in trace.functions:
            if fname not in self.functions:
                raise ValueError(f"trace references unknown function {fname!r}")
        for f in functions:
            self.coord.register_function(f.to_fdef())
        self.gc_every = gc_every
        self.sample_every = sample_every
        self.drain_margin = drain_margin
        # telemetry & metrics
        self.telemetry = TelemetryStream()
        self.memory = Timeline("memory_bytes", keep_nodes=keep_node_timelines)
        self.backlog = Timeline("link_backlog_s",
                                keep_nodes=keep_node_timelines)
        self.decisions: Counter = Counter()
        self.latencies: Dict[str, List[float]] = {}
        self.startups: Dict[str, List[float]] = {}
        self.payload_pages: Counter = Counter()
        self.end_time = 0.0
        self._inflight = 0
        self._mem_peak_live: Dict[str, float] = {}
        # fault plane: the plan is installed as net.faults at run() so the
        # transports consult it; crashes ride the event loop (digest-visible)
        self.faults = faults
        self.injector: Optional[FaultInjector] = None
        self.failures = 0

    # -- modeled lifecycle costs --------------------------------------------

    def charge_coldstart(self, func: str) -> None:
        self.net.advance(self.functions[func].coldstart_s)

    def charge_warm_start(self, func: str) -> None:
        self.net.advance(self.functions[func].warm_start_s)

    # -- event handlers ------------------------------------------------------

    _PAYLOAD_KEYS = ("pages_rdma", "pages_rpc", "pages_cached",
                     "prefetch_wasted")

    def _payload_before(self, inst) -> Dict[str, int]:
        return {k: inst.stats.get(k, 0) for k in self._PAYLOAD_KEYS}

    def _fold_payload(self, inst, before: Dict[str, int]) -> None:
        for k, v0 in before.items():
            self.payload_pages[k] += inst.stats.get(k, 0) - v0

    def _degrade_to_cold(self, inv: Invocation, failed_inst, before):
        """The recovery chain's last rung: the fork path (or a mid-run
        remote read) failed beyond repair, so fold the failed child's
        partial payload stats (bytes it DID move stay accounted), free it,
        and cold-boot a pristine container on a live node.  Returns None —
        counting the invocation as failed — only when no live node can even
        coldstart."""
        if failed_inst is not None:
            self._fold_payload(failed_inst, before)
            if failed_inst.aspace:
                failed_inst.free()
        try:
            inst = self.coord.coldstart(inv.func, self.coord.pick_node())
        except ReproError:
            self.failures += 1
            return None
        self.charge_coldstart(inv.func)
        self.net.meter["degraded_cold"] += 1
        return inst

    def _on_arrival(self, inv: Invocation) -> None:
        t0 = self.net.sim_time
        try:
            kind, inst = self.policy.acquire(self, inv)
        except ReproError:
            # the policy's own path is gone (e.g. every scheduler candidate
            # crashed mid-trace): degrade straight to a coldstart
            kind, inst = "degraded", self._degrade_to_cold(inv, None, {})
            if inst is None:
                self.decisions["failed"] += 1
                return
        ready = self.net.sim_time
        before = self._payload_before(inst)
        fdef = self.coord.functions[inv.func]
        try:
            fdef.behavior(inst, {})
        except ReproError:
            # remote reads failed beyond the sibling/re-seed rungs
            kind, inst = "degraded", self._degrade_to_cold(inv, inst, before)
            if inst is None:
                self.decisions["failed"] += 1
                return
            ready = self.net.sim_time
            before = self._payload_before(inst)
            fdef.behavior(inst, {})     # pristine local pages: no fabric
        self.decisions[kind] += 1
        self.net.advance(fdef.exec_sim_time)
        done = self.net.sim_time
        self.latencies.setdefault(inv.func, []).append(done - t0)
        self.startups.setdefault(inv.func, []).append(ready - t0)
        self._fold_payload(inst, before)
        self._inflight += 1
        f = self.functions[inv.func]
        hold_end = max(done, t0 + (f.hold_s if f.hold_s is not None
                                   else f.exec_s))
        self.end_time = max(self.end_time, hold_end)
        # the completion label carries the serving decision and latency, so
        # the event-log digest witnesses per-invocation OUTCOMES, not just
        # the (policy-independent) dispatch schedule
        self.loop.at(hold_end, self._on_complete, inv, inst,
                     label=f"done:{inv.func}:{kind}:{int((done - t0) * 1e6)}us")

    def _on_complete(self, inv: Invocation, inst) -> None:
        self.policy.release(self, inv, inst)
        self._inflight -= 1

    def _on_crash(self, node_id: str) -> None:
        node = self.coord.nodes.get(node_id)
        if node is not None and node.alive:
            node.crash()
            self.telemetry.emit(self.net.sim_time, "crash", node=node_id)

    def _gc_tick(self) -> None:
        freed = self.coord.gc()
        self.telemetry.emit(
            self.net.sim_time, "gc", seeds=freed["seeds"],
            cached=freed["cached"], dangling=freed["dangling"],
            rereplicated=freed["rereplicated"])
        self.policy.on_gc(self, freed)

    def _sample(self) -> None:
        mem = {n.node_id: float(n.memory_bytes()) for n in self.nodes}
        self.memory.record(self.loop.now, mem)
        self.backlog.record(self.loop.now, self.net.backlog_snapshot()
                            or {self.nodes[0].node_id: 0.0})

    # -- run -----------------------------------------------------------------

    def run(self) -> ReplayResult:
        if self.faults is not None:
            # installed even when the plan is empty: the fig22 crash_rate=0
            # gate proves a live-but-empty injector perturbs nothing (the
            # zero plan draws no RNG, its penalty is an exact *1.0)
            self.injector = FaultInjector(self.net, self.faults)
            self.net.faults = self.injector
            self.injector.schedule(self.loop, self._on_crash)
        self.policy.on_start(self)
        arrivals = self.trace.arrivals(self.loop.rng)
        for inv in arrivals:
            self.loop.at(inv.t, self._on_arrival, inv,
                         label=f"arrive:{inv.func}")
        horizon = self.trace.duration_s + self.drain_margin
        # same-time ordering is declared, not incidental: invocation-facing
        # events (arrivals/completions/crashes) run first at a shared
        # timestamp, then GC sweeps, then timeline sampling — the order the
        # old schedule-sequence tiebreak happened to produce, now pinned by
        # priority so the tiebreak shuffle cannot flip gc/sample collisions
        # (every 60 s both fire at the same instant)
        self.loop.every(self.gc_every, self._gc_tick, until=horizon,
                        label="gc", priority=10)
        self.loop.every(self.sample_every, self._sample, until=horizon,
                        start=0.0, label="sample", priority=20)
        self.loop.run()
        def rollup(per_func: Dict[str, List[float]]) -> Dict[str, Dict[str, int]]:
            rows, flat = {}, []
            for func in sorted(per_func):
                rows[func] = latency_row(per_func[func])
                flat.extend(per_func[func])
            rows["all"] = latency_row(flat)
            return rows

        latency = rollup(self.latencies)
        startup = rollup(self.startups)
        meter = {k: (round(v, 9) if isinstance(v, float) else v)
                 for k, v in sorted(self.net.meter.items())}
        conn = {}
        for name, pb in self.net.per_backend().items():
            if pb["setups"] or pb["conn_live"] or pb["conn_evicted"]:
                conn[name] = {"live": pb["conn_live"],
                              "setups": pb["setups"],
                              "setup_s": round(pb["setup_s"], 9),
                              "evicted": pb["conn_evicted"],
                              "reestablished": pb["conn_reestablished"]}
        return ReplayResult(
            policy=self.policy.describe(), trace=self.trace.name,
            seed=self.seed, nodes=len(self.nodes),
            invocations=len(arrivals), decisions=dict(self.decisions),
            latency=latency, startup=startup,
            memory=self.memory, backlog=self.backlog,
            telemetry=self.telemetry, meter=meter, conn=conn,
            lease={f: dict(c) for f, c in self.coord.lease_telemetry.items()},
            payload_pages=dict(self.payload_pages),
            end_time=self.end_time, events_run=self.loop.events_run,
            event_log_digest=self.loop.log_digest(),
            faults=self._faults_rollup(len(arrivals)))

    def _faults_rollup(self, invocations: int) -> Optional[dict]:
        """Deterministic fault-plane summary section; None for fault-free
        replays AND for installed-but-empty plans, so a zero-rate plan's
        full summary digest is bit-identical to no plan at all."""
        if self.faults is None or self.faults.empty():
            return None
        m = self.net.meter
        return {
            "plan": self.faults.describe(),
            "crashes_fired": self.injector.crashes_fired,
            "timeouts": int(m.get("timeouts", 0)),
            "retries": int(m.get("retries", 0)),
            "backoff_wait_s": round(float(m.get("backoff_wait_s", 0.0)), 9),
            "recovery": {
                "pages": int(m.get("recovery.pages", 0)),
                "bytes": int(m.get("recovery.bytes", 0)),
                "sibling": int(m.get("recovery.sibling", 0)),
                "reseed": int(m.get("recovery.reseed", 0)),
                "reseed_fetches": int(m.get("recovery.reseed_fetches", 0)),
            },
            "degraded": int(m.get("degraded_cold", 0)),
            "failed": self.failures,
            "completion_rate": round(
                1.0 - self.failures / max(1, invocations), 6),
        }
