"""Autoscaler policies — who serves each invocation, and at what cost.

A policy decides, per arrival, whether the invocation lands on a warm
container, a freshly forked child, or a cold boot — and it does so by
driving the *real* platform: ``Coordinator.deploy_seed`` / fork-path
``acquire_instance`` / ``release`` / ``gc``, with lease renewal and cache
keepalive ticking on the replay's sim clock.  The only modeled constants
are the container lifecycle costs the repo does not simulate (process
boot, runtime init): ``coldstart_s`` on a cold boot and ``warm_start_s``
on an unpause, both charged by advancing the network clock.  Everything on
the fork path — descriptor fetch, authentication RPC, demand paging over
contended link lanes — is charged by the data plane itself.

Occupancy matters: a container acquired at t serves until its completion
event, so it is *out* of the warm pool for the whole execution. Keep-warm
capacity therefore tracks real concurrency (the paper's provisioning
argument) instead of one container magically serving a whole spike.

Policies:

* :class:`ForkOnDemand` — MITOSIS: S seed replicas per function, every
  invocation forks a child and frees it on completion.  Seeds stay alive
  through use-driven lease renewal and die of lease expiry when idle.
* :class:`KeepWarm` — Fn/OpenWhisk-style caching: released containers
  park in the coordinator's cached pool (LIFO reuse — the most recently
  parked container is the next one handed out), expire after ``ttl`` via
  ``Coordinator.gc``, optionally capped by ``budget``.
* :class:`Hybrid` — a bounded warm pool backed by fork spill: warm first,
  fork when the pool is empty, cold only if both fail.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core.instance import ModelInstance
from repro.platform.coordinator import DEFAULT_SEED_KEEPALIVE


class AutoscalePolicy:
    """Base policy.  Subclasses implement ``acquire``/``release``; the
    engine calls ``on_start`` once before the first arrival and ``on_gc``
    after every GC sweep."""

    name = "base"

    def on_start(self, engine) -> None:
        pass

    def acquire(self, engine, inv) -> Tuple[str, ModelInstance]:
        """Serve one arrival.  Returns (kind, instance) with kind in
        {"warm", "fork", "cold"}; all setup cost must be charged to the
        network clock before returning."""
        raise NotImplementedError

    def release(self, engine, inv, inst: ModelInstance) -> None:
        """Called at the invocation's completion event."""
        raise NotImplementedError

    def on_gc(self, engine, freed: dict) -> None:
        pass

    def describe(self) -> dict:
        return {"policy": self.name}


class ForkOnDemand(AutoscalePolicy):
    """Remote fork per invocation from S long-lived seed replicas."""

    name = "fork"

    def __init__(self, replicas: int = 1, lease: float = DEFAULT_SEED_KEEPALIVE,
                 renew_every: float = 60.0, lazy: bool = True,
                 prefetch: int = 1):
        self.replicas = replicas
        self.lease = lease
        self.renew_every = renew_every
        self.lazy = lazy
        self.prefetch = prefetch
        self._last_renew: dict = {}

    def on_start(self, engine) -> None:
        # keep the coordinator's auto-reseed path (coldstart fallback after
        # a lease expiry) at the same replica count as the initial deploy
        engine.coord.seed_replicas = self.replicas
        for func in engine.trace.functions:
            engine.coord.deploy_seed(func, replicas=self.replicas,
                                     keep_alive=self.lease)
            self._last_renew[func] = engine.net.sim_time

    def acquire(self, engine, inv) -> Tuple[str, ModelInstance]:
        coord = engine.coord
        now = engine.net.sim_time
        # use-driven keepalive: traffic renews the seed lease; an idle
        # function simply stops renewing and its seed ages out via gc()
        if now - self._last_renew.get(inv.func, 0.0) >= self.renew_every:
            coord.renew_seed(inv.func)
            self._last_renew[inv.func] = now
        had_seed = inv.func in coord.seed_store
        inst = coord.acquire_instance(inv.func, policy="fork",
                                      lazy=self.lazy, prefetch=self.prefetch)
        if inst.ancestry:
            return "fork", inst
        # the seed was gone (expired / reclaimed) and acquire fell back to
        # a coldstart that re-registered it — charge the cold boot
        engine.charge_coldstart(inv.func)
        self._last_renew[inv.func] = engine.net.sim_time
        if had_seed:
            engine.telemetry.emit(engine.net.sim_time, "seed_refresh",
                                  func=inv.func)
        return "cold", inst

    def release(self, engine, inv, inst: ModelInstance) -> None:
        engine.coord.release(inv.func, inst, "fork")

    def describe(self) -> dict:
        return {"policy": self.name, "replicas": self.replicas,
                "lease_s": self.lease, "renew_every_s": self.renew_every}


class KeepWarm(AutoscalePolicy):
    """Caching baseline: boot cold, park released containers warm.

    ``ttl`` maps to ``Coordinator.cache_keepalive`` so expiry is enforced
    by the platform's own GC on the sim clock.  Reuse is LIFO — the most
    recently parked container serves next, so the oldest entries are the
    ones that age out.  (The legacy fig20 model got this backwards: it
    consumed the *longest-lived* pool entries first, which both overstated
    warm capacity late in a spike and understated it early.)  ``budget``
    caps the pool per function, evicting oldest-parked first; ``prewarm``
    boots N containers per function at t=0 — the equal-warm-budget handle
    benchmarks use to compare against S fork replicas.
    """

    name = "cache"

    def __init__(self, ttl: float = 60.0, budget: Optional[int] = None,
                 prewarm: int = 0):
        self.ttl = ttl
        self.budget = budget
        self.prewarm = prewarm

    def on_start(self, engine) -> None:
        coord = engine.coord
        coord.cache_keepalive = self.ttl
        coord.auto_seed = False          # pure caching: no seed state at all
        for func in engine.trace.functions:
            pool = coord.cached.setdefault(func, [])
            for _ in range(self.prewarm):
                inst = coord.coldstart(func, coord.pick_node())
                pool.append((inst, engine.net.sim_time))

    def _pop_warm(self, engine, func: str) -> Optional[ModelInstance]:
        pool: List[tuple] = engine.coord.cached.get(func, [])
        while pool:
            inst, _ts = pool.pop()       # LIFO: most recently parked first
            if inst.aspace:              # husks (freed underneath) dropped
                return inst
        return None

    def acquire(self, engine, inv) -> Tuple[str, ModelInstance]:
        inst = self._pop_warm(engine, inv.func)
        if inst is not None:
            engine.charge_warm_start(inv.func)
            return "warm", inst
        inst = engine.coord.coldstart(inv.func, engine.coord.pick_node())
        engine.charge_coldstart(inv.func)
        return "cold", inst

    def release(self, engine, inv, inst: ModelInstance) -> None:
        coord = engine.coord
        coord.release(inv.func, inst, "cache")
        pool = coord.cached.get(inv.func, [])
        if self.budget is not None and len(pool) > self.budget:
            over = len(pool) - self.budget
            for victim, _ts in pool[:over]:    # evict oldest-parked first
                if victim.aspace:
                    victim.free()
            del pool[:over]
            engine.telemetry.emit(engine.net.sim_time, "evicted",
                                  func=inv.func, count=over)

    def describe(self) -> dict:
        return {"policy": self.name, "ttl_s": self.ttl,
                "budget": self.budget, "prewarm": self.prewarm}


class Hybrid(KeepWarm):
    """Bounded warm pool with fork spill: warm hit if the pool has a live
    container, else fork a child from the seed (``spill_to_fork=True``),
    else cold boot.  Fork children are freed on completion; warm containers
    go back to the pool (capped at ``pool``)."""

    name = "hybrid"

    def __init__(self, pool: int = 2, ttl: float = 60.0,
                 spill_to_fork: bool = True, replicas: int = 1,
                 lease: float = DEFAULT_SEED_KEEPALIVE, lazy: bool = True,
                 prefetch: int = 1):
        super().__init__(ttl=ttl, budget=pool, prewarm=pool)
        self.spill_to_fork = spill_to_fork
        self.replicas = replicas
        self.lease = lease
        self.lazy = lazy
        self.prefetch = prefetch

    def on_start(self, engine) -> None:
        coord = engine.coord
        coord.cache_keepalive = self.ttl
        for func in engine.trace.functions:
            if self.spill_to_fork:
                engine.coord.deploy_seed(func, replicas=self.replicas,
                                         keep_alive=self.lease)
            pool = coord.cached.setdefault(func, [])
            for _ in range(self.prewarm):
                inst = coord.coldstart(func, coord.pick_node())
                pool.append((inst, engine.net.sim_time))

    def acquire(self, engine, inv) -> Tuple[str, ModelInstance]:
        inst = self._pop_warm(engine, inv.func)
        if inst is not None:
            engine.charge_warm_start(inv.func)
            return "warm", inst
        if self.spill_to_fork and inv.func in engine.coord.seed_store:
            inst = engine.coord.acquire_instance(
                inv.func, policy="fork", lazy=self.lazy,
                prefetch=self.prefetch)
            if inst.ancestry:
                return "fork", inst
            engine.charge_coldstart(inv.func)
            return "cold", inst
        inst = engine.coord.coldstart(inv.func, engine.coord.pick_node())
        engine.charge_coldstart(inv.func)
        return "cold", inst

    def release(self, engine, inv, inst: ModelInstance) -> None:
        coord = engine.coord
        if inst.ancestry:
            # spilled fork children are never cached (§6.2)
            coord.release(inv.func, inst, "fork")
            return
        super().release(engine, inv, inst)

    def describe(self) -> dict:
        return {"policy": self.name, "pool": self.budget, "ttl_s": self.ttl,
                "spill_to_fork": self.spill_to_fork,
                "replicas": self.replicas}


class ColdStart(AutoscalePolicy):
    """Control: every invocation boots cold and is torn down after."""

    name = "coldstart"

    def on_start(self, engine) -> None:
        engine.coord.auto_seed = False

    def acquire(self, engine, inv) -> Tuple[str, ModelInstance]:
        inst = engine.coord.coldstart(inv.func, engine.coord.pick_node())
        engine.charge_coldstart(inv.func)
        return "cold", inst

    def release(self, engine, inv, inst: ModelInstance) -> None:
        inst.free()
