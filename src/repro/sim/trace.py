"""Invocation traces — per-minute counts expanded into arrival events.

Traces follow the Azure Functions dataset convention (the workload source
for the paper's Fig. 20): one row per function, one integer column per
minute.  ``load_azure_csv`` reads that format directly; the synthetic
generators build the same shape programmatically — including the paper's
function-660323 spike (1 rps jumping to ~120 rps inside two minutes).

A :class:`Trace` is purely *counts*.  Arrival times are materialized by
``arrivals(rng)``: each minute's count becomes that many uniformly
jittered timestamps inside the minute, drawn from the replay's seeded RNG
in a fixed order (functions sorted by name, minutes ascending) — so the
same trace and seed always yield the same arrival schedule.
"""
from __future__ import annotations

import csv
import dataclasses
import math
import random
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

# The per-minute invocation counts of the paper's motivating function
# (HashFunction 660323 of the Azure trace): flat ~1/min, a 100x+ burst
# over two minutes, then decay back to baseline.
SPIKE_660323: Tuple[int, ...] = (1, 1, 2, 1, 1, 40, 120, 30, 2, 1, 1, 1)


@dataclasses.dataclass(frozen=True)
class Invocation:
    """One arrival: sim time (s), function name, global index."""
    t: float
    func: str
    idx: int


@dataclasses.dataclass(frozen=True)
class Trace:
    """Per-minute invocation counts for one or more functions."""

    name: str
    per_minute: Mapping[str, Tuple[int, ...]]

    def __post_init__(self):
        frozen = {f: tuple(int(c) for c in counts)
                  for f, counts in self.per_minute.items()}
        if not frozen:
            raise ValueError("trace has no functions")
        for f, counts in frozen.items():
            if any(c < 0 for c in counts):
                raise ValueError(f"negative count in trace for {f!r}")
        object.__setattr__(self, "per_minute", frozen)

    @property
    def functions(self) -> List[str]:
        return sorted(self.per_minute)

    @property
    def minutes(self) -> int:
        return max(len(c) for c in self.per_minute.values())

    @property
    def duration_s(self) -> float:
        return self.minutes * 60.0

    def total_invocations(self) -> int:
        return sum(sum(c) for c in self.per_minute.values())

    def peak_per_minute(self) -> int:
        return max((max(c, default=0) for c in self.per_minute.values()),
                   default=0)

    def scaled(self, factor: int) -> "Trace":
        """Multiply every per-minute count (load scaling for smoke vs full)."""
        return Trace(f"{self.name}x{factor}",
                     {f: tuple(c * factor for c in counts)
                      for f, counts in self.per_minute.items()})

    def arrivals(self, rng: random.Random) -> List[Invocation]:
        """Expand counts into time-sorted arrivals with uniform in-minute
        jitter.  RNG consumption order is fixed (sorted functions, minutes
        ascending), so a given (trace, seed) is one schedule, always."""
        out: List[Invocation] = []
        for func in self.functions:
            for minute, count in enumerate(self.per_minute[func]):
                base = minute * 60.0
                for _ in range(count):
                    out.append(Invocation(base + rng.uniform(0.0, 60.0),
                                          func, 0))
        out.sort(key=lambda inv: (inv.t, inv.func))
        return [Invocation(inv.t, inv.func, i) for i, inv in enumerate(out)]


# -- synthetic generators ----------------------------------------------------

def spike_660323(scale: int = 1, func: str = "spike",
                 name: str = "fig20-spike") -> Trace:
    """The paper's Fig. 20 load spike, optionally scaled."""
    return Trace(name, {func: tuple(c * scale for c in SPIKE_660323)})


def diurnal(minutes: int = 60, base: int = 2, peak: int = 30,
            period_minutes: int = 60, phase: float = 0.0,
            func: str = "diurnal", name: str = "diurnal") -> Trace:
    """Sinusoidal day/night load: base..peak over ``period_minutes``."""
    counts = []
    for m in range(minutes):
        x = 2.0 * math.pi * (m / period_minutes + phase)
        level = base + (peak - base) * 0.5 * (1.0 - math.cos(x))
        counts.append(int(round(level)))
    return Trace(name, {func: tuple(counts)})


def multi_function(traces: Iterable[Trace], name: str = "mix") -> Trace:
    """Merge single-function traces into one multi-function workload."""
    merged: Dict[str, Tuple[int, ...]] = {}
    for tr in traces:
        for f, counts in tr.per_minute.items():
            if f in merged:
                raise ValueError(f"duplicate function {f!r} in mix")
            merged[f] = counts
    return Trace(name, merged)


def correlated_spikes(n_functions: int = 4, scale: int = 1,
                      stagger_minutes: int = 0, base: int = 1,
                      name: str = "correlated") -> Trace:
    """The fleet-level worst case: the same spike hitting ``n_functions``
    at once (``stagger_minutes=0``) or rippling across them with a fixed
    offset — correlated demand is what makes keep-warm provisioning
    explode, since every function's pool peaks together."""
    shape = tuple(max(base, c) * scale for c in SPIKE_660323)
    width = len(shape) + stagger_minutes * max(0, n_functions - 1)
    per: Dict[str, Tuple[int, ...]] = {}
    for i in range(n_functions):
        off = i * stagger_minutes
        counts = [base * scale] * width
        for m, c in enumerate(shape):
            counts[off + m] = c
        per[f"fn{i:03d}"] = tuple(counts)
    return Trace(name, per)


# -- Azure Functions CSV -----------------------------------------------------

def load_azure_csv(path: str, functions: Optional[Sequence[str]] = None,
                   minutes: Optional[int] = None, top: Optional[int] = None,
                   name: Optional[str] = None) -> Trace:
    """Load an Azure-Functions-format invocation trace.

    Expected columns: a function id column (``HashFunction``, or the first
    non-numeric column) plus per-minute count columns named ``"1".."1440"``.
    ``functions`` selects specific rows by id; ``top`` keeps the N busiest
    rows; ``minutes`` truncates the horizon.  Function ids are shortened to
    their first 8 chars (Azure hashes are 64 hex chars) with a numeric
    suffix on collision.
    """
    with open(path, newline="") as fh:
        reader = csv.DictReader(fh)
        if reader.fieldnames is None:
            raise ValueError(f"{path}: empty CSV")
        minute_cols = [c for c in reader.fieldnames if c.strip().isdigit()]
        minute_cols.sort(key=int)
        if not minute_cols:
            raise ValueError(
                f"{path}: no per-minute columns (expected numeric headers)")
        if minutes is not None:
            minute_cols = minute_cols[:minutes]
        id_col = ("HashFunction" if "HashFunction" in reader.fieldnames
                  else next(c for c in reader.fieldnames
                            if not c.strip().isdigit()))
        rows: List[Tuple[str, Tuple[int, ...]]] = []
        wanted = set(functions) if functions is not None else None
        for row in reader:
            fid = row[id_col]
            if wanted is not None and fid not in wanted:
                continue
            counts = tuple(int(float(row[c] or 0)) for c in minute_cols)
            rows.append((fid, counts))
    if wanted is not None and len(rows) < len(wanted):
        missing = wanted - {fid for fid, _ in rows}
        raise ValueError(f"{path}: functions not found: {sorted(missing)}")
    if top is not None:
        rows.sort(key=lambda r: (-sum(r[1]), r[0]))
        rows = rows[:top]
    per: Dict[str, Tuple[int, ...]] = {}
    for fid, counts in rows:
        short = fid[:8]
        while short in per:
            short = f"{short[:8]}~{len(per)}"
        per[short] = counts
    return Trace(name or f"azure:{path}", per)
