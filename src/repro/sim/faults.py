"""Deterministic fault plane: seeded crash/flap/degrade/timeout injection.

MITOSIS §6.2's deployability argument is that remote fork survives
failure: leases bound orphaned children, and a child whose parent dies
falls back instead of hanging on a dead RDMA peer.  This module makes
failure *causable* — and exactly reproducible — inside the ``repro.sim``
replay engine:

* a :class:`FaultPlan` is pure data: node crashes at sim times, NIC
  *flaps* (windows during which every op touching the node times out),
  NIC *degrades* (windows during which transfers through the node run at
  a fraction of line rate), and an optional per-op timeout probability;
* :class:`FaultInjector` is the live hook the :class:`~repro.net.network.
  Network` consults (``net.faults``): transports call ``op_fault`` ahead
  of every data-plane op and ``penalty`` on every transfer's wire time.

Determinism: flap/degrade windows are pure functions of ``net.sim_time``
(no mutable toggles, so a handler that advanced its local clock past a
window edge sees the edge immediately); the per-op coin is drawn from the
plan's own seeded RNG in transport-call order — the same order the replay
engine's single event heap fixes.  Crashes are scheduled as labeled
events on the :class:`~repro.sim.events.EventLoop`, so they land in the
replay's event-log digest.  An *empty* plan draws nothing, schedules
nothing and penalizes nothing: installing it is byte-identical to running
without a fault plane at all (the fig22 crash_rate=0 gate).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Crash:
    """Fail-stop of ``node`` at sim time ``t`` (never comes back)."""
    t: float
    node: str


@dataclasses.dataclass(frozen=True)
class Flap:
    """NIC outage on ``node`` over [t0, t1): every op with the node as
    either endpoint times out; the node itself stays alive (its seeds,
    pool and leases survive — only the fabric path is dark)."""
    t0: float
    t1: float
    node: str


@dataclasses.dataclass(frozen=True)
class Degrade:
    """Bandwidth degradation on ``node`` over [t0, t1): transfers touching
    the node run at ``bw_factor`` of line rate (0 < bw_factor <= 1)."""
    t0: float
    t1: float
    node: str
    bw_factor: float


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A reproducible failure schedule.  Pure data + one seed."""

    seed: int = 0
    crashes: Tuple[Crash, ...] = ()
    flaps: Tuple[Flap, ...] = ()
    degrades: Tuple[Degrade, ...] = ()
    op_fail_rate: float = 0.0       # per-attempt timeout probability

    def __post_init__(self):
        if not 0.0 <= self.op_fail_rate <= 1.0:
            raise ValueError(
                f"op_fail_rate must be in [0, 1], got {self.op_fail_rate}")
        for f in self.flaps:
            if f.t1 <= f.t0:
                raise ValueError(f"flap window inverted: {f}")
        for d in self.degrades:
            if d.t1 <= d.t0:
                raise ValueError(f"degrade window inverted: {d}")
            if not 0.0 < d.bw_factor <= 1.0:
                raise ValueError(f"bw_factor must be in (0, 1], got {d}")

    def empty(self) -> bool:
        return (not self.crashes and not self.flaps and not self.degrades
                and self.op_fail_rate == 0.0)

    def describe(self) -> dict:
        """Deterministic JSON-able summary for replay artifacts."""
        return {
            "seed": self.seed,
            "crashes": [[round(c.t, 9), c.node] for c in self.crashes],
            "flaps": [[round(f.t0, 9), round(f.t1, 9), f.node]
                      for f in self.flaps],
            "degrades": [[round(d.t0, 9), round(d.t1, 9), d.node,
                          d.bw_factor] for d in self.degrades],
            "op_fail_rate": self.op_fail_rate,
        }

    @classmethod
    def random(cls, seed: int, node_ids: Sequence[str], duration_s: float,
               crash_rate: float = 0.0, flap_rate: float = 0.0,
               flap_len_s: float = 5.0, degrade_rate: float = 0.0,
               degrade_len_s: float = 30.0, bw_factor: float = 0.25,
               op_fail_rate: float = 0.0) -> "FaultPlan":
        """Generate a plan: ``crash_rate`` / ``flap_rate`` / ``degrade_rate``
        are the fraction of nodes hit over ``duration_s`` (a rate of 0
        generates nothing of that class — the zero plan is exactly the
        empty plan).  Victims and times come from one ``random.Random(seed)``
        in a fixed draw order, so equal arguments always yield equal plans.
        Event times land in the middle 80% of the run so faults hit live
        traffic, not the warmup or drain tail."""
        rng = random.Random(seed)
        ids = sorted(node_ids)

        def _times(n: int) -> List[float]:
            return sorted(rng.uniform(0.1 * duration_s, 0.9 * duration_s)
                          for _ in range(n))

        def _victims(n: int) -> List[str]:
            return rng.sample(ids, min(n, len(ids)))

        n_crash = int(round(crash_rate * len(ids)))
        crashes = tuple(Crash(t, v) for t, v in
                        zip(_times(n_crash), _victims(n_crash)))
        n_flap = int(round(flap_rate * len(ids)))
        flaps = tuple(Flap(t, t + flap_len_s, v) for t, v in
                      zip(_times(n_flap), _victims(n_flap)))
        n_deg = int(round(degrade_rate * len(ids)))
        degrades = tuple(Degrade(t, t + degrade_len_s, v, bw_factor)
                         for t, v in zip(_times(n_deg), _victims(n_deg)))
        return cls(seed=seed, crashes=crashes, flaps=flaps,
                   degrades=degrades, op_fail_rate=op_fail_rate)


class FaultInjector:
    """The live fault hook a Network consults (``net.faults``).

    Window checks are time-pure (computed from ``net.sim_time``), the
    per-op coin is seeded (``plan.seed``) and consumed only when
    ``op_fail_rate > 0`` — so an all-zero plan never touches the RNG and
    perturbs nothing.
    """

    def __init__(self, net, plan: FaultPlan):
        self.net = net
        self.plan = plan
        self._rng = random.Random(plan.seed ^ 0x5EED_FA17)
        self._flaps: Dict[str, List[Tuple[float, float]]] = {}
        for f in plan.flaps:
            self._flaps.setdefault(f.node, []).append((f.t0, f.t1))
        # earliest crash instant per node: the DATA plane sees the node
        # dark the moment the (handler-local) clock passes this, even
        # though the crash EVENT — the control-plane teardown — only
        # dispatches between loop events.  Without this, a handler whose
        # reads straddle the crash instant would keep reading a dead peer.
        self._crashed: Dict[str, float] = {}
        for c in plan.crashes:
            t = self._crashed.get(c.node)
            self._crashed[c.node] = c.t if t is None else min(t, c.t)
        self._degrades: Dict[str, List[Tuple[float, float, float]]] = {}
        for d in plan.degrades:
            self._degrades.setdefault(d.node, []).append(
                (d.t0, d.t1, d.bw_factor))
        self.crashes_fired = 0

    # -- what transports ask --------------------------------------------------

    def flapped(self, node_id: str) -> bool:
        """True while ``node_id``'s NIC is dark at the current sim time."""
        now = self.net.sim_time
        return any(t0 <= now < t1
                   for t0, t1 in self._flaps.get(node_id, ()))

    def dark(self, node_id: str) -> bool:
        """True when ``node_id`` is unreachable right now: NIC flapped, or
        past its crash instant (time-pure — valid even before the crash
        event's teardown has dispatched)."""
        t = self._crashed.get(node_id)
        if t is not None and self.net.sim_time >= t:
            return True
        return self.flapped(node_id)

    def op_fault(self, transport_name: str, op: str, src: str,
                 dst: str) -> bool:
        """Should this op attempt time out?  Called once per attempt, in
        transport-call order (the determinism contract)."""
        if self.dark(src) or self.dark(dst):
            return True
        rate = self.plan.op_fail_rate
        return rate > 0.0 and self._rng.random() < rate

    def penalty(self, src: str, dst: str) -> float:
        """Wire-time multiplier (>= 1.0) for a transfer between src and
        dst right now: 1/bw_factor of the most-degraded endpoint."""
        factor = 1.0
        now = self.net.sim_time
        for node in (src, dst) if src != dst else (src,):
            for t0, t1, f in self._degrades.get(node, ()):
                if t0 <= now < t1:
                    factor = min(factor, f)
        return 1.0 / factor

    # -- scheduling (crashes are loop events; windows are time-pure) ----------

    def schedule(self, loop, crash_fn) -> None:
        """Put every planned crash on the event loop as a labeled event
        (so it lands in the determinism digest); ``crash_fn(node_id)`` is
        the engine's crash hook."""
        for c in self.plan.crashes:
            loop.at(c.t, self._fire_crash, crash_fn, c.node,
                    label=f"fault:crash:{c.node}")

    def _fire_crash(self, crash_fn, node_id: str) -> None:
        self.crashes_fired += 1
        crash_fn(node_id)
