"""Replay metrics — tail-latency CDFs, per-node timelines, telemetry.

Everything the :class:`~repro.sim.engine.ReplayEngine` emits is built from
the deterministic sim clock and the network meter, so two replays of the
same trace under the same RNG seed produce *byte-identical* metrics (and
event logs) — the determinism contract ``tests/test_sim_engine.py`` pins.

``percentile`` is also the fix for the legacy fig20 tail-index bug: the old
``lat[min(int(0.99 * len(lat)), len(lat) - 1)]`` clamp silently reports the
*maximum* on any trace shorter than 100 samples.  Here percentiles
interpolate linearly between order statistics (numpy's default), so a p99
on a short trace is a tail estimate, not a disguised p100.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


def percentile(samples: Sequence[float], q: float) -> float:
    """The q-th percentile (0..100) with linear interpolation between
    order statistics.  Unlike the legacy fig20 index clamp, this never
    silently degrades to the maximum on short traces."""
    arr = np.asarray(samples, np.float64)
    if arr.size == 0:
        return 0.0
    return float(np.percentile(arr, q))


def latency_row(samples: Sequence[float]) -> Dict[str, int]:
    """The standard tail-latency digest (microseconds, ints so committed
    benchmark artifacts stay byte-stable across platforms)."""
    arr = np.asarray(samples, np.float64)
    if arr.size == 0:
        return {"count": 0, "mean_us": 0, "p50_us": 0, "p99_us": 0,
                "p999_us": 0, "max_us": 0}
    return {
        "count": int(arr.size),
        "mean_us": int(arr.mean() * 1e6),
        "p50_us": int(percentile(arr, 50) * 1e6),
        "p99_us": int(percentile(arr, 99) * 1e6),
        "p999_us": int(percentile(arr, 99.9) * 1e6),
        "max_us": int(arr.max() * 1e6),
    }


def cdf_points(samples: Sequence[float],
               qs: Iterable[float] = (50, 90, 99, 99.9)) -> Dict[str, int]:
    """{"p50_us": ..., ...} CDF points for plotting/pinning."""
    return {f"p{str(q).rstrip('0').rstrip('.')}_us":
            int(percentile(samples, q) * 1e6) for q in qs}


@dataclasses.dataclass
class Timeline:
    """Per-node samples over sim time: ``rows`` is [(t, {node: value})].

    The full per-node matrix is kept only when ``keep_nodes`` — at fleet
    scale (thousands of nodes) the aggregate columns are what benchmarks
    pin, and the matrix would dominate the result payload.
    """

    name: str
    keep_nodes: bool = False
    rows: List[Tuple[float, Dict[str, float]]] = dataclasses.field(
        default_factory=list)
    # aggregate columns, one entry per sample: (t, total, max, mean)
    samples: List[Tuple[float, float, float, float]] = dataclasses.field(
        default_factory=list)

    def record(self, t: float, by_node: Dict[str, float]) -> None:
        vals = list(by_node.values())
        total = float(sum(vals))
        mx = float(max(vals)) if vals else 0.0
        mean = total / len(vals) if vals else 0.0
        self.samples.append((t, total, mx, mean))
        if self.keep_nodes:
            self.rows.append((t, dict(by_node)))

    def peak_total(self) -> float:
        return max((s[1] for s in self.samples), default=0.0)

    def peak_node(self) -> float:
        """The busiest single node seen at any sample point."""
        return max((s[2] for s in self.samples), default=0.0)

    def peak_mean(self) -> float:
        return max((s[3] for s in self.samples), default=0.0)

    def final_total(self) -> float:
        return self.samples[-1][1] if self.samples else 0.0

    def series(self) -> List[Dict[str, float]]:
        return [{"t": round(t, 6), "total": total, "max_node": mx,
                 "mean_node": mean} for t, total, mx, mean in self.samples]


class TelemetryStream:
    """Structured replay telemetry: GC sweeps, lease churn, autoscaler
    decisions — each record is (sim_time, kind, payload) and the stream
    serializes canonically for the determinism digest."""

    def __init__(self):
        self.records: List[dict] = []

    def emit(self, t: float, kind: str, **payload) -> None:
        self.records.append({"t": round(t, 9), "kind": kind, **payload})

    def of_kind(self, kind: str) -> List[dict]:
        return [r for r in self.records if r["kind"] == kind]

    def last(self, kind: str) -> Optional[dict]:
        recs = self.of_kind(kind)
        return recs[-1] if recs else None

    def to_json(self) -> str:
        return json.dumps(self.records, sort_keys=True)


def canonical_digest(obj) -> str:
    """sha256 over a canonical JSON encoding — the byte-identity check for
    event logs and metric summaries (same trace + seed => same digest)."""
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()
