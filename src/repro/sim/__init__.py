"""repro.sim — deterministic discrete-event trace replay over the real stack.

The subsystem behind the rebuilt fig20: per-minute invocation traces
(Azure-format CSV or synthetic generators) are expanded into arrival
events on a single sim-time heap, each served through the actual
platform — Coordinator seed store, ForkHandle/ShardedSeed resume paths,
demand paging on contended link lanes, lease renewal/expiry/GC — under a
pluggable autoscaler policy.  No wall clock, no analytical fork-latency
shortcuts; one seed, one schedule, byte-identical metrics.

See ``docs/replay.md`` for the event model and how to add a policy.
"""
from .autoscaler import (AutoscalePolicy, ColdStart, ForkOnDemand, Hybrid,
                         KeepWarm)
from .engine import (ReplayEngine, ReplayResult, SimFunction, build_cluster)
from .events import EventLoop, SimClock
from .faults import Crash, Degrade, FaultInjector, FaultPlan, Flap
from .metrics import (TelemetryStream, Timeline, canonical_digest, cdf_points,
                      latency_row, percentile)
from .trace import (SPIKE_660323, Invocation, Trace, correlated_spikes,
                    diurnal, load_azure_csv, multi_function, spike_660323)

__all__ = [
    "AutoscalePolicy", "ColdStart", "ForkOnDemand", "Hybrid", "KeepWarm",
    "ReplayEngine", "ReplayResult", "SimFunction", "build_cluster",
    "EventLoop", "SimClock",
    "Crash", "Degrade", "FaultInjector", "FaultPlan", "Flap",
    "TelemetryStream", "Timeline", "canonical_digest", "cdf_points",
    "latency_row", "percentile",
    "SPIKE_660323", "Invocation", "Trace", "correlated_spikes", "diurnal",
    "load_azure_csv", "multi_function", "spike_660323",
]
