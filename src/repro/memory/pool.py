"""Paged memory pools — the "physical memory" of a node.

A ``PagePool`` holds, per dtype, a single frames array of shape
(num_frames, PAGE_ELEMS).  Tensors are packed into pages
(memory/paging.py); page tables (core/pagetable.py) map tensor pages to
frames.  This is the analogue of the parent's physical memory that MITOSIS
children read over RDMA.

Two flavors share one interface:

* **host pool** (default) — frames are a host numpy array mutated in
  place.  The data plane is *run-coalesced*: gathers and scatters are
  decomposed into maximal contiguous extents and moved as slice copies
  (one memcpy per extent) instead of per-page fancy indexing, mirroring
  on the CPU exactly what the doorbell-batched wire path does with SGEs.
* **device pool** (``device=True``) — frames are a device (jnp) array and
  the data plane routes through the Pallas kernels: ``write_pages`` is a
  ``cow_scatter`` commit, ``read_pages``/``assemble`` are ``page_gather``
  launches (compiled on TPU, fused-XLA elsewhere — kernels/dispatch.py).
  This is the §5 "CPU out of the byte-moving loop" configuration.

``assemble`` is the fused gather->reassemble path: faulted pages land
directly in the destination tensor layout, skipping the intermediate
page-list concatenate the legacy ``read_pages`` + ``from_pages`` pair
materialized.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.cow_scatter.ops import cow_scatter, cow_scatter_runs
from repro.kernels.page_gather.ops import (gather_assemble, page_gather,
                                           page_gather_runs)

PAGE_ELEMS = 32768  # elements per page (128 KiB fp32 / 64 KiB bf16)

# host gather/scatter switches to per-extent slice copies when the average
# run is at least this long; shorter runs stay on one fancy-index op (the
# python loop per run would dominate)
HOST_RUN_MIN_AVG = 4


def frame_runs(frames) -> Tuple[np.ndarray, np.ndarray]:
    """Decompose a frame list into maximal contiguous runs: (starts, lens).
    The doorbell/SGE shape — shared by the host slice-copy data plane, the
    run-table kernels, and the paging roofline's bucket accounting."""
    idx = np.atleast_1d(np.asarray(frames, np.int64)).ravel()
    if idx.size == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    breaks = np.nonzero(np.diff(idx) != 1)[0] + 1
    bounds = np.concatenate([[0], breaks, [idx.size]])
    return idx[bounds[:-1]].copy(), np.diff(bounds)


class OutOfFrames(RuntimeError):
    pass


class PagePool:
    """Frames are held as a host numpy array (in-place writes — the node's
    simulated physical memory) or, with ``device=True``, as a device array
    whose data plane is the page_gather/cow_scatter kernels.

    ``kernel_backend`` is the dispatch request for device-pool kernel
    launches (see kernels/dispatch.py); ``meter`` is an optional
    Counter-like that receives the ``kernel.{name}.{impl}`` choice counts
    and ``pool.*`` data-plane counters (NodeRuntime wires the network
    meter in, so benchmarks see which backend actually moved the bytes).
    """

    def __init__(self, page_elems: int = PAGE_ELEMS, grow_frames: int = 256,
                 initial_frames: int = 0, device: bool = False,
                 kernel_backend: str = "auto", meter=None):
        self.page_elems = page_elems
        self.grow_frames = grow_frames
        # reserve this many frames per dtype up front: np.zeros is lazy
        # (calloc), so a large reserve costs nothing until frames are
        # touched, while every growth step copies the whole pool — replay
        # clusters reserve their working set and never pay a copy
        self.initial_frames = initial_frames
        self.device = device
        self.kernel_backend = kernel_backend
        self.meter = meter
        self._frames: Dict[str, object] = {}    # dtype name -> (F, page_elems)
        self._free: Dict[str, List[int]] = {}       # kept sorted ascending
        self._allocated: Dict[str, set] = {}

    # -- bookkeeping ---------------------------------------------------------

    def _dt(self, dtype) -> str:
        return jnp.dtype(dtype).name

    def _np_dtype(self, dt: str):
        # numpy has no bfloat16: store via jax's extended dtype view
        return jnp.dtype(dt)

    def _count(self, key: str, n: int = 1) -> None:
        if self.meter is not None:
            self.meter[key] += n

    def _drain_kernel_meters(self) -> None:
        # surface the dispatch layer's chosen-impl counts (recorded by the
        # ops call that just ran) in this pool's meter
        if self.meter is not None:
            dispatch.drain_meters_into(self.meter)

    def _ensure_capacity(self, dt: str, n: int):
        if dt not in self._frames:
            zeros = jnp.zeros if self.device else np.zeros
            self._frames[dt] = zeros((self.initial_frames, self.page_elems),
                                     dtype=self._np_dtype(dt))
            self._free[dt] = list(range(self.initial_frames))
            self._allocated[dt] = set()
        while len(self._free[dt]) < n:
            old = self._frames[dt]
            # geometric growth: each concatenate copies the whole pool, so
            # growing by a constant amortizes to O(F^2) over a replay that
            # churns thousands of instances — doubling keeps it O(F)
            grow = max(self.grow_frames, n - len(self._free[dt]),
                       old.shape[0])
            xp = jnp if self.device else np
            self._frames[dt] = xp.concatenate(
                [old, xp.zeros((grow, self.page_elems), dtype=old.dtype)])
            self._free[dt].extend(range(old.shape[0], old.shape[0] + grow))

    # -- alloc/free ----------------------------------------------------------
    # The allocator is extent-aware: the free list is kept sorted so free
    # frames form coalesced runs, and alloc() hands out the best-fit
    # contiguous run (falling back to the largest runs when fragmented).
    # Contiguity is what makes a VMA's pages one scatter-gather entry on
    # the wire — the transport charges per contiguous run, so a seed
    # packed into extents is read with a handful of doorbell ops instead
    # of one op per page.

    def _free_runs(self, dt: str) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(free_frames, run_starts, run_lens) over the sorted free list."""
        arr = np.asarray(self._free[dt], np.int32)
        if arr.size == 0:
            return arr, np.zeros(0, np.int64), np.zeros(0, np.int64)
        breaks = np.nonzero(np.diff(arr) != 1)[0] + 1
        starts = np.concatenate([[0], breaks]).astype(np.int64)
        ends = np.concatenate([breaks, [arr.size]]).astype(np.int64)
        return arr, starts, ends - starts

    def free_extents(self, dtype) -> List[Tuple[int, int]]:
        """[(first_frame, run_len)] of the coalesced free runs (diagnostics)."""
        dt = self._dt(dtype)
        if dt not in self._free:
            return []
        arr, starts, lens = self._free_runs(dt)
        return [(int(arr[s]), int(l)) for s, l in zip(starts, lens)]

    def alloc(self, dtype, n: int) -> np.ndarray:
        dt = self._dt(dtype)
        if n <= 0:
            return np.zeros(0, np.int32)
        self._ensure_capacity(dt, n)
        if n == 1:
            # fault/COW hot path: pop the highest free frame — O(1), and
            # taking a run's tail frame never splits an extent
            f = self._free[dt].pop()
            self._allocated[dt].add(f)
            return np.asarray([f], np.int32)
        arr, starts, lens = self._free_runs(dt)
        fits = np.nonzero(lens >= n)[0]
        if fits.size:
            # best fit: the smallest run that holds the request whole, so
            # large extents survive for large allocations.  arr indexes the
            # sorted free list positionally, so the hot path removes one
            # slice instead of rebuilding the list.
            i = int(fits[np.argmin(lens[fits])])
            s = int(starts[i])
            take = arr[s:s + n].copy()
            del self._free[dt][s:s + n]
        else:
            # fragmented: span the largest runs first to minimize the
            # number of extents the allocation straddles
            parts, need = [], n
            for i in np.argsort(-lens):
                s, l = int(starts[i]), int(min(lens[i], need))
                parts.append(arr[s:s + l])
                need -= l
                if need == 0:
                    break
            take = np.concatenate(parts)
            taken = set(take.tolist())
            self._free[dt] = [f for f in self._free[dt] if f not in taken]
        self._allocated[dt].update(take.tolist())
        return np.asarray(take, np.int32)

    def free(self, dtype, frames) -> None:
        dt = self._dt(dtype)
        alloc = self._allocated[dt]
        returned = sorted({f for f in np.asarray(frames).tolist()
                           if f in alloc})
        if not returned:
            return
        alloc.difference_update(returned)
        if len(returned) == 1:       # common single-frame case: no re-sort
            bisect.insort(self._free[dt], returned[0])
        else:                        # one merge of two sorted lists
            self._free[dt] = sorted(self._free[dt] + returned)

    def num_allocated(self, dtype=None) -> int:
        if dtype is not None:
            return len(self._allocated.get(self._dt(dtype), ()))
        return sum(len(v) for v in self._allocated.values())

    def bytes_allocated(self) -> int:
        tot = 0
        for dt, alloc in self._allocated.items():
            tot += len(alloc) * self.page_elems * jnp.dtype(dt).itemsize
        return tot

    def bytes_reserved(self) -> int:
        return sum(a.shape[0] * self.page_elems * jnp.dtype(dt).itemsize
                   for dt, a in self._frames.items())

    # -- data plane ----------------------------------------------------------

    def write_pages(self, dtype, frames, pages) -> None:
        """COW-commit ``pages`` into ``frames``.  Device pools route through
        the cow_scatter kernel (one fused scatter per run table); host pools
        land each contiguous extent as one slice copy."""
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        if idx.size == 0:
            return
        self._count("pool.scatter_pages", int(idx.size))
        if self.device:
            payload = jnp.asarray(np.asarray(pages)) \
                if isinstance(pages, np.ndarray) else jnp.asarray(pages)
            starts, lens = frame_runs(idx)
            if starts.size * 2 <= idx.size:
                self._frames[dt] = cow_scatter_runs(
                    self._frames[dt], starts, lens, payload,
                    backend=self.kernel_backend)
            else:
                self._frames[dt] = cow_scatter(
                    self._frames[dt], jnp.asarray(idx), payload,
                    backend=self.kernel_backend)
            self._drain_kernel_meters()
            return
        dst = self._frames[dt]
        if not (isinstance(pages, np.ndarray) and pages.dtype == dst.dtype):
            pages = np.asarray(
                pages.astype(dt) if hasattr(pages, "astype") else pages)
        starts, lens = frame_runs(idx)
        if starts.size * HOST_RUN_MIN_AVG <= idx.size:
            # extent-run commit: one memcpy per contiguous run
            self._count("pool.scatter_runs", int(starts.size))
            o = 0
            for s, l in zip(starts.tolist(), lens.tolist()):
                dst[s:s + l] = pages[o:o + l]
                o += l
        else:
            dst[idx] = pages

    def write_rows(self, dtype, frames, slots, rows, row_elems: int) -> None:
        """In-place row update within pages: frames (B,), slots (B,),
        rows (B, row_elems). Used by the serving engine's token appends."""
        dt = self._dt(dtype)
        fidx = np.asarray(frames, np.int32)
        sidx = np.asarray(slots, np.int32)
        if self.device:
            F = self._frames[dt].shape[0]
            view = self._frames[dt].reshape(F, -1, row_elems)
            self._frames[dt] = view.at[jnp.asarray(fidx),
                                       jnp.asarray(sidx)].set(
                jnp.asarray(rows).astype(view.dtype)).reshape(F, -1)
            return
        F = self._frames[dt].shape[0]
        view = self._frames[dt].reshape(F, -1, row_elems)
        view[fidx, sidx] = \
            np.asarray(rows.astype(dt) if hasattr(rows, "astype") else rows)

    def _gather_host(self, dt: str, idx: np.ndarray,
                     out: Optional[np.ndarray] = None) -> np.ndarray:
        """Run-coalesced host gather: one slice copy per contiguous extent
        when runs are long, one fancy-index op otherwise; lands directly in
        ``out`` when given (no intermediate page-list concatenate)."""
        src = self._frames[dt]
        starts, lens = frame_runs(idx)
        if out is None:
            out = np.empty((idx.size, self.page_elems), src.dtype)
        if starts.size * HOST_RUN_MIN_AVG <= idx.size:
            self._count("pool.gather_runs", int(starts.size))
            o = 0
            for s, l in zip(starts.tolist(), lens.tolist()):
                out[o:o + l] = src[s:s + l]
                o += l
        else:
            np.take(src, idx, axis=0, out=out)
        return out

    def read_pages(self, dtype, frames) -> jax.Array:
        """Gather frames -> (n, page_elems). The local-read data plane."""
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        self._count("pool.gather_pages", int(idx.size))
        if self.device:
            starts, lens = frame_runs(idx)
            if starts.size * 2 <= idx.size:
                out = page_gather_runs(self._frames[dt], starts, lens,
                                       backend=self.kernel_backend)
            else:
                out = page_gather(self._frames[dt], jnp.asarray(idx),
                                  backend=self.kernel_backend)
            self._drain_kernel_meters()
            return out
        return jnp.asarray(self._gather_host(dt, idx))

    def read_pages_host(self, dtype, frames,
                        out: Optional[np.ndarray] = None) -> np.ndarray:
        """Gather frames -> (n, page_elems) as a HOST array (no device
        transfer).  This is what moves on the wire: the RNIC analogue DMAs
        physical frames, and the payload only becomes a device tensor at
        assembly time (``assemble``).  Fleet-scale replays fork tens of
        thousands of children; the paging fast path must not pay a device
        round trip per fault.  ``out`` (optionally pre-allocated by the
        caller) receives the pages in place."""
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        if self.device:
            data = np.asarray(self.read_pages(dtype, frames))
            if out is not None:
                out[...] = data
                return out
            return data
        self._count("pool.gather_pages", int(idx.size))
        return self._gather_host(dt, idx, out=out)

    def assemble(self, dtype, frames, shape) -> jax.Array:
        """Fused gather->reassemble: gather ``frames`` and land them
        directly in the destination tensor layout (trim the final page's
        padding, reshape) — the fault handler's tensor-assembly fast path.

        Device pools run this as ONE fused launch (gather + reshape in a
        single XLA computation / Pallas kernel + fused epilogue); host
        pools gather run-coalesced into a flat destination buffer and hand
        the device exactly one H2D copy — in both cases the intermediate
        (n_pages, page_elems) hop of ``read_pages`` + ``from_pages`` is
        gone."""
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        self._count("pool.assemble_pages", int(idx.size))
        size = int(np.prod(shape)) if len(tuple(shape)) else 1
        if self.device:
            out = gather_assemble(self._frames[dt], jnp.asarray(idx), shape,
                                  out_dtype=dt, backend=self.kernel_backend)
            self._drain_kernel_meters()
            return out
        flat = np.empty(idx.size * self.page_elems,
                        self._frames[dt].dtype)
        self._gather_host(dt, idx, out=flat.reshape(idx.size,
                                                    self.page_elems))
        return jnp.asarray(flat[:size].reshape(shape))

    def frames_array(self, dtype) -> jax.Array:
        """Expose raw physical frames (what the RNIC reads)."""
        if self.device:
            return self._frames[self._dt(dtype)]
        return jnp.asarray(self._frames[self._dt(dtype)])
