"""Paged memory pools — the "physical memory" of a node.

A ``PagePool`` holds, per dtype, a single device-resident frames array of
shape (num_frames, PAGE_ELEMS).  Tensors are packed into pages
(memory/paging.py); page tables (core/pagetable.py) map tensor pages to
frames.  This is the analogue of the parent's physical memory that MITOSIS
children read over RDMA.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

PAGE_ELEMS = 32768  # elements per page (128 KiB fp32 / 64 KiB bf16)


class OutOfFrames(RuntimeError):
    pass


class PagePool:
    """Frames are held as a host numpy array (in-place writes — this is the
    node's simulated physical memory); reads hand out jnp arrays.  On real
    TPU the pool is a device buffer updated by the cow_scatter kernel."""

    def __init__(self, page_elems: int = PAGE_ELEMS, grow_frames: int = 256):
        self.page_elems = page_elems
        self.grow_frames = grow_frames
        self._frames: Dict[str, np.ndarray] = {}    # dtype name -> (F, page_elems)
        self._free: Dict[str, List[int]] = {}
        self._allocated: Dict[str, set] = {}

    # -- bookkeeping ---------------------------------------------------------

    def _dt(self, dtype) -> str:
        return jnp.dtype(dtype).name

    def _np_dtype(self, dt: str):
        # numpy has no bfloat16: store via jax's extended dtype view
        return jnp.dtype(dt)

    def _ensure_capacity(self, dt: str, n: int):
        if dt not in self._frames:
            self._frames[dt] = np.zeros((0, self.page_elems),
                                        dtype=self._np_dtype(dt))
            self._free[dt] = []
            self._allocated[dt] = set()
        while len(self._free[dt]) < n:
            old = self._frames[dt]
            grow = max(self.grow_frames, n - len(self._free[dt]))
            self._frames[dt] = np.concatenate(
                [old, np.zeros((grow, self.page_elems),
                               dtype=old.dtype)])
            self._free[dt].extend(range(old.shape[0], old.shape[0] + grow))

    # -- alloc/free ----------------------------------------------------------

    def alloc(self, dtype, n: int) -> np.ndarray:
        dt = self._dt(dtype)
        self._ensure_capacity(dt, n)
        frames = [self._free[dt].pop() for _ in range(n)]
        self._allocated[dt].update(frames)
        return np.asarray(frames, np.int32)

    def free(self, dtype, frames) -> None:
        dt = self._dt(dtype)
        for f in np.asarray(frames).tolist():
            if f in self._allocated[dt]:
                self._allocated[dt].discard(f)
                self._free[dt].append(f)

    def num_allocated(self, dtype=None) -> int:
        if dtype is not None:
            return len(self._allocated.get(self._dt(dtype), ()))
        return sum(len(v) for v in self._allocated.values())

    def bytes_allocated(self) -> int:
        tot = 0
        for dt, alloc in self._allocated.items():
            tot += len(alloc) * self.page_elems * jnp.dtype(dt).itemsize
        return tot

    def bytes_reserved(self) -> int:
        return sum(a.shape[0] * self.page_elems * jnp.dtype(dt).itemsize
                   for dt, a in self._frames.items())

    # -- data plane ----------------------------------------------------------

    def write_pages(self, dtype, frames, pages) -> None:
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        self._frames[dt][idx] = np.asarray(
            pages.astype(dt) if hasattr(pages, "astype") else pages)

    def write_rows(self, dtype, frames, slots, rows, row_elems: int) -> None:
        """In-place row update within pages: frames (B,), slots (B,),
        rows (B, row_elems). Used by the serving engine's token appends."""
        dt = self._dt(dtype)
        F = self._frames[dt].shape[0]
        view = self._frames[dt].reshape(F, -1, row_elems)
        view[np.asarray(frames, np.int32), np.asarray(slots, np.int32)] = \
            np.asarray(rows.astype(dt) if hasattr(rows, "astype") else rows)

    def read_pages(self, dtype, frames) -> jax.Array:
        """Gather frames -> (n, page_elems). The local-read data plane."""
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        return jnp.asarray(self._frames[dt][idx])

    def frames_array(self, dtype) -> jax.Array:
        """Expose raw physical frames (what the RNIC reads)."""
        return jnp.asarray(self._frames[self._dt(dtype)])
