"""Paged memory pools — the "physical memory" of a node.

A ``PagePool`` holds, per dtype, a single device-resident frames array of
shape (num_frames, PAGE_ELEMS).  Tensors are packed into pages
(memory/paging.py); page tables (core/pagetable.py) map tensor pages to
frames.  This is the analogue of the parent's physical memory that MITOSIS
children read over RDMA.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAGE_ELEMS = 32768  # elements per page (128 KiB fp32 / 64 KiB bf16)


class OutOfFrames(RuntimeError):
    pass


class PagePool:
    """Frames are held as a host numpy array (in-place writes — this is the
    node's simulated physical memory); reads hand out jnp arrays.  On real
    TPU the pool is a device buffer updated by the cow_scatter kernel."""

    def __init__(self, page_elems: int = PAGE_ELEMS, grow_frames: int = 256,
                 initial_frames: int = 0):
        self.page_elems = page_elems
        self.grow_frames = grow_frames
        # reserve this many frames per dtype up front: np.zeros is lazy
        # (calloc), so a large reserve costs nothing until frames are
        # touched, while every growth step copies the whole pool — replay
        # clusters reserve their working set and never pay a copy
        self.initial_frames = initial_frames
        self._frames: Dict[str, np.ndarray] = {}    # dtype name -> (F, page_elems)
        self._free: Dict[str, List[int]] = {}       # kept sorted ascending
        self._allocated: Dict[str, set] = {}

    # -- bookkeeping ---------------------------------------------------------

    def _dt(self, dtype) -> str:
        return jnp.dtype(dtype).name

    def _np_dtype(self, dt: str):
        # numpy has no bfloat16: store via jax's extended dtype view
        return jnp.dtype(dt)

    def _ensure_capacity(self, dt: str, n: int):
        if dt not in self._frames:
            self._frames[dt] = np.zeros((self.initial_frames, self.page_elems),
                                        dtype=self._np_dtype(dt))
            self._free[dt] = list(range(self.initial_frames))
            self._allocated[dt] = set()
        while len(self._free[dt]) < n:
            old = self._frames[dt]
            # geometric growth: each concatenate copies the whole pool, so
            # growing by a constant amortizes to O(F^2) over a replay that
            # churns thousands of instances — doubling keeps it O(F)
            grow = max(self.grow_frames, n - len(self._free[dt]),
                       old.shape[0])
            self._frames[dt] = np.concatenate(
                [old, np.zeros((grow, self.page_elems),
                               dtype=old.dtype)])
            self._free[dt].extend(range(old.shape[0], old.shape[0] + grow))

    # -- alloc/free ----------------------------------------------------------
    # The allocator is extent-aware: the free list is kept sorted so free
    # frames form coalesced runs, and alloc() hands out the best-fit
    # contiguous run (falling back to the largest runs when fragmented).
    # Contiguity is what makes a VMA's pages one scatter-gather entry on
    # the wire — the transport charges per contiguous run, so a seed
    # packed into extents is read with a handful of doorbell ops instead
    # of one op per page.

    def _free_runs(self, dt: str) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(free_frames, run_starts, run_lens) over the sorted free list."""
        arr = np.asarray(self._free[dt], np.int32)
        if arr.size == 0:
            return arr, np.zeros(0, np.int64), np.zeros(0, np.int64)
        breaks = np.nonzero(np.diff(arr) != 1)[0] + 1
        starts = np.concatenate([[0], breaks]).astype(np.int64)
        ends = np.concatenate([breaks, [arr.size]]).astype(np.int64)
        return arr, starts, ends - starts

    def free_extents(self, dtype) -> List[Tuple[int, int]]:
        """[(first_frame, run_len)] of the coalesced free runs (diagnostics)."""
        dt = self._dt(dtype)
        if dt not in self._free:
            return []
        arr, starts, lens = self._free_runs(dt)
        return [(int(arr[s]), int(l)) for s, l in zip(starts, lens)]

    def alloc(self, dtype, n: int) -> np.ndarray:
        dt = self._dt(dtype)
        if n <= 0:
            return np.zeros(0, np.int32)
        self._ensure_capacity(dt, n)
        if n == 1:
            # fault/COW hot path: pop the highest free frame — O(1), and
            # taking a run's tail frame never splits an extent
            f = self._free[dt].pop()
            self._allocated[dt].add(f)
            return np.asarray([f], np.int32)
        arr, starts, lens = self._free_runs(dt)
        fits = np.nonzero(lens >= n)[0]
        if fits.size:
            # best fit: the smallest run that holds the request whole, so
            # large extents survive for large allocations.  arr indexes the
            # sorted free list positionally, so the hot path removes one
            # slice instead of rebuilding the list.
            i = int(fits[np.argmin(lens[fits])])
            s = int(starts[i])
            take = arr[s:s + n].copy()
            del self._free[dt][s:s + n]
        else:
            # fragmented: span the largest runs first to minimize the
            # number of extents the allocation straddles
            parts, need = [], n
            for i in np.argsort(-lens):
                s, l = int(starts[i]), int(min(lens[i], need))
                parts.append(arr[s:s + l])
                need -= l
                if need == 0:
                    break
            take = np.concatenate(parts)
            taken = set(take.tolist())
            self._free[dt] = [f for f in self._free[dt] if f not in taken]
        self._allocated[dt].update(take.tolist())
        return np.asarray(take, np.int32)

    def free(self, dtype, frames) -> None:
        dt = self._dt(dtype)
        alloc = self._allocated[dt]
        returned = sorted({f for f in np.asarray(frames).tolist()
                           if f in alloc})
        if not returned:
            return
        alloc.difference_update(returned)
        if len(returned) == 1:       # common single-frame case: no re-sort
            bisect.insort(self._free[dt], returned[0])
        else:                        # one merge of two sorted lists
            self._free[dt] = sorted(self._free[dt] + returned)

    def num_allocated(self, dtype=None) -> int:
        if dtype is not None:
            return len(self._allocated.get(self._dt(dtype), ()))
        return sum(len(v) for v in self._allocated.values())

    def bytes_allocated(self) -> int:
        tot = 0
        for dt, alloc in self._allocated.items():
            tot += len(alloc) * self.page_elems * jnp.dtype(dt).itemsize
        return tot

    def bytes_reserved(self) -> int:
        return sum(a.shape[0] * self.page_elems * jnp.dtype(dt).itemsize
                   for dt, a in self._frames.items())

    # -- data plane ----------------------------------------------------------

    def write_pages(self, dtype, frames, pages) -> None:
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        if isinstance(pages, np.ndarray) and pages.dtype == self._frames[dt].dtype:
            self._frames[dt][idx] = pages      # host fast path: no copy/cast
        else:
            self._frames[dt][idx] = np.asarray(
                pages.astype(dt) if hasattr(pages, "astype") else pages)

    def write_rows(self, dtype, frames, slots, rows, row_elems: int) -> None:
        """In-place row update within pages: frames (B,), slots (B,),
        rows (B, row_elems). Used by the serving engine's token appends."""
        dt = self._dt(dtype)
        F = self._frames[dt].shape[0]
        view = self._frames[dt].reshape(F, -1, row_elems)
        view[np.asarray(frames, np.int32), np.asarray(slots, np.int32)] = \
            np.asarray(rows.astype(dt) if hasattr(rows, "astype") else rows)

    def read_pages(self, dtype, frames) -> jax.Array:
        """Gather frames -> (n, page_elems). The local-read data plane."""
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        return jnp.asarray(self._frames[dt][idx])

    def read_pages_host(self, dtype, frames) -> np.ndarray:
        """Gather frames -> (n, page_elems) as a HOST array (no device
        transfer).  This is what moves on the wire: the RNIC analogue DMAs
        physical frames, and the payload only becomes a device tensor at
        assembly time (``ensure_tensor``).  Fleet-scale replays fork tens of
        thousands of children; the paging fast path must not pay a device
        round trip per fault."""
        dt = self._dt(dtype)
        idx = np.asarray(frames, np.int32)
        return self._frames[dt][idx]

    def frames_array(self, dtype) -> jax.Array:
        """Expose raw physical frames (what the RNIC reads)."""
        return jnp.asarray(self._frames[self._dt(dtype)])
