"""Tensor <-> page packing."""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def num_pages(size: int, page_elems: int) -> int:
    return max(1, math.ceil(size / page_elems))


def to_pages(arr, page_elems: int):
    """Flatten + pad a tensor into (n_pages, page_elems).  Host arrays stay
    host arrays (packing is memory layout, not compute): container churn in
    fleet-scale replays boots thousands of instances from host pytrees, and
    a jax dispatch per leaf would dominate the boot cost."""
    if isinstance(arr, np.ndarray):
        flat = np.ravel(arr)
        n = num_pages(flat.size, page_elems)
        pad = n * page_elems - flat.size
        if pad:
            flat = np.pad(flat, (0, pad))
        return flat.reshape(n, page_elems)
    flat = jnp.ravel(arr)
    n = num_pages(flat.size, page_elems)
    pad = n * page_elems - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n, page_elems)


def from_pages(pages, shape, dtype):
    size = int(np.prod(shape)) if shape else 1
    return jnp.ravel(pages)[:size].reshape(shape).astype(dtype)
