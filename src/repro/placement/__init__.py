"""repro.placement — the seed placement/routing plane.

Sits between the fork control plane (:mod:`repro.fork`) and the pluggable
transports (:mod:`repro.net`): sharded multi-parent seeds
(:class:`ShardedSeed`), explicit per-VMA routes (:class:`RoutePlan` /
:class:`VMARoute`) chosen by a :class:`PlacementPolicy`
(:class:`SpreadPolicy`, :class:`HotColdPolicy`), and transport-/load-aware
node scheduling (:class:`RoundRobinScheduler`,
:class:`TransportAwareScheduler`).  See ``docs/placement.md``.
"""
from repro.placement.policy import (DEFAULT_COLD_PATTERN, HotColdPolicy,
                                    PlacementPolicy, SpreadPolicy)
from repro.placement.route import (ReplicaSource, Router, RoutePlan, VMAInfo,
                                   VMARoute, descriptor_vma_infos,
                                   route_demand)
from repro.placement.scheduler import (RoundRobinScheduler,
                                       TransportAwareScheduler)
from repro.placement.sharded import ShardedSeed

__all__ = [
    "DEFAULT_COLD_PATTERN",
    "HotColdPolicy",
    "PlacementPolicy",
    "ReplicaSource",
    "RoundRobinScheduler",
    "Router",
    "RoutePlan",
    "ShardedSeed",
    "SpreadPolicy",
    "TransportAwareScheduler",
    "VMAInfo",
    "VMARoute",
    "descriptor_vma_infos",
    "route_demand",
]
