"""ShardedSeed — one logical seed backed by S parent replicas.

MITOSIS forks 10k containers in a second only because no single machine
sits on the data path; a seed prepared on ONE parent still funnels every
child's first-touch reads through that parent's NIC.  A ``ShardedSeed``
wraps S :class:`~repro.fork.ForkHandle` replicas (each a fully
materialized copy of the seed, created over the ordinary fork path) and
routes every child's VMAs *across* the replica set per its placement
policy — fan-out read bandwidth scales with S instead of one NIC.

The sharded resume fetches one KB-sized descriptor per live replica (each
parent's own frame table), plans routes over the live set, assembles the
child address space VMA-by-VMA from the routed replica's page table, and
hands off to the same ``instantiate_child`` tail as a single-parent
resume.  A replica that died between planning and fetch is dropped and its
VMAs re-routed over the survivors (``lost_parents`` records the victims
for the coordinator's lease telemetry); the coordinator re-replicates back
to ``target_replicas`` during ``gc()``.

The handle-compatible surface (``parent_node``, ``lease_deadline``,
``expired`` / ``alive`` / ``remaining``, ``renew`` / ``revoke`` /
``reclaim``, ``resume_on``) lets the coordinator's seed store hold plain
handles and sharded seeds interchangeably.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import List, Optional, Sequence

import numpy as np

from repro.core.pagetable import VMA
from repro.fork.handle import ForkHandle, instantiate_child
from repro.fork.policy import ForkPolicy
from repro.fork.tree import build_fork_tree
from repro.net import (AccessRevoked, LeaseExpired, SeedUnavailable,
                       TransportError)
from repro.placement.policy import PlacementPolicy, SpreadPolicy
from repro.placement.route import ReplicaSource, Router


class ShardedSeed:
    """S fork-handle replicas behind one logical seed record."""

    def __init__(self, handles: Sequence[ForkHandle],
                 placement: Optional[PlacementPolicy] = None,
                 target_replicas: Optional[int] = None):
        if not handles:
            raise ValueError("a ShardedSeed needs at least one replica handle")
        self.handles: List[ForkHandle] = list(handles)
        self.placement = placement or SpreadPolicy()
        self.target_replicas = target_replicas or len(self.handles)
        # per-parent VMA routes served (fan-out balance introspection)
        self.serve_counts: Counter = Counter()
        # parents purged because they left the network (drained into the
        # coordinator's per-function lease telemetry as "parent_lost")
        self.lost_parents: List[str] = []
        self._rotation = 0

    # -- introspection -------------------------------------------------------

    @property
    def replicas(self) -> int:
        return len(self.handles)

    @property
    def parent_nodes(self) -> List[str]:
        return [h.parent_node for h in self.handles]

    def __repr__(self) -> str:
        return (f"ShardedSeed(replicas={self.replicas}/{self.target_replicas},"
                f" parents={self.parent_nodes})")

    # -- handle-compatible surface ------------------------------------------

    @property
    def parent_node(self) -> str:
        return self.handles[0].parent_node

    @property
    def handler_id(self) -> int:
        return self.handles[0].handler_id

    @property
    def lease_deadline(self) -> float:
        return min((h.lease_deadline for h in self.handles), default=math.inf)

    def remaining(self, now: Optional[float] = None) -> float:
        # a fully purged seed has nothing left to serve: report it expired
        return max((h.remaining(now) for h in self.handles),
                   default=-math.inf)

    @property
    def expired(self) -> bool:
        return all(h.expired for h in self.handles)

    @property
    def alive(self) -> bool:
        return any(h.alive for h in self.handles)

    def renew(self, extend: Optional[float] = None) -> "ShardedSeed":
        for h in self.handles:
            if h.alive:
                h.renew(extend)
        return self

    def revoke(self) -> "ShardedSeed":
        """Bump every replica's generation; this seed keeps serving through
        the refreshed handles."""
        self.handles = [h.revoke() if h.alive else h for h in self.handles]
        return self

    def reclaim(self, free_instance: bool = False) -> None:
        for h in self.handles:
            h.reclaim(free_instance=free_instance)

    def __enter__(self) -> "ShardedSeed":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.reclaim()

    # -- membership ----------------------------------------------------------

    def purge_lost(self, live_nodes) -> List[str]:
        """Drop replicas whose parent left the network; returns the lost
        parent ids (also appended to ``lost_parents`` for telemetry)."""
        lost = [h.parent_node for h in self.handles
                if h.parent_node not in live_nodes]
        if lost:
            self.handles = [h for h in self.handles
                            if h.parent_node in live_nodes]
            self.lost_parents.extend(lost)
        return lost

    def drain_lost(self) -> List[str]:
        lost, self.lost_parents = self.lost_parents, []
        return lost

    def add_replica(self, handle: ForkHandle) -> None:
        self.handles.append(handle)

    def live_handles(self) -> List[ForkHandle]:
        """Replicas that can still serve a fork right now."""
        return [h for h in self.handles if h.alive and not h.expired]

    # -- the sharded resume --------------------------------------------------

    def _live_descriptors(self, child_node, policy: ForkPolicy):
        """(handle, descriptor) per usable replica.  A parent that left the
        network is purged (and telemetered); one that refuses the fork
        (revoked/expired/reclaimed underneath us) is skipped for this
        resume but kept for the coordinator to sort out."""
        net = child_node.network
        pairs = []
        for h in list(self.handles):
            if h.parent_node not in net.nodes:
                self.handles.remove(h)
                self.lost_parents.append(h.parent_node)
                continue
            try:
                pairs.append((h, h.fetch_descriptor(child_node, policy)))
            except (TransportError, AccessRevoked, LeaseExpired,
                    PermissionError):
                continue
        return pairs

    def resume_on(self, child_node,
                  policy: Optional[ForkPolicy] = None) -> "object":
        """Fork a child whose VMAs page in from across the replica set.

        Each usable replica contributes its own descriptor (its frames, DC
        keys and prepared keys); the placement policy assigns every VMA an
        owner replica + transport, and the child's page tables are built
        from the routed replica's tables — so first-touch reads fan out
        over S parent NICs instead of one.
        """
        policy = ForkPolicy.coerce(policy)
        pairs = self._live_descriptors(child_node, policy)
        if not pairs:
            raise SeedUnavailable(
                f"sharded seed {self.parent_nodes or '[]'}: no live replicas")
        primary, desc = pairs[self._rotation % len(pairs)]
        by_parent = {h.parent_node: (h, d) for h, d in pairs}
        plan = self.placement.plan_for(desc, list(by_parent),
                                       offset=self._rotation)
        self._rotation += 1

        tables = {h.parent_node: {v["name"]: v for v in d.vmas}
                  for h, d in pairs}
        aspace = {}
        for vd in desc.vmas:
            route = plan[vd["name"]]
            owner, d = route.owner, by_parent[route.owner][1]
            vma = VMA.from_table_dict(tables[owner][vd["name"]])
            vma = vma.child_view(d.extra["prepared_keys"][vd["name"]],
                                 parent_node=owner,
                                 default_ancestry=d.ancestry)
            vma.transport = route.transport or vma.transport
            aspace[vma.name] = vma
            self.serve_counts[owner] += 1
        ancestry = [primary.parent_node] + list(desc.ancestry)
        inst = instantiate_child(child_node, policy, desc, aspace, ancestry)
        if policy.reroute_backlog is not None and len(pairs) > 1:
            # every replica's descriptor is already in hand: keep the
            # alternate frame tables + keys so the child's fault handler can
            # divert hop-1 reads off a hot (or lost) parent link
            inst.router = Router(child_node.network, plan,
                                 self._route_sources(pairs),
                                 threshold=policy.reroute_backlog,
                                 src=child_node.node_id)
        return inst

    @staticmethod
    def _route_sources(pairs):
        """vma name -> {replica parent -> ReplicaSource}: each replica's
        own frame table, prepared DC key and payload size for every VMA —
        the Router's re-route alternatives."""
        sources = {}
        for h, d in pairs:
            prepared = d.extra["prepared_keys"]
            for vd in d.vmas:
                nbytes = (int(np.prod(vd["shape"]))
                          * np.dtype(vd["dtype"]).itemsize)
                sources.setdefault(vd["name"], {})[h.parent_node] = \
                    ReplicaSource(
                        frames=np.frombuffer(vd["frames"], np.int32),
                        dc_key=prepared[vd["name"]], nbytes=nbytes)
        return sources

    def fan_out(self, nodes: Sequence, policy: Optional[ForkPolicy] = None,
                tree_degree: Optional[int] = None,
                child_lease: Optional[float] = None):
        """Fork one child per target node.

        ``tree_degree=None`` (default) keeps the flat fan-out: every child
        resumes straight off the replica set, each with its own rotated
        route plan.  With ``tree_degree=k`` the fan-out grows a §6.3 fork
        tree *under the seed's placement policy*: the sharded seed itself
        serves the first ``k × replicas`` children (its NIC budget is S
        parent links, not one), and when that frontier is exhausted the
        next short-lived re-seed is promoted from the child on the
        least-loaded side of the cluster — smallest live link backlog
        (``Network.link_backlog``), then smallest NIC-time ledger — instead
        of by raw descriptor-count order.  Returns a
        :class:`~repro.fork.tree.ForkTree` (flat mode returns the plain
        child list)."""
        if tree_degree is None:
            return [self.resume_on(n, policy) for n in nodes]

        def promote_least_loaded(promotable):
            # placement-aware promotion: re-seed on the least-loaded
            # replica's side of the cluster — smallest live link backlog,
            # then smallest NIC-time ledger, then BFS order
            net = promotable[0][0].node.network
            return min(range(len(promotable)), key=lambda j: (
                net.link_backlog(promotable[j][0].node.node_id),
                net.node_busy(promotable[j][0].node.node_id), j))

        return build_fork_tree(
            self, nodes, policy=policy, tree_degree=tree_degree,
            child_lease=child_lease,
            root_quota=tree_degree * max(1, len(self.live_handles())),
            promote=promote_least_loaded)
