"""Node schedulers — where a child lands (transport- and load-aware).

``Coordinator.pick_node`` used to be blind round-robin with a drifting
cursor: ``self._rr % len(ids)`` indexed the *filtered* (live, non-excluded)
list, so an exclusion or crash shifted every later pick and the cursor
could hand out the same node back-to-back.  The schedulers here fix that
and add the Swift-style cost dimension: connection setup (RC's 4 ms QP
connect amortizes very differently than DCT's piggybacked setup) and
per-channel backlog should decide where a fork lands.

* :class:`RoundRobinScheduler` — deterministic, exclusion-stable rotation
  over a stable node order; skipping a dead/excluded node never shifts the
  other nodes' turns.
* :class:`TransportAwareScheduler` — scores each candidate against the
  seed's route demand ((owner, transport) pairs) from OBSERVED pool
  state: ``Network.setup_owed`` prices exactly the establishment the
  next op would pay (0 for a warm slot — even a shared DCT context
  another sibling brought up — the backend's setup cost for a cold or
  LRU-evicted pair), and busy channels/links/control planes charge
  their backlogs.  Ties fall back to the round-robin order, so with no
  demand context it degrades to exactly the deterministic rotation.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.net import NoNodesAvailable


class RoundRobinScheduler:
    """Deterministic, exclusion-stable round-robin.

    The cursor walks a stable order (node ids in first-seen order, growing
    as nodes register); a pick scans from the cursor for the first live,
    non-excluded node and advances the cursor just past it.  Excluded or
    dead nodes are skipped *in place* — the mapping from cursor to node
    never re-indexes a filtered list, so the same node is only returned
    twice in a row when it is the sole eligible node.
    """

    def __init__(self):
        self._order: List[str] = []
        self._known = set()
        self._cursor = 0

    def _refresh(self, nodes: Dict[str, object]) -> None:
        for nid in nodes:
            if nid not in self._known:
                self._known.add(nid)
                self._order.append(nid)

    def _eligible(self, nodes: Dict[str, object],
                  exclude: Iterable[str]) -> List[Tuple[int, object]]:
        """(order index, node) in scan order starting at the cursor."""
        self._refresh(nodes)
        exclude = set(exclude)
        out = []
        n = len(self._order)
        for i in range(n):
            idx = (self._cursor + i) % n
            nid = self._order[idx]
            node = nodes.get(nid)
            if node is not None and node.alive and nid not in exclude:
                out.append((idx, node))
        return out

    def pick(self, nodes: Dict[str, object], exclude: Iterable[str] = (),
             demand: Optional[Sequence[tuple]] = None):
        ranked = self._eligible(nodes, exclude)
        if not ranked:
            raise NoNodesAvailable("no live nodes")
        idx, node = ranked[0]
        self._cursor = (idx + 1) % len(self._order)
        return node


class TransportAwareScheduler(RoundRobinScheduler):
    """Score candidates by what the seed's route plan would cost from
    there; fall back to the stable rotation when scores tie (or no demand
    context is given)."""

    def __init__(self, network):
        super().__init__()
        self.net = network

    def score(self, node_id: str, demand: Sequence[tuple]) -> float:
        """Cost of placing a child on ``node_id`` for the given
        (owner, transport) route demand: the establishment the pools say
        each route would actually owe (``Network.setup_owed`` — observed
        state, NOT a backend-constant estimate, so a candidate holding a
        warm shared DCT context beats a cold RC peer and an LRU-evicted
        pair is correctly priced as cold again), the current backlog of
        each (child, owner) channel, the link backlog of the candidate's
        own NIC, and its control-plane backlog (in-flight handshakes).
        (The OWNERS' link backlogs are deliberately not charged: every
        candidate queues on them equally, so they cannot discriminate a
        placement.)

        Connection setup is priced once per (src, dst, transport) —
        repeated demand entries for the same pair (a many-VMA plan routed
        to one owner, or ``None`` next to the spelled-out default
        backend) are deduped, and each (child, owner) channel is charged
        once, not once per transport riding it."""
        cost = self.net.link_backlog(node_id) + self.net.conn_backlog(node_id)
        seen_pairs = set()
        seen_owners = set()
        for owner, transport in demand:
            name = transport or self.net.transport
            if (owner, name) not in seen_pairs:
                seen_pairs.add((owner, name))
                cost += self.net.setup_owed(name, node_id, owner)
            if owner not in seen_owners:
                seen_owners.add(owner)
                cost += self.net.channel_backlog(node_id, owner)
        return cost

    def pick(self, nodes: Dict[str, object], exclude: Iterable[str] = (),
             demand: Optional[Sequence[tuple]] = None):
        ranked = self._eligible(nodes, exclude)
        if not ranked:
            raise NoNodesAvailable("no live nodes")
        if demand:
            # min() is stable: equal scores resolve to scan order, i.e. the
            # deterministic round-robin fallback
            idx, node = min(ranked,
                            key=lambda e: self.score(e[1].node_id, demand))
        else:
            idx, node = ranked[0]
        self._cursor = (idx + 1) % len(self._order)
        return node
