"""PlacementPolicy — who serves each VMA, and over which fabric.

Faasm's key observation is that per-region state policy (hot vs cold) is
what makes stateful serverless fast; MITOSIS's is that fan-out bandwidth
must not funnel through one parent NIC.  A placement policy owns both
decisions for one seed: given the VMAs of a descriptor and the live parent
replica set, it emits a :class:`~repro.placement.route.RoutePlan` naming,
per VMA, the replica that serves it and the transport the pages ride.

Built-ins:

* :class:`SpreadPolicy` — balance VMA bytes across the replica set (LPT
  greedy), one transport for everything.  The sharded-seed default.
* :class:`HotColdPolicy` — classify VMAs hot/cold by name pattern (cold:
  optimizer state, EMA shadows, ...), route hot VMAs over the fast fabric
  (``dct``/``tpu_ici``) and cold ones over the cheap one (``shared_fs``),
  spreading both classes across replicas.
"""
from __future__ import annotations

import re
from typing import List, Optional, Sequence

from repro.net import resolve_transport
from repro.placement.route import RoutePlan, VMAInfo, VMARoute

# optimizer / shadow state: read rarely, tolerates checkpoint-fabric latency
DEFAULT_COLD_PATTERN = r"(^|/)(opt|optimizer|adam|momentum|ema|shadow)(/|$)"


class PlacementPolicy:
    """Base: route every VMA to the first replica over the default
    transport (exactly the legacy single-parent behavior)."""

    def plan(self, vmas: Sequence[VMAInfo], replicas: Sequence[str],
             offset: int = 0) -> RoutePlan:
        if not replicas:
            raise ValueError("cannot place VMAs on an empty replica set")
        return RoutePlan(routes={v.name: VMARoute(owner=replicas[0])
                                 for v in vmas})

    def plan_for(self, desc, replicas: Sequence[str],
                 offset: int = 0) -> RoutePlan:
        """Plan from a descriptor's page tables (metadata only)."""
        from repro.placement.route import descriptor_vma_infos
        return self.plan(descriptor_vma_infos(desc), replicas, offset=offset)

    def transport_hints(self) -> List[Optional[str]]:
        """Transport names this policy may route over (None = default);
        used by schedulers to estimate setup costs before any descriptor
        exists."""
        return [None]


def _spread(vmas: Sequence[VMAInfo], replicas: Sequence[str],
            offset: int) -> dict:
    """LPT greedy: biggest VMA first onto the least-loaded replica, so
    per-replica serve bytes stay balanced.  ``offset`` rotates the replica
    order per child, spreading tie-broken assignments (and thus channel
    load) across the fleet deterministically."""
    order = [replicas[(i + offset) % len(replicas)]
             for i in range(len(replicas))]
    load = {r: 0 for r in order}
    owners = {}
    for v in sorted(vmas, key=lambda v: (-v.nbytes, v.name)):
        owner = min(order, key=lambda r: load[r])
        owners[v.name] = owner
        load[owner] += v.nbytes
    return owners


class SpreadPolicy(PlacementPolicy):
    """Balance VMA bytes across the replica set; single transport."""

    def __init__(self, transport: Optional[str] = None):
        if transport is not None:
            resolve_transport(transport)        # unknown name -> ValueError
        self.transport = transport

    def plan(self, vmas: Sequence[VMAInfo], replicas: Sequence[str],
             offset: int = 0) -> RoutePlan:
        if not replicas:
            raise ValueError("cannot place VMAs on an empty replica set")
        owners = _spread(vmas, replicas, offset)
        return RoutePlan(routes={
            v.name: VMARoute(owner=owners[v.name], transport=self.transport)
            for v in vmas})

    def transport_hints(self) -> List[Optional[str]]:
        return [self.transport]


class HotColdPolicy(PlacementPolicy):
    """Hot VMAs (weights) over the fast fabric, cold VMAs (optimizer /
    shadow state, matched by ``cold_pattern``) over the cheap one; both
    classes spread across the replica set by bytes."""

    def __init__(self, hot: Optional[str] = "dct",
                 cold: Optional[str] = "shared_fs",
                 cold_pattern: str = DEFAULT_COLD_PATTERN):
        for name in (hot, cold):
            if name is not None:
                resolve_transport(name)
        self.hot = hot
        self.cold = cold
        self._cold_re = re.compile(cold_pattern)

    def is_cold(self, name: str) -> bool:
        return self._cold_re.search(name) is not None

    def plan(self, vmas: Sequence[VMAInfo], replicas: Sequence[str],
             offset: int = 0) -> RoutePlan:
        if not replicas:
            raise ValueError("cannot place VMAs on an empty replica set")
        owners = _spread(vmas, replicas, offset)
        return RoutePlan(routes={
            v.name: VMARoute(owner=owners[v.name],
                             transport=self.cold if self.is_cold(v.name)
                             else self.hot)
            for v in vmas})

    def transport_hints(self) -> List[Optional[str]]:
        return [self.hot, self.cold]
