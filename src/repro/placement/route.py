"""RoutePlan — the explicit per-VMA route of one fork.

The implicit route of the single-parent design ("every VMA pages in from
``Descriptor.parent_node`` over the instance transport") becomes a
first-class object: one :class:`VMARoute` per VMA naming the owner node
that serves its pages and the transport it rides.  Placement policies
(:mod:`repro.placement.policy`) build plans; ``ForkHandle.resume_on`` /
``ShardedSeed.resume_on`` apply them by stamping each child VMA's route
fields (``VMA.ancestry`` / ``VMA.transport``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class VMAInfo:
    """What a placement policy sees of one VMA: name + payload size."""

    name: str
    nbytes: int


def descriptor_vma_infos(desc) -> List[VMAInfo]:
    """VMAInfo list for a descriptor's page tables (metadata only)."""
    return [VMAInfo(name=vd["name"],
                    nbytes=int(np.prod(vd["shape"]))
                    * np.dtype(vd["dtype"]).itemsize)
            for vd in desc.vmas]


@dataclasses.dataclass(frozen=True)
class VMARoute:
    """One VMA's route: the parent replica serving its pages and the
    transport name the reads ride (None = the policy/network default)."""

    owner: str
    transport: Optional[str] = None


@dataclasses.dataclass
class RoutePlan:
    """vma name -> VMARoute for one resume.  Serializable (descriptors and
    control-plane messages carry it as plain dicts)."""

    routes: Dict[str, VMARoute] = dataclasses.field(default_factory=dict)

    def __getitem__(self, name: str) -> VMARoute:
        return self.routes[name]

    def __contains__(self, name: str) -> bool:
        return name in self.routes

    def owners(self) -> List[str]:
        """Distinct owner nodes, in first-use order."""
        seen: Dict[str, None] = {}
        for r in self.routes.values():
            seen.setdefault(r.owner, None)
        return list(seen)

    def transports(self) -> List[Optional[str]]:
        """Distinct transport names (None = default), in first-use order."""
        seen: Dict[Optional[str], None] = {}
        for r in self.routes.values():
            seen.setdefault(r.transport, None)
        return list(seen)

    def by_owner(self) -> Dict[str, List[str]]:
        """owner -> [vma names] it serves under this plan."""
        out: Dict[str, List[str]] = {}
        for name, r in self.routes.items():
            out.setdefault(r.owner, []).append(name)
        return out

    def reroute(self, lost_owner: str, plan: "RoutePlan") -> int:
        """Replace every route through ``lost_owner`` with the matching
        route from ``plan``.  Serves both the degradation path (a replica
        died between planning and fetch) and the Router's hot-spot path (a
        live replica's link backlog crossed the policy threshold); a VMA
        the fallback plan has no entry for keeps its current route.
        Returns the number of VMAs re-routed."""
        moved = 0
        for name, r in list(self.routes.items()):
            if r.owner == lost_owner and name in plan.routes:
                self.routes[name] = plan.routes[name]
                moved += 1
        return moved

    def to_dict(self) -> Dict[str, dict]:
        return {n: {"owner": r.owner, "transport": r.transport}
                for n, r in self.routes.items()}

    @classmethod
    def from_dict(cls, d: Dict[str, dict]) -> "RoutePlan":
        return cls(routes={n: VMARoute(owner=r["owner"],
                                       transport=r.get("transport"))
                           for n, r in d.items()})


@dataclasses.dataclass(frozen=True)
class ReplicaSource:
    """One sibling replica's copy of one VMA: the frame table its pages
    live in, the DC key guarding them, and the payload size — everything a
    Router needs to serve the VMA from that replica instead."""

    frames: np.ndarray
    dc_key: int
    nbytes: int


class Router:
    """Dynamic hot-spot re-routing for one routed child (ROADMAP: live
    load triggering ``RoutePlan.reroute``, not just crash degradation).

    The fault handler and the async PrefetchEngine consult the Router
    (via ``ModelInstance._hop_groups``) before every hop-1 read.  When the
    planned owner's link backlog (``Network.link_backlog``) exceeds the
    policy threshold — or the owner left the network entirely — and a
    sibling replica holds the same bytes, the Router re-plans every VMA
    routed through that owner across the cooler replicas
    (``RoutePlan.reroute``) and re-stamps the faulting VMA's page table
    from the alternate's frame table.  Other re-routed VMAs re-stamp
    lazily on their next fault.  A re-route moves the SAME pages from a
    different NIC: sweeps stay byte-identical to the static plan, only
    their queueing differs.
    """

    def __init__(self, net, plan: "RoutePlan",
                 sources: Dict[str, Dict[str, ReplicaSource]],
                 threshold: float, src: Optional[str] = None):
        self.net = net
        self.plan = plan
        self.sources = sources
        self.threshold = threshold
        # the child node reads originate from: with it known, fallback
        # candidates are priced with the establishment the pools say a
        # (src, candidate) route would owe — a replica this child already
        # holds a warm connection to beats an equally-cool cold one
        self.src = src
        self.reroutes = 0           # VMAs moved off a hot/lost owner
        # owner -> (sim_time, backlog) of the last replan that moved
        # nothing: until the clock or the owner's backlog changes, the
        # alternates can only be the same or hotter, so retrying the
        # greedy fallback plan on every fault would be pure wasted work
        self._stay_put: Dict[str, tuple] = {}

    def _owner_backlog(self, owner: str) -> float:
        if owner not in self.net.nodes:
            return float("inf")     # crash degradation: infinitely hot
        return self.net.link_backlog(owner)

    def _usable(self, name: str, owner: str) -> bool:
        src = self.sources.get(name, {}).get(owner)
        return (src is not None and owner in self.net.nodes
                and self.net.target_valid(owner, src.dc_key))

    def _fallback_plan(self, hot: str) -> "RoutePlan":
        """Spread every VMA currently planned on ``hot`` across the cooler
        replicas, greedily loading the least-backlogged link first (wire
        seconds estimated from each VMA's bytes over its routed fabric).
        VMAs with no viable alternate are left out (they keep their
        route)."""
        backlog = self._owner_backlog(hot)
        load: Dict[str, float] = {}
        fallback = RoutePlan()
        pending = sorted(
            ((n, r) for n, r in self.plan.routes.items() if r.owner == hot),
            key=lambda e: -self.sources.get(e[0], {}).get(hot, _NO_SRC).nbytes)
        for name, route in pending:
            cands = [o for o in self.sources.get(name, {})
                     if o != hot and self._usable(name, o)]
            if not cands:
                continue
            for o in cands:
                if o not in load:
                    load[o] = self.net.link_backlog(o)
                    if self.src is not None:
                        # observed pool state: a cold candidate owes its
                        # connection setup before the first byte moves
                        load[o] += self.net.setup_owed(
                            route.transport or self.net.transport,
                            self.src, o)
            best = min(cands, key=lambda o: (load[o], o))
            # a VMA the hot owner can no longer serve at all (revoked key)
            # moves to ANY usable sibling, however loaded
            if load[best] >= backlog and self._usable(name, hot):
                continue            # everyone is at least as hot: stay put
            fallback.routes[name] = VMARoute(owner=best,
                                             transport=route.transport)
            t = self.net.transport_obj(route.transport)
            load[best] += self.sources[name][best].nbytes / t.bandwidth()
        return fallback

    def sync(self, vma) -> None:
        """Bring ``vma``'s stamped route up to date before a hop-1 read:
        re-route its planned owner if hot/lost, then re-point the page
        table at the routed replica's frames when the plan moved."""
        route = self.plan.routes.get(vma.name)
        if route is None or not vma.ancestry:
            return
        stale = vma.ancestry[0] != route.owner  # plan moved on an earlier
        #                                         fault; stamp lags behind
        backlog = self._owner_backlog(route.owner)
        if (backlog > self.threshold
                or (stale and not self._usable(vma.name, route.owner))):
            # the planned owner is hot, lost, or (if we are about to lazily
            # re-stamp onto it) no longer able to serve this VMA at all —
            # re-plan its whole share before resolving the read, unless an
            # identical attempt already came up empty
            state = (self.net.sim_time, backlog)
            if self._stay_put.get(route.owner) != state:
                moved = self.plan.reroute(route.owner,
                                          self._fallback_plan(route.owner))
                if moved:
                    self.reroutes += moved
                    self.net.meter["reroutes"] += moved
                    self._stay_put.pop(route.owner, None)
                else:
                    self._stay_put[route.owner] = state
            route = self.plan.routes[vma.name]
        if vma.ancestry[0] == route.owner:
            return                  # stamp already matches the plan
        if not self._usable(vma.name, route.owner):
            return                  # never re-stamp onto a dead/revoked
            #                         owner: keep serving from the stamp
        # the plan moved (here or on an earlier fault): re-stamp the still
        # remote hop-1 pages onto the new owner's frame table and key
        src = self.sources[vma.name][route.owner]
        remote = (vma.owner_hop == 1) & vma.missing_mask()
        vma.frames[remote] = src.frames[remote]
        vma.dc_keys[1] = src.dc_key
        vma.ancestry = [route.owner] + vma.ancestry[1:]
        vma.transport = route.transport or vma.transport


_NO_SRC = ReplicaSource(frames=None, dc_key=-1, nbytes=0)


def route_demand(owners: Iterable[str],
                 transports: Iterable[Optional[str]]) -> List[tuple]:
    """(owner, transport) pairs a scheduler scores a candidate child node
    against — the cross product of a seed's replica set and its policy's
    transport mix."""
    return [(o, t) for o in owners for t in transports]
