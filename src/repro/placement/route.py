"""RoutePlan — the explicit per-VMA route of one fork.

The implicit route of the single-parent design ("every VMA pages in from
``Descriptor.parent_node`` over the instance transport") becomes a
first-class object: one :class:`VMARoute` per VMA naming the owner node
that serves its pages and the transport it rides.  Placement policies
(:mod:`repro.placement.policy`) build plans; ``ForkHandle.resume_on`` /
``ShardedSeed.resume_on`` apply them by stamping each child VMA's route
fields (``VMA.ancestry`` / ``VMA.transport``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class VMAInfo:
    """What a placement policy sees of one VMA: name + payload size."""

    name: str
    nbytes: int


def descriptor_vma_infos(desc) -> List[VMAInfo]:
    """VMAInfo list for a descriptor's page tables (metadata only)."""
    return [VMAInfo(name=vd["name"],
                    nbytes=int(np.prod(vd["shape"]))
                    * np.dtype(vd["dtype"]).itemsize)
            for vd in desc.vmas]


@dataclasses.dataclass(frozen=True)
class VMARoute:
    """One VMA's route: the parent replica serving its pages and the
    transport name the reads ride (None = the policy/network default)."""

    owner: str
    transport: Optional[str] = None


@dataclasses.dataclass
class RoutePlan:
    """vma name -> VMARoute for one resume.  Serializable (descriptors and
    control-plane messages carry it as plain dicts)."""

    routes: Dict[str, VMARoute] = dataclasses.field(default_factory=dict)

    def __getitem__(self, name: str) -> VMARoute:
        return self.routes[name]

    def __contains__(self, name: str) -> bool:
        return name in self.routes

    def owners(self) -> List[str]:
        """Distinct owner nodes, in first-use order."""
        seen: Dict[str, None] = {}
        for r in self.routes.values():
            seen.setdefault(r.owner, None)
        return list(seen)

    def transports(self) -> List[Optional[str]]:
        """Distinct transport names (None = default), in first-use order."""
        seen: Dict[Optional[str], None] = {}
        for r in self.routes.values():
            seen.setdefault(r.transport, None)
        return list(seen)

    def by_owner(self) -> Dict[str, List[str]]:
        """owner -> [vma names] it serves under this plan."""
        out: Dict[str, List[str]] = {}
        for name, r in self.routes.items():
            out.setdefault(r.owner, []).append(name)
        return out

    def reroute(self, lost_owner: str, plan: "RoutePlan") -> None:
        """Replace every route through ``lost_owner`` with the matching
        route from ``plan`` (the degradation path: a replica died between
        planning and fetch)."""
        for name, r in list(self.routes.items()):
            if r.owner == lost_owner:
                self.routes[name] = plan.routes[name]

    def to_dict(self) -> Dict[str, dict]:
        return {n: {"owner": r.owner, "transport": r.transport}
                for n, r in self.routes.items()}

    @classmethod
    def from_dict(cls, d: Dict[str, dict]) -> "RoutePlan":
        return cls(routes={n: VMARoute(owner=r["owner"],
                                       transport=r.get("transport"))
                           for n, r in d.items()})


def route_demand(owners: Iterable[str],
                 transports: Iterable[Optional[str]]) -> List[tuple]:
    """(owner, transport) pairs a scheduler scores a candidate child node
    against — the cross product of a seed's replica set and its policy's
    transport mix."""
    return [(o, t) for o in owners for t in transports]
