"""Figure 18: optimization ladder — +lean executor (GL), +one-sided
descriptor fetch (FD), +DCT transport, +no-copy page mapping, +prefetch —
plus a transport sweep across every backend in the repro.net registry,
plus the connection control-plane ablation (Swift-style setup storms).

All transport selection happens purely by registry name through
``ForkPolicy(page_fetch=..., descriptor_fetch=...)``; the sweep doubles as
the CI metering smoke (``python -m benchmarks.fig18_ablation --smoke``):
a backend that moves bytes without charging its per-backend meter keys
fails the run.

The connection rows (``fig18.conn.*``) exercise the bounded QP pools
(``NetModel.conn_cap``), the RC-vs-DCT structural difference under a
1k-child cold fan-out, and the LRU eviction-churn regime; ``--smoke``
pins them into ``BENCH_fanout.json`` under the ``conn`` key (merged, so
fig14's sections survive) and fails unless throughput degrades
monotonically as the cap shrinks below the fan-out degree at equal
bytes, DCT beats cold RC, and setup-aware placement recovers most of
the RC gap.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (deploy_parent, make_cluster, merge_bench_json,
                               timed, touch_fraction)
from repro.fork import ForkPolicy
from repro.net import NetModel, Network, transport_names
from repro.placement import TransportAwareScheduler
from repro.platform.node import NodeRuntime

TOUCH = 0.6

# each rung: (label, page transport, descriptor transport, lazy, prefetch)
LADDER = [
    ("+GL",       "rc",  "rpc", False, 0),   # baseline derives from this rung
    ("+FD",       "rc",  "rc",  False, 0),   # descriptor goes one-sided
    ("+DCT",      "dct", "dct", False, 0),
    ("+nocopy",   "dct", "dct", True,  0),
    ("+prefetch", "dct", "dct", True,  1),
]


def _fork_exec(nodes, handle, *, page, dfetch, lazy, prefetch, touch=TOUCH):
    child = handle.resume_on(nodes[1], ForkPolicy(
        lazy=lazy, page_fetch=page, descriptor_fetch=dfetch,
        prefetch=prefetch))
    touch_fraction(child, touch, prefetch)
    return child


def _one_fork(fname, *, page, dfetch, lazy, prefetch, touch=TOUCH):
    net, nodes = make_cluster(2, transport="dct")
    parent = deploy_parent(nodes[0], fname)
    handle = nodes[0].prepare_fork(parent)
    t = timed(net, _fork_exec, nodes, handle, page=page, dfetch=dfetch,
              lazy=lazy, prefetch=prefetch, touch=touch)
    return net, t


def ladder_rows(fname: str):
    rows = []
    # baseline = the +GL rung's fork plus a cold "containerization" fixed
    # cost (paper: ~100 ms runC) that the lean executor pool removes —
    # derived from the SAME measured fork so the baseline->+GL delta is
    # exactly the modeled saving, immune to wall-clock noise between runs
    lean_cold_s = 0.100
    for label, page, dfetch, lazy, prefetch in LADDER:
        _, t = _one_fork(fname, page=page, dfetch=dfetch, lazy=lazy,
                         prefetch=prefetch)
        if label == "+GL":
            rows.append(dict(name=f"fig18.baseline.{fname}",
                             us_per_call=int((t.wall_s + lean_cold_s) * 1e6),
                             sim_us=int((t.sim_s + lean_cold_s) * 1e6)))
        rows.append(dict(name=f"fig18.{label}.{fname}",
                         us_per_call=int(t.wall_s * 1e6),
                         sim_us=int(t.sim_s * 1e6)))
    return rows


def sweep_rows(fname: str, touch: float = TOUCH):
    """Same fork protocol over every registered backend, selected by name.
    Asserts each backend meters its own bytes/ops PER PHASE (descriptor
    fetch, then paging) — the CI smoke check.  The cluster default (control
    plane) is always a *different* backend, so the swept backend's keys can
    only be charged by its own data path."""
    rows = []
    for tname in transport_names():
        control = "rc" if tname == "dct" else "dct"
        net, nodes = make_cluster(2, transport=control)
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        t0 = timed(net, handle.resume_on, nodes[1], ForkPolicy(
            lazy=True, page_fetch=tname, descriptor_fetch=tname, prefetch=1))
        desc_bytes = net.meter.get(f"{tname}.bytes", 0)
        assert desc_bytes > 0, \
            f"transport {tname!r} fetched a descriptor without metering bytes"
        t1 = timed(net, touch_fraction, t0.out, touch, 1)
        page_bytes = net.meter.get(f"{tname}.bytes", 0) - desc_bytes
        assert page_bytes > 0, \
            f"transport {tname!r} served pages without metering bytes"
        nops = net.meter.get(f"{tname}.ops", 0)
        assert nops > 1, f"transport {tname!r} moved data without metering ops"
        rows.append(dict(name=f"fig18.transport.{tname}.{fname}",
                         us_per_call=int((t0.wall_s + t1.wall_s) * 1e6),
                         sim_us=int((t0.sim_s + t1.sim_s) * 1e6),
                         bytes=desc_bytes + page_bytes, ops=nops))
    return rows


# ---------------------------------------------------------------------------
# connection control-plane ablation (fig18.conn.*)
# ---------------------------------------------------------------------------

CONN_PAGES = 16          # x 64 elems x float32 = 4 KiB per read


def _conn_cluster(cap: int, transport: str = "rc"):
    """One owner with a frame pool; children are pure initiator ids (the
    pool manager tracks their connection tables by node id, no runtime
    needed on the read side)."""
    net = Network(model=NetModel(conn_cap=cap), transport=transport)
    owner = NodeRuntime("owner", net, page_elems=64)
    key = net.create_dc_target("owner")
    return net, owner, key


def conn_cap_rows(caps=(0, 8, 6, 4, 2)):
    """Bounded-pool sweep: 8 children replay a reuse-distance ladder
    (2 passes over the first m children, m = 2, 4, 6, 8) against one
    owner over RC.  Bytes moved are identical for every cap; only the
    control plane differs — a cap at or above the fan-out degree pays 8
    setups total, and each step below it turns part of the ladder into
    an LRU churn regime (evict + re-establish), so sim time must degrade
    monotonically as the cap shrinks."""
    rows = []
    children = [f"child{i}" for i in range(8)]
    for cap in caps:
        net, owner, key = _conn_cluster(cap)
        frames = owner.pool.alloc("float32", CONN_PAGES)
        t0 = net.sim_time
        for m in (2, 4, 6, 8):
            for _ in range(2):
                for c in children[:m]:
                    net.read_pages(c, "owner", "float32", frames, key,
                                   transport="rc")
        rows.append(dict(
            name=f"fig18.conn.cap{cap}", cap=cap,
            sim_us=int(round((net.sim_time - t0) * 1e6)),
            bytes=net.meter["rc.bytes"],
            setups=net.meter["rc.setups"],
            evicted=net.meter["rc.conn_evicted"],
            reestablished=net.meter["rc.conn_reestablished"]))
    return rows


def conn_fanout_rows(n_children: int = 1000):
    """Cold 1k-child fan-out, equal bytes per variant:

    * ``dct`` — one shared DC initiator per child node, per-new-pair
      piggybacked handshake (cheap control plane);
    * ``rc``  — blind placement, one cold RC QP pair per child (the
      Swift setup storm: 1000 x rc_setup dominates);
    * ``rc_aware`` — same RC backend, but ``TransportAwareScheduler``
      places each child from OBSERVED pool state, so after the first
      child warms a QP every sibling packs onto it and the storm
      collapses to one setup."""
    rows = []
    for label, tname, aware in (("dct", "dct", False), ("rc", "rc", False),
                                ("rc_aware", "rc", True)):
        net, owner, key = _conn_cluster(0, transport=tname)
        frames = owner.pool.alloc("float32", CONN_PAGES)
        t0 = net.sim_time
        if aware:
            workers = {f"w{i}": NodeRuntime(f"w{i}", net, page_elems=64)
                       for i in range(n_children)}
            sched = TransportAwareScheduler(net)
            for _ in range(n_children):
                node = sched.pick(workers, demand=[("owner", tname)])
                net.read_pages(node.node_id, "owner", "float32", frames,
                               key, transport=tname)
        else:
            for i in range(n_children):
                net.read_pages(f"w{i}", "owner", "float32", frames, key,
                               transport=tname)
        rows.append(dict(
            name=f"fig18.conn.fanout.{label}",
            sim_us=int(round((net.sim_time - t0) * 1e6)),
            bytes=net.meter[f"{tname}.bytes"],
            setups=net.meter[f"{tname}.setups"]))
    return rows


def conn_summary():
    """The pinned ``conn`` section of BENCH_fanout.json (and the smoke
    gate's evidence): cap sweep + fan-out rows plus the derived claims."""
    cap_rows = conn_cap_rows()
    fan_rows = conn_fanout_rows()
    by = {r["name"]: r for r in cap_rows + fan_rows}
    bounded = [r for r in cap_rows if r["cap"] > 0]   # descending caps
    rc = by["fig18.conn.fanout.rc"]
    dct = by["fig18.conn.fanout.dct"]
    aware = by["fig18.conn.fanout.rc_aware"]
    return {
        "schema": "conn-ablation/v1",
        "rows": cap_rows + fan_rows,
        "cap_equal_bytes": len({r["bytes"] for r in cap_rows}) == 1,
        "cap_monotone": all(a["sim_us"] < b["sim_us"]
                            for a, b in zip(bounded, bounded[1:])),
        "cap_unbounded_matches_fanout_cap":
            by["fig18.conn.cap0"]["sim_us"] == by["fig18.conn.cap8"]["sim_us"],
        "churn": {"evicted": by["fig18.conn.cap2"]["evicted"],
                  "reestablished": by["fig18.conn.cap2"]["reestablished"]},
        "fanout_equal_bytes": len({r["bytes"] for r in fan_rows}) == 1,
        "dct_beats_rc": dct["sim_us"] < rc["sim_us"],
        "gap_recovered_pct": round(
            100.0 * (rc["sim_us"] - aware["sim_us"])
            / (rc["sim_us"] - dct["sim_us"]), 2),
        "aware_setups": aware["setups"],
    }


def run_conn(write_json=None):
    summary = conn_summary()
    if write_json:
        merge_bench_json(write_json, {"conn": summary})
    return summary


def run():
    rows = []
    for fname in ("json", "recognition"):
        rows.extend(ladder_rows(fname))
        rows.extend(sweep_rows(fname))
    rows.extend(run_conn()["rows"])
    return rows


def smoke(write_json=None):
    """Quick mode for CI: one small function, tiny touch fraction, every
    registered backend; fails loudly if any backend stops metering.  Also
    runs the connection ablation, pins it into ``write_json`` (merged),
    and gates on the issue's acceptance claims."""
    rows = sweep_rows("json", touch=0.2)
    for r in rows:
        print(f"{r['name']}: sim {r['sim_us']} us, "
              f"{r['bytes']} B / {r['ops']} ops")
    conn = run_conn(write_json)
    for r in conn["rows"]:
        print(f"{r['name']}: sim {r['sim_us']} us, {r['bytes']} B, "
              f"{r['setups']} setups")
    assert conn["cap_equal_bytes"] and conn["fanout_equal_bytes"], \
        "conn ablation rows must move identical bytes"
    assert conn["cap_monotone"], \
        "sim time must degrade monotonically as the pool cap shrinks " \
        "below the fan-out degree"
    assert conn["cap_unbounded_matches_fanout_cap"], \
        "a cap at the fan-out degree must behave like an unbounded pool"
    assert conn["churn"]["evicted"] > 0 and \
        conn["churn"]["reestablished"] > 0, \
        "the tight-cap row must show LRU eviction churn"
    assert conn["dct_beats_rc"], \
        "DCT must beat blind RC on a cold 1k-child fan-out"
    assert conn["gap_recovered_pct"] >= 90.0, \
        f"setup-aware placement recovered only " \
        f"{conn['gap_recovered_pct']}% of the RC gap"
    print(f"conn: gap_recovered {conn['gap_recovered_pct']}%, "
          f"churn {conn['churn']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick all-transport metering check (CI)")
    ap.add_argument("--json", default="BENCH_fanout.json",
                    help="tracked artifact to merge the conn section into")
    args = ap.parse_args()
    if args.smoke:
        smoke(write_json=args.json)
    else:
        from benchmarks.common import fmt_csv
        print(fmt_csv(run()))
