"""Figure 18: optimization ladder — +lean executor (GL), +one-sided
descriptor fetch (FD), +DCT transport, +no-copy page mapping, +prefetch —
plus a transport sweep across every backend in the repro.net registry.

All transport selection happens purely by registry name through
``ForkPolicy(page_fetch=..., descriptor_fetch=...)``; the sweep doubles as
the CI metering smoke (``python -m benchmarks.fig18_ablation --smoke``):
a backend that moves bytes without charging its per-backend meter keys
fails the run.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (deploy_parent, make_cluster, timed,
                               touch_fraction)
from repro.fork import ForkPolicy
from repro.net import transport_names

TOUCH = 0.6

# each rung: (label, page transport, descriptor transport, lazy, prefetch)
LADDER = [
    ("+GL",       "rc",  "rpc", False, 0),   # baseline derives from this rung
    ("+FD",       "rc",  "rc",  False, 0),   # descriptor goes one-sided
    ("+DCT",      "dct", "dct", False, 0),
    ("+nocopy",   "dct", "dct", True,  0),
    ("+prefetch", "dct", "dct", True,  1),
]


def _fork_exec(nodes, handle, *, page, dfetch, lazy, prefetch, touch=TOUCH):
    child = handle.resume_on(nodes[1], ForkPolicy(
        lazy=lazy, page_fetch=page, descriptor_fetch=dfetch,
        prefetch=prefetch))
    touch_fraction(child, touch, prefetch)
    return child


def _one_fork(fname, *, page, dfetch, lazy, prefetch, touch=TOUCH):
    net, nodes = make_cluster(2, transport="dct")
    parent = deploy_parent(nodes[0], fname)
    handle = nodes[0].prepare_fork(parent)
    t = timed(net, _fork_exec, nodes, handle, page=page, dfetch=dfetch,
              lazy=lazy, prefetch=prefetch, touch=touch)
    return net, t


def ladder_rows(fname: str):
    rows = []
    # baseline = the +GL rung's fork plus a cold "containerization" fixed
    # cost (paper: ~100 ms runC) that the lean executor pool removes —
    # derived from the SAME measured fork so the baseline->+GL delta is
    # exactly the modeled saving, immune to wall-clock noise between runs
    lean_cold_s = 0.100
    for label, page, dfetch, lazy, prefetch in LADDER:
        _, t = _one_fork(fname, page=page, dfetch=dfetch, lazy=lazy,
                         prefetch=prefetch)
        if label == "+GL":
            rows.append(dict(name=f"fig18.baseline.{fname}",
                             us_per_call=int((t.wall_s + lean_cold_s) * 1e6),
                             sim_us=int((t.sim_s + lean_cold_s) * 1e6)))
        rows.append(dict(name=f"fig18.{label}.{fname}",
                         us_per_call=int(t.wall_s * 1e6),
                         sim_us=int(t.sim_s * 1e6)))
    return rows


def sweep_rows(fname: str, touch: float = TOUCH):
    """Same fork protocol over every registered backend, selected by name.
    Asserts each backend meters its own bytes/ops PER PHASE (descriptor
    fetch, then paging) — the CI smoke check.  The cluster default (control
    plane) is always a *different* backend, so the swept backend's keys can
    only be charged by its own data path."""
    rows = []
    for tname in transport_names():
        control = "rc" if tname == "dct" else "dct"
        net, nodes = make_cluster(2, transport=control)
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        t0 = timed(net, handle.resume_on, nodes[1], ForkPolicy(
            lazy=True, page_fetch=tname, descriptor_fetch=tname, prefetch=1))
        desc_bytes = net.meter.get(f"{tname}.bytes", 0)
        assert desc_bytes > 0, \
            f"transport {tname!r} fetched a descriptor without metering bytes"
        t1 = timed(net, touch_fraction, t0.out, touch, 1)
        page_bytes = net.meter.get(f"{tname}.bytes", 0) - desc_bytes
        assert page_bytes > 0, \
            f"transport {tname!r} served pages without metering bytes"
        nops = net.meter.get(f"{tname}.ops", 0)
        assert nops > 1, f"transport {tname!r} moved data without metering ops"
        rows.append(dict(name=f"fig18.transport.{tname}.{fname}",
                         us_per_call=int((t0.wall_s + t1.wall_s) * 1e6),
                         sim_us=int((t0.sim_s + t1.sim_s) * 1e6),
                         bytes=desc_bytes + page_bytes, ops=nops))
    return rows


def run():
    rows = []
    for fname in ("json", "recognition"):
        rows.extend(ladder_rows(fname))
        rows.extend(sweep_rows(fname))
    return rows


def smoke():
    """Quick mode for CI: one small function, tiny touch fraction, every
    registered backend; fails loudly if any backend stops metering."""
    rows = sweep_rows("json", touch=0.2)
    for r in rows:
        print(f"{r['name']}: sim {r['sim_us']} us, "
              f"{r['bytes']} B / {r['ops']} ops")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick all-transport metering check (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        from benchmarks.common import fmt_csv
        print(fmt_csv(run()))
