"""Figure 18: optimization ladder — +lean executor (GL), +one-sided
descriptor fetch (FD), +DCT transport, +no-copy page mapping, +prefetch."""
from __future__ import annotations

import time

from benchmarks.common import (checkpoint_blob, deploy_parent, make_cluster,
                               restore_from_blob, timed, touch_fraction)
from repro.core.lean import LeanExecutorPool
from repro.fork import ForkPolicy

TOUCH = 0.6


def _fork_exec(net, nodes, handle, *, dfetch, lazy, prefetch):
    child = handle.resume_on(nodes[1], ForkPolicy(
        lazy=lazy, descriptor_fetch=dfetch, prefetch=prefetch))
    touch_fraction(child, TOUCH, prefetch)
    return child


def run():
    rows = []
    for fname in ("json", "recognition"):
        # baseline: cold "containerization" = compile-equivalent fixed cost
        # (paper: ~100 ms runC) + RPC descriptor + RC transport + eager copy
        lean_cold_s = 0.100

        net, nodes = make_cluster(2, transport="rc")
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        t0 = timed(net, _fork_exec, net, nodes, handle, dfetch="rpc",
                   lazy=False, prefetch=0)
        base = t0.wall_s + lean_cold_s
        rows.append(dict(name=f"fig18.baseline.{fname}",
                         us_per_call=int(base * 1e6),
                         sim_us=int((t0.sim_s + lean_cold_s) * 1e6)))

        # +GL: lean executor pool removes the fixed containerization cost
        rows.append(dict(name=f"fig18.+GL.{fname}",
                         us_per_call=int(t0.wall_s * 1e6),
                         sim_us=int(t0.sim_s * 1e6)))

        # +FD: descriptor over one-sided read instead of RPC
        net, nodes = make_cluster(2, transport="rc")
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        t1 = timed(net, _fork_exec, net, nodes, handle, dfetch="rdma",
                   lazy=False, prefetch=0)
        rows.append(dict(name=f"fig18.+FD.{fname}",
                         us_per_call=int(t1.wall_s * 1e6),
                         sim_us=int(t1.sim_s * 1e6)))

        # +DCT: connectionless transport (RC pays per-connection setup)
        net, nodes = make_cluster(2, transport="dct")
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        t2 = timed(net, _fork_exec, net, nodes, handle, dfetch="rdma",
                   lazy=False, prefetch=0)
        rows.append(dict(name=f"fig18.+DCT.{fname}",
                         us_per_call=int(t2.wall_s * 1e6),
                         sim_us=int(t2.sim_s * 1e6)))

        # +nocopy: map pages lazily instead of eager full copy
        net, nodes = make_cluster(2, transport="dct")
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        t3 = timed(net, _fork_exec, net, nodes, handle, dfetch="rdma",
                   lazy=True, prefetch=0)
        rows.append(dict(name=f"fig18.+nocopy.{fname}",
                         us_per_call=int(t3.wall_s * 1e6),
                         sim_us=int(t3.sim_s * 1e6)))

        # +prefetch
        net, nodes = make_cluster(2, transport="dct")
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        t4 = timed(net, _fork_exec, net, nodes, handle, dfetch="rdma",
                   lazy=True, prefetch=1)
        rows.append(dict(name=f"fig18.+prefetch.{fname}",
                         us_per_call=int(t4.wall_s * 1e6),
                         sim_us=int(t4.sim_s * 1e6)))
    return rows
