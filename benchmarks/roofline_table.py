"""Roofline summary rows from the dry-run artifacts (EXPERIMENTS §Roofline)."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run():
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        d = json.load(open(p))
        if d.get("status") != "ok" or d.get("tag"):
            continue
        r = d["roofline"]
        lb = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(dict(
            name=f"roofline.{d['arch']}.{d['shape']}.{d['mesh']}",
            us_per_call=int(lb * 1e6),
            compute_s=round(r["compute_s"], 4),
            memory_s=round(r["memory_s"], 4),
            collective_s=round(r["collective_s"], 4),
            dominant=r["dominant"],
            useful_ratio=round(d.get("useful_flops_ratio") or 0, 3),
            frac=round(d.get("roofline_fraction", 0), 5)))
    return rows
