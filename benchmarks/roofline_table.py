"""Roofline summaries.

Two halves:

* ``run()`` — roofline rows for the dry-run artifacts (EXPERIMENTS
  §Roofline): compute/memory/collective lower bounds per arch/mesh.
* ``paging_roofline()`` — the FAULT-PATH roofline: per extent-size bucket
  (run lengths 1..128 at fixed total pages), the modeled wire time
  (doorbell ops + bandwidth, NetModel constants) vs the modeled host copy
  time (per-extent overhead + copy bandwidth), which side bounds the
  bucket, and the measured achieved bandwidth of the fused run-coalesced
  gather vs the legacy per-page host loop at equal bytes.

``--smoke`` merges the ``paging_roofline`` section into
``BENCH_paging.json`` (pinned fields are deterministic: byte/op/model
numbers plus the huge-margin ``fused_beats_host`` boolean; achieved
bandwidths are printed but never pinned) and exits non-zero if the fused
path fails to beat the per-page host path.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys
import time

import numpy as np

from benchmarks.common import merge_bench_json
from repro.memory.pool import PagePool, frame_runs
from repro.net.model import NetModel

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

# -- paging roofline configuration ------------------------------------------
PAGE_ELEMS = 4096              # benchmark page size (16 KiB fp32)
DTYPE = "float32"
TOTAL_PAGES = 1024             # fixed per bucket: every bucket moves the
                               # same bytes, only the extent structure varies
RUN_LENS = (1, 2, 4, 8, 16, 32, 64, 128)
MAX_SGE = 16                   # SGEs per doorbell op (transport.DCT)
# modeled host copy ceiling: per-extent dispatch overhead + copy bandwidth.
# Fixed constants (not measured) so the tracked rows are deterministic;
# achieved bandwidth is printed alongside for the eyeball comparison.
MODEL_COPY_BW = 25e9           # B/s — DDR-class single-stream memcpy
MODEL_COPY_OVERHEAD = 2e-6     # s per extent — fault dispatch + copy setup
REPEATS = 3


def run():
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        d = json.load(open(p))
        if d.get("status") != "ok" or d.get("tag"):
            continue
        r = d["roofline"]
        lb = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(dict(
            name=f"roofline.{d['arch']}.{d['shape']}.{d['mesh']}",
            us_per_call=int(lb * 1e6),
            compute_s=round(r["compute_s"], 4),
            memory_s=round(r["memory_s"], 4),
            collective_s=round(r["collective_s"], 4),
            dominant=r["dominant"],
            useful_ratio=round(d.get("useful_flops_ratio") or 0, 3),
            frac=round(d.get("roofline_fraction", 0), 5)))
    return rows


# -- paging roofline --------------------------------------------------------

def _bucket_frames(run_len: int) -> np.ndarray:
    """TOTAL_PAGES frames in runs of ``run_len`` with one-frame gaps, so the
    extent structure per bucket is exact (sges == runs)."""
    runs = TOTAL_PAGES // run_len
    base = np.arange(runs, dtype=np.int64) * (run_len + 1)
    return (base[:, None] + np.arange(run_len)[None, :]).reshape(-1) \
        .astype(np.int32)


def _best_of(fn, reps: int = REPEATS) -> float:
    best = math.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def paging_roofline():
    """Returns (rows, summary).  Rows carry only deterministic fields;
    measured achieved bandwidths live in the (unpinned) summary."""
    model = NetModel()
    page_bytes = PAGE_ELEMS * np.dtype(DTYPE).itemsize
    nbytes = TOTAL_PAGES * page_bytes
    pool = PagePool(page_elems=PAGE_ELEMS, initial_frames=2 * TOTAL_PAGES)
    pool._ensure_capacity(DTYPE, 2 * TOTAL_PAGES)
    rng = np.random.default_rng(0)
    pool.write_pages(DTYPE, np.arange(2 * TOTAL_PAGES),
                     rng.standard_normal((2 * TOTAL_PAGES, PAGE_ELEMS))
                     .astype(DTYPE))

    rows, achieved = [], {}
    for run_len in RUN_LENS:
        frames = _bucket_frames(run_len)
        starts, lens = frame_runs(frames)
        runs = int(starts.size)
        ops = max(1, math.ceil(runs / MAX_SGE))
        wire_us = ops * model.rdma_lat * 1e6 + nbytes / model.rdma_bw * 1e6
        copy_us = (runs * MODEL_COPY_OVERHEAD * 1e6
                   + nbytes / MODEL_COPY_BW * 1e6)
        rows.append(dict(
            name=f"paging_roofline.run{run_len}",
            run_len=run_len, runs=runs, pages=TOTAL_PAGES, bytes=nbytes,
            sges=runs, ops=ops,
            wire_us=round(wire_us, 1), copy_us=round(copy_us, 1),
            bound="copy" if copy_us > wire_us else "wire"))
        t = _best_of(lambda: pool.read_pages_host(DTYPE, frames))
        achieved[run_len] = nbytes / t / 1e9

    # fused run-coalesced gather vs the legacy per-page host loop at equal
    # bytes (a representative mid bucket); the pinned boolean has a ~10x
    # wall-clock margin, everything else about the comparison is metered
    frames = _bucket_frames(16)
    t_fused = _best_of(lambda: pool.read_pages_host(DTYPE, frames))
    t0 = time.perf_counter()
    for p in frames.tolist():
        pool.read_pages_host(DTYPE, [p])
    t_host = time.perf_counter() - t0
    summary = {
        "pages": TOTAL_PAGES,
        "bytes": nbytes,
        "page_bytes": page_bytes,
        "model_copy_bw_gbps": MODEL_COPY_BW / 1e9,
        "model_copy_overhead_us": MODEL_COPY_OVERHEAD * 1e6,
        "equal_bytes": True,        # both sides gather the same frame list
        "fused_beats_host": bool(t_fused < t_host),
        # measured, NOT pinned (summary carries them for the console only)
        "_achieved_gbps": {str(k): round(v, 2) for k, v in achieved.items()},
        "_fused_us": int(t_fused * 1e6),
        "_host_loop_us": int(t_host * 1e6),
    }
    return rows, summary


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="merge the paging_roofline section into the BENCH "
                         "artifact and fail unless the fused gather beats "
                         "the per-page host loop at equal bytes")
    ap.add_argument("--json", default="BENCH_paging.json",
                    help="tracked artifact to merge the section into")
    args = ap.parse_args()
    rows, summary = paging_roofline()
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()),
              f"achieved_gbps={summary['_achieved_gbps'][str(r['run_len'])]}")
    print(f"fused {summary['_fused_us']}us vs per-page host loop "
          f"{summary['_host_loop_us']}us at {summary['bytes']} bytes "
          f"-> fused_beats_host={summary['fused_beats_host']}")
    tracked = {k: v for k, v in summary.items() if not k.startswith("_")}
    tracked["rows"] = rows
    merge_bench_json(args.json, {"paging_roofline": tracked})
    print(f"merged paging_roofline into {args.json}")
    if args.smoke:
        return 0 if summary["fused_beats_host"] else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
