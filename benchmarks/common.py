"""Shared benchmark harness.

Measures both REAL wall time of the implementation's operations and the
DERIVED time from the calibrated network model (repro.net.NetModel),
since this container's single CPU core is not representative of
RNIC/ICI-attached hosts.  Both columns are reported.
"""
from __future__ import annotations

import dataclasses
import io
import json
import pickle
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.instance import ModelInstance
from repro.net import Network
from repro.models import lm
from repro.platform.node import NodeRuntime
from repro.sim import SimClock

# the paper's function suite, mapped to instance sizes (see micro.py)
FUNCTIONS = {
    "hello": "micro-hello",
    "json": "micro-small",
    "image": "micro-medium",
    "recognition": "micro-large",
}

PAGE_ELEMS = 4096


def make_cluster(n_nodes: int = 4, cache: bool = False, transport="dct",
                 clock=None, pool_frames: int = 0):
    """Build a benchmark cluster.  ``clock="sim"`` wires every node's lease
    clock to the network's sim time (``repro.sim.SimClock``) so replay-driven
    renew/expiry/GC tick in simulated seconds; any other callable is passed
    through to the nodes.  ``pool_frames`` pre-reserves per-node frame
    capacity (lazily zeroed) so container churn never pays pool-growth
    copies.  Construction is O(n_nodes): per-pair channel and per-node lane
    state at the Network is created lazily on first traffic, so fleets of
    1000+ sim nodes build in linear time (tests/test_cluster_scale.py pins
    this)."""
    net = Network(transport=transport)
    if clock == "sim":
        clock = SimClock(net)
    extra = {} if clock is None else {"clock": clock}
    nodes = [NodeRuntime(f"node{i}", net, page_elems=PAGE_ELEMS,
                         cache_enabled=cache, pool_frames=pool_frames,
                         **extra) for i in range(n_nodes)]
    return net, nodes


_PARAMS_CACHE: Dict[str, dict] = {}


def params_for(fname: str):
    if fname not in _PARAMS_CACHE:
        cfg = get_arch(FUNCTIONS[fname])
        _PARAMS_CACHE[fname] = lm.init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS_CACHE[fname]


def deploy_parent(node, fname: str) -> ModelInstance:
    cfg = get_arch(FUNCTIONS[fname])
    inst = ModelInstance.create(node, cfg.name, params_for(fname))
    return inst


def touch_fraction(inst: ModelInstance, frac: float, prefetch: int = 0,
                   compute_s_per_page: float = 0.0, batch: bool = False):
    """Simulate a function touching `frac` of the parent's memory
    (the paper's synthetic micro-function).

    ``compute_s_per_page`` models the function actually *executing* on each
    touched page (charged via ``Network.advance``) — this is the time async
    prefetch overlaps transfers with.  ``batch=True`` touches each VMA's
    working set in ONE fault instead of a per-page loop, exercising the
    run-coalesced doorbell path."""
    net = inst.node.network
    for name in inst.leaf_names:
        vma = inst.aspace[name]
        n = max(1, int(round(vma.npages * frac)))
        if batch:
            inst.touch_pages(name, np.arange(n), prefetch=prefetch)
            net.advance(n * compute_s_per_page)
        else:
            for p in range(n):
                inst.touch_pages(name, [p], prefetch=prefetch)
                net.advance(compute_s_per_page)


@dataclasses.dataclass
class Timed:
    wall_s: float
    sim_s: float
    out: object = None


def timed(net: Network, fn: Callable, *args, **kw) -> Timed:
    s0 = net.sim_time
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return Timed(time.perf_counter() - t0, net.sim_time - s0, out)


def checkpoint_blob(inst: ModelInstance) -> bytes:
    """C/R baseline: serialize the FULL container state to a file blob."""
    buf = io.BytesIO()
    data = {n: np.asarray(inst.ensure_tensor(n)) for n in inst.leaf_names}
    pickle.dump(data, buf, protocol=4)
    return buf.getvalue()


def restore_from_blob(node, arch: str, blob: bytes) -> ModelInstance:
    data = pickle.loads(blob)
    tree = {k: jnp.asarray(v) for k, v in data.items()}
    return ModelInstance.create(node, arch, tree)


def merge_bench_json(path: str, updates: Dict[str, object]) -> dict:
    """Read-merge-write a tracked BENCH artifact.  Several benchmarks pin
    sections into one file (fig14 owns the fan-out sweeps, fig18 the
    connection ablation in ``BENCH_fanout.json``): each owns its own
    top-level keys and must preserve everyone else's — a whole-file dump
    from one benchmark would silently drop the others' pinned numbers."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        data = {}
    data.update(updates)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return data


def fmt_csv(rows: List[dict]) -> str:
    out = []
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        out.append(f"{name},{us},{derived}")
    return "\n".join(out)
