"""Figure 14: peak fork throughput + bottleneck analysis: what limits a
single seed — parent NIC bandwidth vs child CPU vs RPC handlers."""
from __future__ import annotations

from benchmarks.common import FUNCTIONS, deploy_parent, make_cluster, timed, touch_fraction
from repro.fork import ForkPolicy

TOUCH = 0.6
K = 6  # forks measured


def run():
    rows = []
    for fname in FUNCTIONS:
        net, nodes = make_cluster(3)
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        net.reset_meter()
        t = timed(net, lambda: [
            touch_fraction(handle.resume_on(nodes[1 + i % 2],
                                            ForkPolicy(prefetch=1)), TOUCH, 1)
            for i in range(K)])
        bytes_per_fork = net.meter["rdma_bytes"] / K
        # bottleneck model (paper §7.2): parent NIC serves rdma_bw
        nic_forks_per_s = net.model.rdma_bw / max(bytes_per_fork, 1)
        rpc_per_fork = net.meter["rpc_ops"] / K
        rpc_cap = 1.1e6 / max(rpc_per_fork, 1)      # paper: 1.1M rpc/s
        rows.append(dict(
            name=f"fig14.mitosis.{fname}",
            us_per_call=int(t.wall_s / K * 1e6),
            sim_us_per_fork=int(t.sim_s / K * 1e6),
            mb_per_fork=round(bytes_per_fork / 2**20, 1),
            nic_bound_forks_per_s=int(nic_forks_per_s),
            rpc_bound_forks_per_s=int(rpc_cap),
            bottleneck="nic" if nic_forks_per_s < rpc_cap else "rpc"))
    return rows
