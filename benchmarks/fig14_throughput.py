"""Figure 14 (extended): peak fork throughput — bottleneck analysis plus
the placement plane's sharded fan-out, link-contention, hot-spot reroute
and per-VMA routing sweeps.

* ``fig14.mitosis.*`` — the paper's bottleneck model: what limits a single
  seed (parent NIC bandwidth vs RPC handler capacity), now with the
  metered ``channel_wait_us`` stall column.
* ``fig14.sharded.s{S}`` — one logical seed backed by S parent replicas
  (``Coordinator.deploy_seed(replicas=S)``); K children route their VMAs
  across the replica set, so fan-out makespan is the *busiest parent's*
  NIC time (``Network.node_busy``) and children/sec scales with S at equal
  bytes moved.
* ``fig14.contention.s{S}.k{K}`` — the per-node link CLOCK
  (``NetModel.node_links``): K concurrent children gather async from the
  seed, and the makespan is the last busy parent link stamp in sim time
  itself.  A single parent's completion grows with K; S=4 sharding
  restores children/sec.
* ``fig14.reroute.*`` — load-triggered ``RoutePlan.reroute``: under a
  pre-heated parent NIC, a child with ``ForkPolicy(reroute_backlog=...)``
  diverts to the cooler replica and beats the static plan at byte-identical
  traffic.
* ``fig14.route.*`` — per-VMA transport routing: a mixed HotCold plan (hot
  weights over ``dct``, cold optimizer state over ``shared_fs``) against
  uniform single-transport baselines at equal working set.

``run(write_json=path)`` (and ``--smoke``) writes the sweeps to
``BENCH_fanout.json``; ``--smoke`` exits non-zero unless children/sec
strictly increases S=1 -> 2 -> 4 at equal page bytes, the single-parent
contention makespan grows with K while S=4 restores children/sec, the
reroute row beats the static-route row at equal bytes, AND the mixed
route plan beats the uniform ``shared_fs`` baseline on sim time.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from benchmarks.common import (FUNCTIONS, deploy_parent, make_cluster,
                               params_for, timed, touch_fraction)
from repro.core.prefetch import issue_fan_in
from repro.fork import ForkPolicy
from repro.placement import HotColdPolicy, SpreadPolicy
from repro.platform.coordinator import Coordinator, FunctionDef

TOUCH = 0.6
K = 6            # forks measured (bottleneck model)

SHARD_FN = "json"       # ~18 MB, 11 VMAs: spreads well, stays smoke-fast
SHARD_K = 8             # children per sharded fan-out
SHARD_S = (1, 2, 4)     # parent replica counts swept
COLD_FRAC_NAME = "opt"  # cold state prefix the HotCold policy matches

CONTENTION_K = (2, 4, 8)   # concurrent children per async fan-in
CONTENTION_S = (1, 4)      # one hot parent vs a sharded replica set
REROUTE_JUNK_PAGES = 8192  # pre-heat wire time on the hot parent's link
REROUTE_BACKLOG_S = 1e-4   # Router threshold for the reroute row


def run_bottleneck():
    """The original single-seed bottleneck rows (paper §7.2)."""
    rows = []
    for fname in FUNCTIONS:
        net, nodes = make_cluster(3)
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        net.reset_meter()
        t = timed(net, lambda: [
            touch_fraction(handle.resume_on(nodes[1 + i % 2],
                                            ForkPolicy(prefetch=1)), TOUCH, 1)
            for i in range(K)])
        bytes_per_fork = net.meter["rdma_bytes"] / K
        # bottleneck model (paper §7.2): parent NIC serves rdma_bw
        nic_forks_per_s = net.model.rdma_bw / max(bytes_per_fork, 1)
        rpc_per_fork = net.meter["rpc_ops"] / K
        rpc_cap = 1.1e6 / max(rpc_per_fork, 1)      # paper: 1.1M rpc/s
        rows.append(dict(
            name=f"fig14.mitosis.{fname}",
            us_per_call=int(t.wall_s / K * 1e6),
            sim_us_per_fork=int(t.sim_s / K * 1e6),
            mb_per_fork=round(bytes_per_fork / 2**20, 1),
            nic_bound_forks_per_s=int(nic_forks_per_s),
            rpc_bound_forks_per_s=int(rpc_cap),
            channel_wait_us=int(net.meter["channel_wait_s"] * 1e6),
            bottleneck="nic" if nic_forks_per_s < rpc_cap else "rpc"))
    return rows


def _sharded_coordinator(s: int):
    """Coordinator over S parent slots + SHARD_K child nodes; the sharded
    seed's replicas land on nodes[0..S-1] (deterministic round-robin)."""
    net, nodes = make_cluster(s + SHARD_K)
    coord = Coordinator(net, nodes)
    coord.register_function(FunctionDef(
        name="fn", arch=FUNCTIONS[SHARD_FN],
        make_params=lambda: params_for(SHARD_FN),
        behavior=lambda inst, ctx: {}))
    seed = coord.deploy_seed("fn", nodes[0], replicas=s,
                             placement=SpreadPolicy())
    return net, nodes, seed


def run_sharded():
    """children/sec vs replica count at equal bytes: the busiest parent's
    NIC time is the fan-out makespan, and sharding divides it."""
    rows = []
    policy = ForkPolicy(descriptor_fetch="rpc")
    for s in SHARD_S:
        net, nodes, seed = _sharded_coordinator(s)
        parents = list(seed.parent_nodes)
        net.reset_meter()

        def fan_out(s=s):
            children = [seed.resume_on(nodes[s + i], policy)
                        for i in range(SHARD_K)]
            for c in children:
                touch_fraction(c, TOUCH, 0, batch=True)
            return children
        t = timed(net, fan_out)
        # payload pages only: auth RPCs and descriptors scale with S, the
        # working set must not
        page_bytes = sum(c.stats["pages_rdma"] for c in t.out) \
            * nodes[0].pool.page_elems * 4
        makespan = max(net.node_busy(p) for p in parents)
        rows.append(dict(
            name=f"fig14.sharded.s{s}",
            us_per_call=int(t.wall_s * 1e6),
            replicas=s,
            children=SHARD_K,
            page_bytes=int(page_bytes),
            dct_bytes=int(net.meter["dct.bytes"]),
            busiest_parent_us=int(makespan * 1e6),
            children_per_s=int(SHARD_K / makespan)))
    return rows


def run_contention():
    """The link clock at work: K concurrent children async-gather their
    whole working set; makespan = last busy parent link stamp in sim_time.
    One parent's completion grows with K; S=4 restores children/sec."""
    rows = []
    policy = ForkPolicy(async_prefetch=4096, descriptor_fetch="rpc")
    for s in CONTENTION_S:
        for k in CONTENTION_K:
            net, nodes, seed = _sharded_coordinator(s)
            parents = [seed.parent_node] if s == 1 \
                else list(seed.parent_nodes)
            children = [seed.resume_on(nodes[s + i], policy)
                        for i in range(k)]
            t0, b0 = net.sim_time, net.meter["dct.bytes"]
            issue_fan_in(children)
            makespan = max(net.link_busy_until(p) for p in parents) - t0
            page_bytes = net.meter["dct.bytes"] - b0
            rows.append(dict(
                name=f"fig14.contention.s{s}.k{k}",
                replicas=s, children=k,
                page_bytes=int(page_bytes),
                bytes_per_child=int(page_bytes / k),
                makespan_us=int(makespan * 1e6),
                children_per_s=int(k / makespan)))
    return rows


def _heat_link(net, node, pages):
    """Backlog ``node``'s NIC organically: one large async read from a
    bystander rides the real charge path and occupies the link."""
    frames = node.pool.alloc("float32", pages)
    key = net.create_dc_target(node.node_id)
    net.read_pages("fig14-bystander", node.node_id, "float32", frames, key,
                   async_read=True)


def run_reroute():
    """Load-triggered RoutePlan.reroute vs the static plan under a hot
    parent NIC, at byte-identical traffic."""
    rows = {}
    for label, backlog in (("static", None), ("reroute", REROUTE_BACKLOG_S)):
        net, nodes, seed = _sharded_coordinator(2)
        child = seed.resume_on(nodes[2], ForkPolicy(
            descriptor_fetch="rpc", reroute_backlog=backlog))
        _heat_link(net, nodes[0], REROUTE_JUNK_PAGES)
        t0, b0 = net.sim_time, net.meter["dct.bytes"]
        t = timed(net, touch_fraction, child, 1.0, 0, 0.0, True)
        rows[label] = dict(
            name=f"fig14.reroute.{label}",
            us_per_call=int(t.wall_s * 1e6),
            sim_us=int(t.sim_s * 1e6),
            page_bytes=int(net.meter["dct.bytes"] - b0),
            channel_wait_us=int(net.meter["channel_wait_s"] * 1e6),
            reroutes=int(net.meter["reroutes"]))
    return rows


def _routed_parent(node):
    """A seed with hot weights AND cold optimizer state (same byte count
    as the weights), so hot/cold routing has something to split."""
    inst = deploy_parent(node, SHARD_FN)
    elems = sum(int(np.prod(inst.aspace[n].shape)) for n in inst.leaf_names)
    for shadow in ("m", "v"):
        inst.add_tensor(f"{COLD_FRAC_NAME}/{shadow}",
                        np.zeros(elems // 2, np.float32))
    return inst


def run_routing():
    """Mixed per-VMA transports vs uniform baselines at equal working set."""
    rows = {}
    cases = {
        "uniform_fs": dict(policy=ForkPolicy(page_fetch="shared_fs",
                                             descriptor_fetch="rpc")),
        "uniform_dct": dict(policy=ForkPolicy(descriptor_fetch="rpc")),
        "mixed": dict(policy=ForkPolicy(descriptor_fetch="rpc"),
                      placement=HotColdPolicy(hot="dct", cold="shared_fs")),
    }
    for label, kw in cases.items():
        net, nodes = make_cluster(2)
        parent = _routed_parent(nodes[0])
        handle = nodes[0].prepare_fork(parent)
        child = handle.resume_on(nodes[1], kw["policy"],
                                 placement=kw.get("placement"))
        net.reset_meter()
        t = timed(net, touch_fraction, child, 1.0, 0, 0.0, True)
        rows[label] = dict(
            name=f"fig14.route.{label}",
            us_per_call=int(t.wall_s * 1e6),
            sim_us=int(t.sim_s * 1e6),
            dct_bytes=int(net.meter["dct.bytes"]),
            dfs_bytes=int(net.meter["shared_fs.bytes"]),
            total_bytes=int(net.meter["dct.bytes"]
                            + net.meter["shared_fs.bytes"]))
    return rows


def run_sweeps(write_json=None):
    """Sharded + contention + reroute + routing sweeps;
    returns (rows, summary)."""
    sharded = run_sharded()
    contention = run_contention()
    reroute = run_reroute()
    routed = run_routing()
    rows = sharded + contention + list(reroute.values()) \
        + list(routed.values())
    by_s = {r["replicas"]: r for r in sharded}
    by_sk = {(r["replicas"], r["children"]): r for r in contention}
    single = [by_sk[(1, k)] for k in CONTENTION_K]
    kmax = CONTENTION_K[-1]
    summary = {
        # v3: BENCH_fanout.json gained fig18's "conn" section (connection
        # control-plane ablation); writers merge instead of overwrite
        "schema": "fanout-bench/v3",
        "rows": rows,
        "sharded": {
            "children": SHARD_K,
            "children_per_s": {f"s{s}": by_s[s]["children_per_s"]
                               for s in SHARD_S},
            "equal_bytes": len({by_s[s]["page_bytes"]
                                for s in SHARD_S}) == 1,
            "scaling": all(
                by_s[a]["children_per_s"] < by_s[b]["children_per_s"]
                for a, b in zip(SHARD_S, SHARD_S[1:])),
        },
        "contention": {
            "makespan_us": {f"s{r['replicas']}.k{r['children']}":
                            r["makespan_us"] for r in contention},
            # one parent NIC: completion grows with concurrent children
            "single_parent_grows": all(
                a["makespan_us"] < b["makespan_us"]
                for a, b in zip(single, single[1:])),
            # S=4 replicas: children/sec comes back at the full fan-in
            "sharding_restores": by_sk[(4, kmax)]["children_per_s"]
            > by_sk[(1, kmax)]["children_per_s"],
            "equal_bytes_per_child": len({r["bytes_per_child"]
                                          for r in contention}) == 1,
        },
        "reroute": {
            "static_sim_us": reroute["static"]["sim_us"],
            "reroute_sim_us": reroute["reroute"]["sim_us"],
            "reroutes": reroute["reroute"]["reroutes"],
            "equal_bytes": reroute["reroute"]["page_bytes"]
            == reroute["static"]["page_bytes"],
            "beats_static": reroute["reroute"]["sim_us"]
            < reroute["static"]["sim_us"],
            # the static plan's stall is metered, not absorbed
            "static_channel_wait_us": reroute["static"]["channel_wait_us"],
        },
        "routing": {
            "mixed_sim_us": routed["mixed"]["sim_us"],
            "uniform_fs_sim_us": routed["uniform_fs"]["sim_us"],
            "uniform_dct_sim_us": routed["uniform_dct"]["sim_us"],
            "equal_bytes": routed["mixed"]["total_bytes"]
            == routed["uniform_fs"]["total_bytes"],
            "mixed_beats_uniform": routed["mixed"]["sim_us"]
            < routed["uniform_fs"]["sim_us"],
            # what per-VMA routing buys the parent NIC vs uniform dct
            "mixed_dct_bytes": routed["mixed"]["dct_bytes"],
            "uniform_dct_bytes": routed["uniform_dct"]["dct_bytes"],
        },
    }
    if write_json:
        # wall time is machine noise — the tracked artifact keeps only the
        # deterministic sim/meter fields so diffs mean real regressions;
        # merge-write so fig18's pinned "conn" section survives
        from benchmarks.common import merge_bench_json
        tracked = dict(summary)
        tracked["rows"] = [{k: v for k, v in r.items() if k != "us_per_call"}
                           for r in rows]
        merge_bench_json(write_json, tracked)
    return rows, summary


def run(write_json=None):
    """Harness entry point (benchmarks/run.py): bottleneck + sweep rows."""
    return run_bottleneck() + run_sweeps(write_json=write_json)[0]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="write BENCH_fanout.json and fail unless sharded "
                         "fan-out scales with S and the mixed route plan "
                         "beats the uniform baseline")
    ap.add_argument("--json", default="BENCH_fanout.json",
                    help="output path for the fan-out summary")
    args = ap.parse_args()
    rows, s = run_sweeps(write_json=args.json)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"wrote {args.json}")
    if args.smoke:
        sh, rt = s["sharded"], s["routing"]
        ct, rr = s["contention"], s["reroute"]
        ok = sh["scaling"] and sh["equal_bytes"] \
            and ct["single_parent_grows"] and ct["sharding_restores"] \
            and ct["equal_bytes_per_child"] \
            and rr["beats_static"] and rr["equal_bytes"] \
            and rr["reroutes"] >= 1 \
            and rt["mixed_beats_uniform"] and rt["equal_bytes"]
        print(f"smoke: children/s {sh['children_per_s']} "
              f"(equal_bytes={sh['equal_bytes']}), contention "
              f"{ct['makespan_us']} (grows={ct['single_parent_grows']}, "
              f"restored={ct['sharding_restores']}), reroute "
              f"{rr['reroute_sim_us']}us vs static {rr['static_sim_us']}us "
              f"({rr['reroutes']} reroutes, equal_bytes={rr['equal_bytes']}),"
              f" mixed {rt['mixed_sim_us']}us vs uniform "
              f"{rt['uniform_fs_sim_us']}us -> {'OK' if ok else 'FAIL'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
