"""Figure 19: (a) state transfer between two functions (fork vs Fn/Redis
messaging vs C/R), (b) FINRA end-to-end vs #audit instances."""
from __future__ import annotations

import pickle
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import deploy_parent, make_cluster, timed
from repro.configs.base import get_arch
from repro.core.instance import ModelInstance
from repro.fork import ForkPolicy
from repro.models import lm
from repro.platform.coordinator import Coordinator, FunctionDef
from repro.platform.workflow import Workflow, WorkflowFunc, run_workflow


def run():
    rows = []
    # (a) transfer 1/8/64 MB between two functions
    for mb in (1, 8, 64):
        payload = np.random.default_rng(0).standard_normal(
            mb * 2**20 // 4).astype(np.float32)

        # fork path: upstream pre-materializes; downstream maps pages
        net, nodes = make_cluster(2)
        up = deploy_parent(nodes[0], "hello")
        up.add_tensor("globals/data", jnp.asarray(payload))
        handle = nodes[0].prepare_fork(up)
        t_fork = timed(net, lambda: np.asarray(
            handle.resume_on(nodes[1], ForkPolicy(prefetch=1))
            .ensure_tensor("globals/data")))
        np.testing.assert_allclose(t_fork.out, payload)

        # message path: serialize -> copy -> deserialize (Redis-style)
        t0 = time.perf_counter()
        blob = pickle.dumps(payload, protocol=4)
        redis_copy = 2 * len(blob) / net.model.rdma_bw + 27e-3  # via store
        got = pickle.loads(blob)
        t_msg_wall = time.perf_counter() - t0
        rows.append(dict(
            name=f"fig19a.transfer{mb}mb",
            us_per_call=int(t_fork.wall_s * 1e6),
            fork_sim_us=int(t_fork.sim_s * 1e6),
            msg_wall_us=int(t_msg_wall * 1e6),
            msg_sim_us=int((t_msg_wall + redis_copy) * 1e6),
            # calibrated-network comparison (serialize+store vs one-sided map)
            speedup=round((t_msg_wall + redis_copy) /
                          max(t_fork.sim_s, 1e-9), 1)))

    # (b) FINRA: fused fetch upstream, N audit children
    cfg = get_arch("micro-hello")
    params = lm.init_params(__import__("jax").random.PRNGKey(0), cfg)
    market = np.random.default_rng(1).standard_normal(6 * 2**20 // 4).astype(np.float32)

    def fetch(inst, ctx):
        inst.add_tensor("globals/market", jnp.asarray(market))
        return {"fetched": True}

    def audit(inst, ctx):
        if "msg:fetchData" in ctx:
            data = ctx["msg:fetchData"]["market"]
        else:
            data = np.asarray(inst.ensure_tensor("globals/market"))
        return {"violations": int((data > 3.0).sum())}

    def fetch_msg(inst, ctx):
        return {"market": market, "fetched": True}

    for n_rules in (2, 8):
        for transfer, fetch_fn in (("fork", fetch), ("message", fetch_msg)):
            net, nodes = make_cluster(4)
            coord = Coordinator(net, nodes)
            coord.register_function(FunctionDef("finra-fetch", cfg.name,
                                                lambda: params, fetch_fn))
            coord.register_function(FunctionDef("finra-audit", cfg.name,
                                                lambda: params, audit))
            wf = Workflow("finra")
            wf.add(WorkflowFunc("fetchData", "finra-fetch"))
            wf.add(WorkflowFunc("runAuditRule", "finra-audit",
                                fork_from="fetchData"))
            wf.edge("fetchData", "runAuditRule")
            t = timed(net, run_workflow, coord, wf, {}, transfer=transfer,
                      fan_out={"runAuditRule": n_rules})
            rows.append(dict(
                name=f"fig19b.finra.{transfer}.n{n_rules}",
                us_per_call=int(t.wall_s * 1e6),
                sim_us=int(t.sim_s * 1e6),
                msg_bytes=net.meter.get("msg_bytes", 0),
                rdma_bytes=net.meter.get("rdma_bytes", 0)))
    return rows
