"""Figure 12: prepare / startup / execution phase breakdown, per function,
MITOSIS vs CRIU-local vs CRIU-remote."""
from __future__ import annotations

from benchmarks.common import (FUNCTIONS, checkpoint_blob, deploy_parent,
                               make_cluster, restore_from_blob, timed,
                               touch_fraction)
from repro.fork import ForkPolicy

TOUCH = 0.6


def run():
    rows = []
    for fname in FUNCTIONS:
        net, nodes = make_cluster(3)
        parent = deploy_parent(nodes[0], fname)

        # MITOSIS
        tp = timed(net, nodes[0].prepare_fork, parent)
        handle = tp.out
        ts = timed(net, handle.resume_on, nodes[1], ForkPolicy(prefetch=1))
        te = timed(net, touch_fraction, ts.out, TOUCH, 1)
        rows.append(dict(
            name=f"fig12.mitosis.{fname}",
            us_per_call=int((tp.wall_s + ts.wall_s + te.wall_s) * 1e6),
            prepare_us=int(tp.wall_s * 1e6),
            startup_us=int(ts.wall_s * 1e6),
            exec_us=int(te.wall_s * 1e6),
            exec_sim_us=int(te.sim_s * 1e6),
            descriptor_kb=round(
                len(nodes[0].seeds[handle.handler_id].blob) / 1024, 1)))

        # CRIU-local: checkpoint + full file copy + restore
        tc = timed(net, checkpoint_blob, parent)
        copy_s = len(tc.out) / net.model.rdma_bw
        tr = timed(net, restore_from_blob, nodes[2], parent.arch, tc.out)
        rows.append(dict(
            name=f"fig12.criu_local.{fname}",
            us_per_call=int((tc.wall_s + copy_s + tr.wall_s) * 1e6),
            prepare_us=int(tc.wall_s * 1e6),
            startup_us=int((copy_s + tr.wall_s) * 1e6),
            exec_us=0, ckpt_mb=round(len(tc.out) / 2**20, 1)))

        # CRIU-remote: on-demand pages through a DFS (dfs_lat per fault)
        nfaults = sum(max(1, int(v.npages * TOUCH))
                      for v in parent.aspace.values())
        dfs_exec = nfaults * net.model.dfs_lat + \
            TOUCH * parent.total_bytes() / net.model.rdma_bw
        rows.append(dict(
            name=f"fig12.criu_remote.{fname}",
            us_per_call=int((tc.wall_s + dfs_exec) * 1e6),
            prepare_us=int(tc.wall_s * 1e6),
            exec_sim_us=int(dfs_exec * 1e6), faults=nfaults))
    return rows
