"""Figure 15: prefetch size ∈ {0,1,2,6} — execution time vs runtime memory."""
from __future__ import annotations

from benchmarks.common import deploy_parent, make_cluster, timed, touch_fraction

FN = "image"
TOUCH = 0.6


def run():
    rows = []
    for prefetch in (0, 1, 2, 6):
        net, nodes = make_cluster(2)
        parent = deploy_parent(nodes[0], FN)
        handle = nodes[0].prepare_fork(parent)
        child = handle.resume_on(nodes[1])
        net.reset_meter()
        t = timed(net, touch_fraction, child, TOUCH, prefetch)
        rows.append(dict(
            name=f"fig15.prefetch{prefetch}",
            us_per_call=int(t.wall_s * 1e6),
            sim_us=int(t.sim_s * 1e6),
            faults=child.stats["faults"],
            pages=child.stats["pages_rdma"],
            runtime_mb=round(child.resident_bytes() / 2**20, 2)))
    return rows
