"""Figure 15 (extended): the demand-paging fast path under prefetch.

Three sweeps over the same function working set:

* ``fig15.prefetch{N}``  — the paper's sweep: per-page faults with a
  synchronous prefetch window N ∈ {0,1,2,6} (execution vs runtime memory).
* ``fig15.scalar|batched`` — per-page fault loop vs ONE run-coalesced fault
  per VMA at equal bytes: what doorbell batching (SGE coalescing + extent
  allocation) is worth on the wire.
* ``fig15.sync{W}|async{W}`` — synchronous prefetch window W vs the async
  PrefetchEngine at the same W and equal bytes moved, with a per-page
  compute cost modeled via ``Network.advance`` — the transfer/execution
  overlap the engine exists for.  Async must be strictly faster.

``run(write_json=path)`` (and ``--smoke``) writes the sweep results to
``BENCH_paging.json`` so the paging perf trajectory is tracked per commit;
``--smoke`` exits non-zero if async fails to beat sync or bytes diverge.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import (deploy_parent, make_cluster, merge_bench_json,
                               timed, touch_fraction)

FN = "image"
TOUCH = 0.6
COMPUTE_S_PER_PAGE = 2e-6      # modeled per-page execution (overlap target)
OVERLAP_W = 8                  # window for the sync-vs-async comparison


def _fork_child(prefetch=0, async_prefetch=0):
    net, nodes = make_cluster(2)
    parent = deploy_parent(nodes[0], FN)
    handle = nodes[0].prepare_fork(parent)
    child = handle.resume_on(nodes[1], {"prefetch": prefetch,
                                        "async_prefetch": async_prefetch})
    net.reset_meter()
    return net, child


def _row(name, net, child, t):
    return dict(
        name=name,
        us_per_call=int(t.wall_s * 1e6),
        sim_us=int(t.sim_s * 1e6),
        faults=child.stats["faults"],
        pages=child.stats["pages_rdma"],
        ops=int(net.meter["dct.ops"]),
        sges=int(net.meter["dct.sges"]),
        bytes=int(net.meter["dct.bytes"]),
        runtime_mb=round(child.resident_bytes() / 2**20, 2))


def run_sweeps(write_json=None):
    """All three sweeps; returns (rows, summary)."""
    rows = []

    # -- sweep 1: the paper's prefetch ladder (per-page faults) -------------
    for prefetch in (0, 1, 2, 6):
        net, child = _fork_child()
        t = timed(net, touch_fraction, child, TOUCH, prefetch)
        rows.append(_row(f"fig15.prefetch{prefetch}", net, child, t))

    # -- sweep 2: scalar fault loop vs one batched fault per VMA ------------
    for batch in (False, True):
        net, child = _fork_child()
        t = timed(net, touch_fraction, child, TOUCH, 0, 0.0, batch)
        rows.append(_row("fig15.batched" if batch else "fig15.scalar",
                         net, child, t))

    # -- sweep 3: sync vs async prefetch at equal bytes, with compute -------
    # full touch so both sweeps move exactly the working set once
    sweep = {}
    for mode in ("sync", "async"):
        kw = ({"prefetch": OVERLAP_W} if mode == "sync"
              else {"async_prefetch": OVERLAP_W})
        net, child = _fork_child(**kw)
        t = timed(net, touch_fraction, child, 1.0, 0 if mode == "async"
                  else OVERLAP_W, COMPUTE_S_PER_PAGE)
        if child.prefetch_engine is not None:
            child.prefetch_engine.drain_all()
            t.sim_s = net.sim_time      # include landing the tail
        row = _row(f"fig15.{mode}{OVERLAP_W}", net, child, t)
        row["prefetch_used"] = child.stats["prefetch_used"]
        rows.append(row)
        sweep[mode] = row

    summary = {
        "schema": "paging-bench/v2",
        "rows": rows,
        "overlap": {
            "window": OVERLAP_W,
            "compute_s_per_page": COMPUTE_S_PER_PAGE,
            "sync_sim_us": sweep["sync"]["sim_us"],
            "async_sim_us": sweep["async"]["sim_us"],
            "sync_bytes": sweep["sync"]["bytes"],
            "async_bytes": sweep["async"]["bytes"],
            "async_beats_sync": sweep["async"]["sim_us"] < sweep["sync"]["sim_us"],
            "equal_bytes": sweep["async"]["bytes"] == sweep["sync"]["bytes"],
        },
        "doorbell": {
            "scalar_ops": next(r["ops"] for r in rows if r["name"] == "fig15.scalar"),
            "batched_ops": next(r["ops"] for r in rows if r["name"] == "fig15.batched"),
        },
    }
    if write_json:
        # wall time is machine noise — the tracked artifact keeps only the
        # deterministic sim/meter fields so diffs mean real regressions.
        # BENCH_paging.json is shared: fig16 owns "cow_fused" and the
        # roofline owns "paging_roofline", so merge our sections only.
        tracked = dict(summary)
        tracked["rows"] = [{k: v for k, v in r.items() if k != "us_per_call"}
                           for r in rows]
        merge_bench_json(write_json, tracked)
    return rows, summary


def run(write_json=None):
    """Harness entry point (benchmarks/run.py): returns the row list."""
    return run_sweeps(write_json=write_json)[0]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="write BENCH_paging.json and fail unless async "
                         "strictly beats sync at equal bytes")
    ap.add_argument("--json", default="BENCH_paging.json",
                    help="output path for the perf summary")
    args = ap.parse_args()
    rows, s = run_sweeps(write_json=args.json)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"wrote {args.json}")
    if args.smoke:
        ov, db = s["overlap"], s["doorbell"]
        ok = ov["async_beats_sync"] and ov["equal_bytes"] \
            and db["batched_ops"] < db["scalar_ops"]
        print(f"smoke: async {ov['async_sim_us']}us vs sync "
              f"{ov['sync_sim_us']}us, equal_bytes={ov['equal_bytes']}, "
              f"batched {db['batched_ops']} vs scalar {db['scalar_ops']} ops "
              f"-> {'OK' if ok else 'FAIL'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
