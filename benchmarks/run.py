"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. us_per_call is measured wall time
of the real implementation on this host; derived fields include the
RDMA/ICI-model projections (see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (fig12_phases, fig13_memory, fig14_throughput,
                        fig15_prefetch, fig16_cow, fig18_ablation,
                        fig19_state_transfer, fig20_spikes, roofline_table,
                        table1_startup)
from benchmarks.common import fmt_csv

MODULES = [
    ("table1", table1_startup),
    ("fig12", fig12_phases),
    ("fig13", fig13_memory),
    ("fig14", fig14_throughput),
    ("fig15", fig15_prefetch),
    ("fig16_17", fig16_cow),
    ("fig18", fig18_ablation),
    ("fig19", fig19_state_transfer),
    ("fig20", fig20_spikes),
    ("roofline", roofline_table),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for name, mod in MODULES:
        if only and name not in only:
            continue
        try:
            rows = mod.run()
            print(fmt_csv(rows), flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
