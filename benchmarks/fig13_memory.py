"""Figure 13: per-function memory — provisioned (before running, hatched)
vs runtime (colored), per technique, amortized per machine (m=4)."""
from __future__ import annotations

from benchmarks.common import FUNCTIONS, deploy_parent, make_cluster, touch_fraction
from repro.fork import ForkPolicy

TOUCH = 0.6
M = 4  # machines


def run():
    rows = []
    for fname in FUNCTIONS:
        # Caching: one cached instance per machine (O(n)>=O(m))
        net, nodes = make_cluster(M)
        for nd in nodes:
            deploy_parent(nd, fname)
        caching_prov = sum(nd.memory_bytes() for nd in nodes) / M

        # MITOSIS: ONE seed across the cluster
        net, nodes = make_cluster(M)
        parent = deploy_parent(nodes[0], fname)
        handle = nodes[0].prepare_fork(parent)
        mit_prov = sum(nd.memory_bytes() for nd in nodes) / M
        kids = [handle.resume_on(nd, ForkPolicy(prefetch=1))
                for nd in nodes[1:]]
        for k in kids:
            touch_fraction(k, TOUCH, 1)
        mit_runtime = sum(nd.memory_bytes() for nd in nodes) / M - mit_prov

        # C/R: provisioned = checkpoint file bytes / m; runtime = full restore
        ckpt_prov = parent.total_bytes() / M
        cr_runtime = parent.total_bytes()

        rows.append(dict(name=f"fig13.caching.{fname}",
                         us_per_call="",
                         provisioned_mb=round(caching_prov / 2**20, 2),
                         runtime_mb=0.0))
        rows.append(dict(name=f"fig13.mitosis.{fname}",
                         us_per_call="",
                         provisioned_mb=round(mit_prov / 2**20, 2),
                         runtime_mb=round(mit_runtime / 2**20, 2)))
        rows.append(dict(name=f"fig13.criu.{fname}",
                         us_per_call="",
                         provisioned_mb=round(ckpt_prov / 2**20, 2),
                         runtime_mb=round(cr_runtime / 2**20, 2)))
    return rows
