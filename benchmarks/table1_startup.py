"""Table 1: startup technique comparison — local/remote latency and
provisioned resources for n concurrent invocations on m machines."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (checkpoint_blob, deploy_parent, make_cluster,
                               params_for, restore_from_blob, timed,
                               touch_fraction)
from repro.fork import ForkPolicy

FN = "json"
TOUCH = 0.6


def run():
    rows = []
    net, nodes = make_cluster(3)
    parent = deploy_parent(nodes[0], FN)
    state_b = parent.total_bytes()
    handle = nodes[0].prepare_fork(parent)

    # --- coldstart (local image): build params + instance from scratch
    t = timed(net, lambda: deploy_parent(nodes[1], FN))
    cold_local = t.wall_s
    # remote image adds pulling the image over the wire (derived)
    cold_remote = cold_local + state_b / net.model.disk_bw + 64e-3

    # --- caching: unpause a cached instance
    cached = deploy_parent(nodes[1], FN)
    t = timed(net, lambda: cached)          # pop from pool: O(us)
    cache_lat = 5e-4

    # --- local fork
    t = timed(net, lambda: handle.resume_on(nodes[0]))
    lf = t
    touch_t = timed(net, touch_fraction, lf.out, TOUCH)

    # --- C/R (remote): checkpoint -> copy -> restore
    tc = timed(net, checkpoint_blob, parent)
    blob = tc.out
    copy_sim = len(blob) / net.model.rdma_bw
    tr = timed(net, restore_from_blob, nodes[2], parent.arch, blob)

    # --- MITOSIS remote fork
    tm = timed(net, lambda: handle.resume_on(nodes[2], ForkPolicy(prefetch=1)))
    child = tm.out
    tmt = timed(net, touch_fraction, child, TOUCH, 1)

    rows.append(dict(name="table1.coldstart", us_per_call=int(cold_local * 1e6),
                     remote_us=int(cold_remote * 1e6), provisioned="O(1)"))
    rows.append(dict(name="table1.caching", us_per_call=int(cache_lat * 1e6),
                     remote_us="n/a", provisioned="O(n)"))
    rows.append(dict(name="table1.fork_local",
                     us_per_call=int(lf.wall_s * 1e6),
                     sim_us=int(lf.sim_s * 1e6), provisioned="O(m)"))
    rows.append(dict(name="table1.checkpoint_restore",
                     us_per_call=int((tc.wall_s + copy_sim + tr.wall_s) * 1e6),
                     ckpt_us=int(tc.wall_s * 1e6),
                     copy_us=int(copy_sim * 1e6),
                     restore_us=int(tr.wall_s * 1e6), provisioned="O(1)"))
    rows.append(dict(name="table1.mitosis_remote_fork",
                     us_per_call=int(tm.wall_s * 1e6),
                     sim_us=int((tm.sim_s + tmt.sim_s) * 1e6),
                     exec_touch_us=int(tmt.wall_s * 1e6), provisioned="O(1)",
                     state_bytes=state_b,
                     descriptor_bytes=len(nodes[0].seeds[handle.handler_id].blob)))
    return rows
