"""Figure 22: fault-plane replay — the spike trace under injected crashes,
NIC flaps and per-op timeouts, through the full recovery chain.

MITOSIS §6.2's deployability claim is that remote fork *survives* failure:
leases bound orphaned children and a child whose parent dies falls back
instead of hanging on a dead RDMA peer.  This benchmark makes that claim a
pinned number.  Every row replays the fig20 spike trace (smaller scale)
under ``ForkOnDemand(replicas=2)`` with a :class:`~repro.sim.FaultPlan`:

* ``baseline``  — no fault plane at all;
* ``zero``      — a LIVE injector with an all-zero plan: its full summary
  digest must be bit-identical to ``baseline`` (the fault plane is free
  when nothing is planned);
* ``crash`` / ``flap`` — a targeted fault on a seed parent inside the
  burst minute, guaranteeing mid-execution failures so the recovery chain
  (sibling re-route -> coordinator re-seed -> graceful coldstart) runs and
  moves bytes;
* ``crash_sweep`` / ``storm`` — seeded random plans (crash-rate and
  flap-rate sweeps, plus op timeouts) over the whole cluster.

The replayed function is *phased*: its handler touches half its working
set at start and the rest mid-execution (``exec_s`` later), the demand-
paging-over-execution pattern that makes a parent loss observable at all —
a handler that pages everything at t0 can never be caught mid-read.

Gates (``--smoke``): the zero row is digest-identical to baseline; every
faulted row completes >= 99% of invocations; the targeted rows move
recovery bytes; a repeated storm replay is byte-identical; the storm row
replayed under SimSan (``repro.analysis``: every runtime invariant check
armed) raises nothing and reproduces the same summary; and no row
exceeds the wall budget.  ``run(write_json=...)`` pins the summary into
``BENCH_faults.json`` (merge-written, see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import gc
import sys
import time

import numpy as np

from benchmarks.common import merge_bench_json
from repro.net.model import NetModel
from repro.sim import (Crash, FaultPlan, Flap, ForkOnDemand, ReplayEngine,
                       SimFunction, build_cluster, spike_660323)

FN = "spike"
SEED = 20260809
SCALE = 8                 # 201 x 8 = 1608 invocations
N_NODES = 32
PAGE_ELEMS = 1024         # 4 KiB sim pages
STATE_BYTES = 64 * PAGE_ELEMS * 4   # 64 pages / container across 2 VMAs
TOUCH = 0.5
EXEC_S = 0.5              # long enough that faults land mid-execution
HOLD_S = 60.0
REPLICAS = 2
N_LINKS = 8               # concurrent wire transfers per NIC: the phased
#                           handlers' mid-execution reads reserve lane time
#                           in the future, and a single-lane NIC cannot
#                           backfill the idle gap they leave behind — at the
#                           burst's arrival rate that compounds into hundreds
#                           of seconds of spurious backlog
ROW_WALL_BUDGET_S = 120.0  # per-row wall ceiling enforced by --smoke
# the burst minute of SPIKE_660323 (index 5): targeted faults land here,
# and the deterministic round-robin deploy places seed replicas on n0/n1
BURST_T = 300.0
SEED_NODE = "n0"


@dataclasses.dataclass(frozen=True)
class PhasedFunction(SimFunction):
    """A SimFunction whose handler pages in across its execution: half the
    working set at start, the rest ``exec_s`` later — so a parent lost
    mid-run leaves the child with unread remote pages to recover."""

    def behavior(self, inst, inputs):
        for name, vma in inst.aspace.items():
            n = max(1, int(round(vma.npages * self.touch_frac)))
            inst.fetch_pages(name, np.arange(n // 2))
            inst.node.network.advance(self.exec_s / 2)
            inst.fetch_pages(name, np.arange(n // 2, n))
        return {}


def _function() -> PhasedFunction:
    return PhasedFunction(FN, state_bytes=STATE_BYTES, vmas=2,
                          touch_frac=TOUCH, exec_s=EXEC_S, hold_s=HOLD_S)


def _node_ids(n: int = N_NODES):
    return [f"n{i}" for i in range(n)]


def _plans(duration_s: float):
    """label -> FaultPlan (None = no fault plane installed at all)."""
    ids = _node_ids()
    return {
        "baseline": None,
        # live injector, nothing planned: must not perturb one bit
        "zero": FaultPlan.random(SEED, ids, duration_s, crash_rate=0.0),
        # targeted: a seed parent dies / flaps inside the burst, while
        # children forked from it are mid-execution
        "crash": FaultPlan(seed=1, crashes=(Crash(BURST_T + 25.0, SEED_NODE),),
                           op_fail_rate=0.02),
        "flap": FaultPlan(seed=2, flaps=(Flap(BURST_T + 20.0, BURST_T + 25.0,
                                              SEED_NODE),),
                          op_fail_rate=0.02),
        # seeded random sweeps over the whole cluster
        "crash_sweep": FaultPlan.random(SEED + 1, ids, duration_s,
                                        crash_rate=0.15, op_fail_rate=0.05),
        "storm": FaultPlan.random(SEED + 2, ids, duration_s, crash_rate=0.1,
                                  flap_rate=0.2, degrade_rate=0.1,
                                  op_fail_rate=0.05),
    }


def replay_once(plan, scale: int = SCALE, n_nodes: int = N_NODES,
                seed: int = SEED, sanitize=None):
    """One fault-plane replay -> (deterministic summary, wall seconds).
    ``sanitize=True`` runs the cluster under SimSan (repro.analysis) —
    the sanitizer only reads, so the summary must be byte-identical."""
    trace = spike_660323(scale=scale)
    net, nodes = build_cluster(n_nodes, model=NetModel(node_links=N_LINKS),
                               page_elems=PAGE_ELEMS, sanitize=sanitize)
    eng = ReplayEngine(trace, ForkOnDemand(replicas=REPLICAS, prefetch=0),
                       [_function()], network=net, nodes=nodes, seed=seed,
                       reroute_backlog=0.05, faults=plan)
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    summary = res.summary()
    del eng, res, trace
    gc.collect()
    return summary, wall


def run_sweeps(write_json=None, scale: int = SCALE, n_nodes: int = N_NODES,
               seed: int = SEED):
    duration = spike_660323(scale=scale).duration_s
    plans = _plans(duration)
    rows, reps, walls = [], {}, {}
    for label, plan in plans.items():
        s, wall = replay_once(plan, scale=scale, n_nodes=n_nodes, seed=seed)
        reps[label], walls[label] = s, wall
        f = s.get("faults") or {}
        rec = f.get("recovery") or {}
        rows.append(dict(
            name=f"fig22.{label}",
            wall_s=round(wall, 3),
            invocations=s["invocations"],
            forks=s["decisions"].get("fork", 0),
            colds=s["decisions"].get("cold", 0),
            degraded=s["decisions"].get("degraded", 0),
            failed=s["decisions"].get("failed", 0),
            completion_rate=f.get("completion_rate", 1.0),
            p99_us=s["latency"]["all"]["p99_us"],
            crashes=f.get("crashes_fired", 0),
            timeouts=f.get("timeouts", 0),
            retries=f.get("retries", 0),
            recovery_pages=rec.get("pages", 0),
            recovery_bytes=rec.get("bytes", 0),
            reseeds=rec.get("reseed", 0),
            digest=s["event_log_digest"][:12]))
    # determinism witness: the storm plan replayed twice must match exactly
    d2, _ = replay_once(plans["storm"], scale=scale, n_nodes=n_nodes,
                        seed=seed)
    # SimSan witness: the storm row replayed with every runtime invariant
    # check armed (lane/channel monotonicity, meter and payload
    # conservation, conn-pool consistency, lease edges) must raise nothing
    # AND reproduce the exact summary — the sanitizer observes, it never
    # perturbs the clock or the meters
    dsan, _ = replay_once(plans["storm"], scale=scale, n_nodes=n_nodes,
                          seed=seed, sanitize=True)
    faulted = [l for l in plans if plans[l] is not None
               and not plans[l].empty()]
    targeted_bytes = sum(
        (reps[l]["faults"]["recovery"]["bytes"]) for l in ("crash", "flap"))
    summary = {
        "schema": "faults-bench/v1",
        "rows": rows,
        "seed": seed,
        "nodes": n_nodes,
        "invocations": reps["baseline"]["invocations"],
        "replicas": REPLICAS,
        # the three smoke gates
        "zero_plan_identical": reps["zero"] == reps["baseline"],
        "completion": {l: reps[l]["faults"]["completion_rate"]
                       for l in faulted},
        "completion_gate": all(reps[l]["faults"]["completion_rate"] >= 0.99
                               for l in faulted),
        "recovery_bytes_targeted": targeted_bytes,
        "recovery_gate": targeted_bytes > 0,
        "deterministic": d2 == reps["storm"],
        "simsan_storm_identical": dsan == reps["storm"],
        "event_log_digest": {l: reps[l]["event_log_digest"] for l in plans},
        "lease": {l: reps[l]["lease"] for l in ("crash", "crash_sweep")},
    }
    if write_json:
        tracked = dict(summary)
        tracked["rows"] = [{k: v for k, v in r.items() if k != "wall_s"}
                           for r in rows]
        merge_bench_json(write_json, {"fig22": tracked})
    return rows, summary, walls


def run(write_json=None):
    """Harness entry point (benchmarks/run.py)."""
    return run_sweeps(write_json=write_json)[0]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="write BENCH_faults.json and fail unless the "
                         "zero-plan/completion/recovery/determinism gates "
                         "hold inside the wall budget")
    ap.add_argument("--json", default="BENCH_faults.json")
    ap.add_argument("--scale", type=int, default=SCALE)
    ap.add_argument("--nodes", type=int, default=N_NODES)
    ap.add_argument("--seed", type=int, default=SEED)
    args = ap.parse_args()
    rows, s, walls = run_sweeps(write_json=args.json, scale=args.scale,
                                n_nodes=args.nodes, seed=args.seed)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"wrote {args.json}")
    if args.smoke:
        slow = {l: round(w, 1) for l, w in walls.items()
                if w > ROW_WALL_BUDGET_S}
        ok = (s["zero_plan_identical"] and s["completion_gate"]
              and s["recovery_gate"] and s["deterministic"]
              and s["simsan_storm_identical"] and not slow)
        print(f"smoke: zero_plan_identical={s['zero_plan_identical']} "
              f"completion={s['completion']} (gate>=99%) "
              f"recovery_bytes={s['recovery_bytes_targeted']} (gate>0) "
              f"deterministic={s['deterministic']} "
              f"simsan_storm_identical={s['simsan_storm_identical']} "
              f"over_budget={slow or None} "
              f"-> {'OK' if ok else 'FAIL'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
