"""Figures 16+17: COW (on-demand) vs non-COW (read-everything-upfront):
latency and throughput across touch ratios — plus ``fig16.cow.fused``, the
kernel-speedup row: the per-page host commit loop vs ONE fused cow_scatter
commit at equal bytes (the tentpole's on-device COW commit path).

``--smoke`` merges the ``cow_fused`` section into ``BENCH_paging.json``
(deterministic byte/op fields + the huge-margin ``fused_beats_host``
boolean; wall times are printed, never pinned) and exits non-zero if the
fused commit fails to beat the host loop.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import (deploy_parent, make_cluster, merge_bench_json,
                               timed, touch_fraction)
from repro.fork import ForkPolicy
from repro.memory.pool import PagePool

FN = "image"

# fused-commit comparison shape: small pages make the per-page python loop's
# overhead honest (one write_pages call per page, the pre-fusion commit
# shape) while the fused side lands the same bytes in one kernel launch
FUSED_PAGE_ELEMS = 4096
FUSED_PAGES = 1024


def run():
    rows = []
    for ratio in (0.1, 0.3, 0.6, 0.9, 1.0):
        # COW / lazy
        net, nodes = make_cluster(2)
        parent = deploy_parent(nodes[0], FN)
        handle = nodes[0].prepare_fork(parent)
        t_lazy = timed(net, lambda: touch_fraction(
            handle.resume_on(nodes[1]), ratio, 1))
        lazy_bytes = net.meter["rdma_bytes"]

        # non-COW / eager
        net2, nodes2 = make_cluster(2)
        parent2 = deploy_parent(nodes2[0], FN)
        handle2 = nodes2[0].prepare_fork(parent2)
        t_eager = timed(net2, lambda: handle2.resume_on(
            nodes2[1], ForkPolicy(lazy=False)))
        eager_bytes = net2.meter["rdma_bytes"]

        rows.append(dict(
            name=f"fig16.touch{int(ratio*100)}",
            us_per_call=int(t_lazy.wall_s * 1e6),
            cow_sim_us=int(t_lazy.sim_s * 1e6),
            eager_us=int(t_eager.wall_s * 1e6),
            eager_sim_us=int(t_eager.sim_s * 1e6),
            cow_mb=round(lazy_bytes / 2**20, 1),
            eager_mb=round(eager_bytes / 2**20, 1),
            thpt_ratio=round(eager_bytes / max(lazy_bytes, 1), 2)))
    return rows


def cow_fused():
    """The fused-commit row: per-page host numpy commit loop vs one fused
    cow_scatter commit (device pool, kernels/dispatch-selected backend) at
    equal bytes.  Returns (row, wall) where ``row`` carries only the
    deterministic pinned fields and ``wall`` the measured times."""
    import warnings
    rng = np.random.default_rng(0)
    pages = rng.standard_normal((FUSED_PAGES, FUSED_PAGE_ELEMS)) \
        .astype(np.float32)
    frames = np.arange(FUSED_PAGES, dtype=np.int32)
    nbytes = pages.nbytes

    host = PagePool(page_elems=FUSED_PAGE_ELEMS, initial_frames=FUSED_PAGES)
    host._ensure_capacity("float32", FUSED_PAGES)
    t0 = time.perf_counter()
    for i in range(FUSED_PAGES):        # the pre-fusion commit shape
        host.write_pages("float32", frames[i:i + 1], pages[i:i + 1])
    t_host = time.perf_counter() - t0

    dev = PagePool(page_elems=FUSED_PAGE_ELEMS, initial_frames=FUSED_PAGES,
                   device=True)
    dev._ensure_capacity("float32", FUSED_PAGES)
    with warnings.catch_warnings():     # off-TPU fallback is the point here
        warnings.simplefilter("ignore", RuntimeWarning)
        dev.write_pages("float32", frames, pages)   # warm the jit cache
        t1 = time.perf_counter()
        dev.write_pages("float32", frames, pages)
        t_fused = time.perf_counter() - t1

    same = np.array_equal(np.asarray(dev.frames_array("float32")),
                          host._frames["float32"])
    row = dict(
        name="fig16.cow.fused",
        pages=FUSED_PAGES, bytes=nbytes,
        host_ops=FUSED_PAGES,           # one commit call per page
        fused_ops=1,                    # one fused scatter for the run table
        equal_bytes=True, bitwise_equal=bool(same),
        fused_beats_host=bool(t_fused < t_host))
    return row, {"host_us": int(t_host * 1e6), "fused_us": int(t_fused * 1e6)}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="merge the cow_fused section into the BENCH "
                         "artifact and fail unless the fused commit beats "
                         "the per-page host loop at equal bytes")
    ap.add_argument("--json", default="BENCH_paging.json",
                    help="tracked artifact to merge the section into")
    args = ap.parse_args()
    row, wall = cow_fused()
    print(",".join(f"{k}={v}" for k, v in row.items()))
    print(f"fused commit {wall['fused_us']}us vs per-page host loop "
          f"{wall['host_us']}us at {row['bytes']} bytes")
    merge_bench_json(args.json, {"cow_fused": row})
    print(f"merged cow_fused into {args.json}")
    if args.smoke:
        return 0 if (row["fused_beats_host"] and row["bitwise_equal"]) else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
