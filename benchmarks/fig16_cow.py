"""Figures 16+17: COW (on-demand) vs non-COW (read-everything-upfront):
latency and throughput across touch ratios."""
from __future__ import annotations

from benchmarks.common import deploy_parent, make_cluster, timed, touch_fraction
from repro.fork import ForkPolicy

FN = "image"


def run():
    rows = []
    for ratio in (0.1, 0.3, 0.6, 0.9, 1.0):
        # COW / lazy
        net, nodes = make_cluster(2)
        parent = deploy_parent(nodes[0], FN)
        handle = nodes[0].prepare_fork(parent)
        t_lazy = timed(net, lambda: touch_fraction(
            handle.resume_on(nodes[1]), ratio, 1))
        lazy_bytes = net.meter["rdma_bytes"]

        # non-COW / eager
        net2, nodes2 = make_cluster(2)
        parent2 = deploy_parent(nodes2[0], FN)
        handle2 = nodes2[0].prepare_fork(parent2)
        t_eager = timed(net2, lambda: handle2.resume_on(
            nodes2[1], ForkPolicy(lazy=False)))
        eager_bytes = net2.meter["rdma_bytes"]

        rows.append(dict(
            name=f"fig16.touch{int(ratio*100)}",
            us_per_call=int(t_lazy.wall_s * 1e6),
            cow_sim_us=int(t_lazy.sim_s * 1e6),
            eager_us=int(t_eager.wall_s * 1e6),
            eager_sim_us=int(t_eager.sim_s * 1e6),
            cow_mb=round(lazy_bytes / 2**20, 1),
            eager_mb=round(eager_bytes / 2**20, 1),
            thpt_ratio=round(eager_bytes / max(lazy_bytes, 1), 2)))
    return rows
