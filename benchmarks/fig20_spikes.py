"""Figure 20: load-spike replay (Azure trace 660323) — MITOSIS fork-on-demand
vs Caching(Fn) vs coldstart, now driven through ``repro.sim``.

* ``fig20.replay.*`` — the real thing: a discrete-event :class:`ReplayEngine`
  schedules every invocation of the spike trace as an arrival event and
  serves it through the actual platform (``Coordinator`` seed store + GC on
  the sim clock, fork descriptor fetch + auth + demand paging over contended
  link lanes).  There is no analytical latency shortcut — an invocation's
  latency is whatever the data plane charged between arrival and completion.
  Policies compare at an EQUAL WARM BUDGET: ``ForkOnDemand(replicas=S)``
  against ``KeepWarm(prewarm=S)``, plus a bounded-pool ``Hybrid`` row and a
  coldstart control.
* ``fig20.legacy.*`` — the previous closed-form minute-granularity model,
  kept for one release as a cross-check, with its two bugs fixed:
  warm-pool consumption is now LIFO (the old ``cache = cache[hits:]``
  consumed the *oldest* entries, so TTL expiry almost never fired), and
  p99 is the interpolated percentile (the old index clamp reported the
  max on short traces).

``run(write_json=path)`` (and ``--smoke``) writes ``BENCH_spikes.json``;
``--smoke`` exits non-zero unless the replayed MITOSIS p99 is >= 80% below
caching-at-equal-warm-budget, keep-warm peak per-node memory is >= 10x the
fork row's, and a repeated replay at the same seed reproduces the event
log byte-for-byte.
"""
from __future__ import annotations

import argparse
import gc
import json
import sys
import time

from benchmarks.common import deploy_parent, make_cluster
from repro.sim import (ColdStart, ForkOnDemand, Hybrid, KeepWarm,
                       ReplayEngine, SimFunction, percentile, spike_660323)

FN = "spike"
EXEC_S = 0.030            # modeled function body (paper fig20: 30 ms)
COLD_S = 0.167            # paper §2: 167 ms local coldstart
CACHE_TTL = 60.0          # Fn keeps coldstarted containers warm ~1 trace tick
HOLD_S = 60.0             # container occupancy = the trace's minute tick —
#                           the legacy model's one-call-per-container-per-
#                           minute assumption, enforced by completion events
PAGE_ELEMS = 1024         # 4 KiB sim pages: page COUNT (16/container) drives
#                           the fault traffic and the memory-ratio gate;
#                           smaller pages cut the byte volume cold boots must
#                           physically copy, keeping smoke under the minute
STATE_BYTES = 16 * PAGE_ELEMS * 4   # pristine container state, 16 pages
TOUCH = 0.05              # handler touches 5% of state (>= 1 page)
WARM_BUDGET = 4           # S fork replicas == S prewarmed containers
SCALE = 50                # spike trace x50 -> 10050 invocations
N_NODES = 64
SEED = 20260809

# legacy closed-form inputs (unchanged from the pre-replay rows)
LEGACY_FN = "json"
TRACE = [1, 1, 2, 1, 1, 40, 120, 30, 2, 1, 1, 1]

POLICIES = {
    "mitosis": lambda: ForkOnDemand(replicas=WARM_BUDGET, prefetch=0),
    "caching": lambda: KeepWarm(ttl=CACHE_TTL, prewarm=WARM_BUDGET),
    "hybrid": lambda: Hybrid(pool=WARM_BUDGET, ttl=CACHE_TTL, prefetch=0),
    "coldstart": lambda: ColdStart(),
}


def _sim_function() -> SimFunction:
    return SimFunction(FN, state_bytes=STATE_BYTES, touch_frac=TOUCH,
                       exec_s=EXEC_S, coldstart_s=COLD_S, hold_s=HOLD_S)


def replay_once(label: str, scale: int = SCALE, n_nodes: int = N_NODES,
                seed: int = SEED):
    """One (policy, trace) replay -> (deterministic summary, wall seconds)."""
    trace = spike_660323(scale=scale)
    eng = ReplayEngine(trace, POLICIES[label](), [_sim_function()],
                       n_nodes=n_nodes, seed=seed, page_elems=PAGE_ELEMS)
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    summary = res.summary()
    # Drop the replay's object graph before the next row: a retained engine
    # (10^5 event-log entries + 64 node pools) makes the cyclic collector
    # rescan it during the next row's allocation churn — measured ~10x
    # slower back-to-back rows on this host without the explicit collect.
    del eng, res, trace
    gc.collect()
    return summary, wall


def run_replay(scale: int = SCALE, n_nodes: int = N_NODES, seed: int = SEED):
    """The fig20.replay.* rows; returns (rows, per-policy summaries)."""
    rows, reps = [], {}
    for label in POLICIES:
        s, wall = replay_once(label, scale=scale, n_nodes=n_nodes, seed=seed)
        lat, startup = s["latency"]["all"], s["startup"]["all"]
        rows.append(dict(
            name=f"fig20.replay.{label}",
            us_per_call=int(wall / max(1, s["invocations"]) * 1e6),
            invocations=s["invocations"],
            nodes=s["nodes"],
            p50_us=lat["p50_us"],
            p99_us=lat["p99_us"],
            p999_us=lat["p999_us"],
            startup_p99_us=startup["p99_us"],
            warm=s["decisions"].get("warm", 0),
            forks=s["decisions"].get("fork", 0),
            colds=s["decisions"].get("cold", 0),
            rdma_pages=s["payload_pages"].get("pages_rdma", 0),
            peak_node_mb=s["mem_peak_node_mb"],
            peak_total_mb=s["mem_peak_total_mb"],
            digest=s["event_log_digest"][:12]))
        reps[label] = s
    return rows, reps


def run_legacy():
    """The closed-form minute-granularity rows (bug-fixed, one release)."""
    rows = []
    for policy in ("mitosis", "caching", "coldstart"):
        net, nodes = make_cluster(4)
        parent = deploy_parent(nodes[0], LEGACY_FN)
        nodes[0].prepare_fork(parent)       # the one provisioned seed
        state_b = parent.total_bytes()
        cache: list = []                    # expiry minutes of idle containers
        lat, mem_tl = [], []
        for minute, calls in enumerate(TRACE):
            cache = [e for e in cache if e >= minute]
            if policy == "mitosis":
                # derived: descriptor + on-demand pages at touch ratio
                lat += [0.001 + 0.6 * state_b / net.model.rdma_bw + EXEC_S
                        ] * calls
                mem = state_b                        # ONE seed cluster-wide
            elif policy == "caching":
                # calls within a minute are concurrent: each needs its own
                # container; hits = available cached, misses coldstart.
                # Consumption is LIFO — the most recently parked containers
                # serve, the oldest stay put and age out via TTL.
                hits = min(len(cache), calls)
                misses = calls - hits
                lat += [0.0005 + EXEC_S] * hits + [COLD_S + EXEC_S] * misses
                if hits:
                    del cache[-hits:]
                cache += [minute + CACHE_TTL / 60] * calls  # all re-park
                mem = len(cache) * state_b
            else:
                lat += [COLD_S + EXEC_S] * calls
                mem = 0
            mem_tl.append(mem / 4 / 2**20)          # per-machine MiB
        rows.append(dict(
            name=f"fig20.legacy.{policy}",
            us_per_call=int(sum(lat) / len(lat) * 1e6),
            p50_us=int(percentile(lat, 50.0) * 1e6),
            p99_us=int(percentile(lat, 99.0) * 1e6),
            idle_mem_mb=round(mem_tl[0], 2),
            peak_mem_mb=round(max(mem_tl), 2)))
    return rows


def run_sweeps(write_json=None, scale: int = SCALE, n_nodes: int = N_NODES,
               seed: int = SEED):
    """Replay + legacy rows plus the gated summary; returns (rows, summary)."""
    replay_rows, reps = run_replay(scale=scale, n_nodes=n_nodes, seed=seed)
    legacy_rows = run_legacy()
    rows = replay_rows + legacy_rows

    mit, cach = reps["mitosis"], reps["caching"]
    mit_p99 = mit["latency"]["all"]["p99_us"]
    cach_p99 = cach["latency"]["all"]["p99_us"]
    mem_ratio = cach["mem_peak_node_mb"] / max(mit["mem_peak_node_mb"], 1e-9)
    # determinism witness: a small replay repeated at the same seed must
    # reproduce the full summary (event log digest included) exactly
    d1, _ = replay_once("mitosis", scale=2, n_nodes=8, seed=seed)
    d2, _ = replay_once("mitosis", scale=2, n_nodes=8, seed=seed)

    summary = {
        "schema": "spikes-bench/v1",
        "rows": rows,
        "replay": {
            "trace": mit["trace"],
            "seed": seed,
            "nodes": n_nodes,
            "invocations": mit["invocations"],
            "equal_warm_budget": WARM_BUDGET,
            "p99_us": {k: reps[k]["latency"]["all"]["p99_us"]
                       for k in POLICIES},
            # mitosis p99 must sit >= 80% below caching at equal warm budget
            "p99_reduction": round(1.0 - mit_p99 / cach_p99, 4),
            "p99_gate": mit_p99 <= 0.2 * cach_p99,
            "mem_peak_node_mb": {k: reps[k]["mem_peak_node_mb"]
                                 for k in POLICIES},
            # keep-warm provisioning must cost >= 10x the fork row's memory
            "mem_ratio": round(mem_ratio, 2),
            "mem_gate": mem_ratio >= 10.0,
            "deterministic": d1 == d2,
            "event_log_digest": {k: reps[k]["event_log_digest"]
                                 for k in POLICIES},
            "gc": {k: reps[k]["gc"] for k in POLICIES},
            "lease": mit["lease"],
        },
    }
    if write_json:
        # wall time is machine noise — the tracked artifact keeps only the
        # deterministic replay/meter fields so diffs mean real regressions
        tracked = dict(summary)
        tracked["rows"] = [{k: v for k, v in r.items() if k != "us_per_call"}
                           for r in rows]
        with open(write_json, "w") as f:
            json.dump(tracked, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows, summary


def run(write_json=None):
    """Harness entry point (benchmarks/run.py): replay + legacy rows."""
    return run_sweeps(write_json=write_json)[0]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="write BENCH_spikes.json and fail unless the "
                         "replayed p99/memory gates hold and the replay is "
                         "deterministic under the fixed seed")
    ap.add_argument("--json", default="BENCH_spikes.json",
                    help="output path for the spike-replay summary")
    ap.add_argument("--scale", type=int, default=SCALE,
                    help="spike trace multiplier (default %(default)s -> "
                         "10050 invocations)")
    ap.add_argument("--nodes", type=int, default=N_NODES)
    ap.add_argument("--seed", type=int, default=SEED)
    args = ap.parse_args()
    rows, s = run_sweeps(write_json=args.json, scale=args.scale,
                         n_nodes=args.nodes, seed=args.seed)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"wrote {args.json}")
    if args.smoke:
        rp = s["replay"]
        ok = rp["p99_gate"] and rp["mem_gate"] and rp["deterministic"]
        print(f"smoke: {rp['invocations']} invocations on {rp['nodes']} "
              f"nodes; p99 {rp['p99_us']} "
              f"(reduction={rp['p99_reduction']:.1%}, gate>=80%), "
              f"peak node MB {rp['mem_peak_node_mb']} "
              f"(ratio={rp['mem_ratio']}x, gate>=10x), "
              f"deterministic={rp['deterministic']} "
              f"-> {'OK' if ok else 'FAIL'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
