"""Figure 20: load-spike replay (Azure-trace-shaped): latency CDF points and
per-machine memory timeline for MITOSIS vs Caching(Fn) vs coldstart."""
from __future__ import annotations

import numpy as np

from benchmarks.common import deploy_parent, make_cluster, timed, touch_fraction

FN = "json"
EXEC_S = 0.030            # modeled function body
CACHE_TTL = 60.0          # Fn keeps coldstarted containers warm ~1 trace tick
# per-minute call counts shaped like the paper's 660323 trace
TRACE = [1, 1, 2, 1, 1, 40, 120, 30, 2, 1, 1, 1]


def run():
    rows = []
    for policy in ("mitosis", "caching", "coldstart"):
        net, nodes = make_cluster(4)
        parent = deploy_parent(nodes[0], FN)
        nodes[0].prepare_fork(parent)       # the one provisioned seed
        state_b = parent.total_bytes()
        cold_s = 0.167                      # paper: 167 ms local coldstart
        cache: list = []                    # expiry minutes of idle containers
        lat, mem_tl = [], []
        for minute, calls in enumerate(TRACE):
            cache = [e for e in cache if e >= minute]
            if policy == "mitosis":
                # derived: descriptor + on-demand pages at touch ratio
                lat += [0.001 + 0.6 * state_b / net.model.rdma_bw + EXEC_S
                        ] * calls
                mem = state_b                        # ONE seed cluster-wide
            elif policy == "caching":
                # calls within a minute are concurrent: each needs its own
                # container; hits = available cached, misses coldstart
                hits = min(len(cache), calls)
                misses = calls - hits
                lat += [0.0005 + EXEC_S] * hits + [cold_s + EXEC_S] * misses
                cache = cache[hits:] + \
                    [minute + CACHE_TTL / 60] * calls   # all return to cache
                mem = len(cache) * state_b
            else:
                lat += [cold_s + EXEC_S] * calls
                mem = 0
            mem_tl.append(mem / 4 / 2**20)          # per-machine MiB
        lat = np.sort(np.asarray(lat))
        rows.append(dict(
            name=f"fig20.{policy}",
            us_per_call=int(lat.mean() * 1e6),
            p50_us=int(lat[int(0.5 * len(lat))] * 1e6),
            p99_us=int(lat[min(int(0.99 * len(lat)), len(lat) - 1)] * 1e6),
            idle_mem_mb=round(mem_tl[0], 2),
            peak_mem_mb=round(max(mem_tl), 2)))
    return rows
