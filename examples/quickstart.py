"""Quickstart: the MITOSIS-JAX remote fork in 60 lines.

Builds a 2-node cluster, deploys one seed LM replica, remote-forks it to the
second node (descriptor-only transfer + on-demand paging), and generates
text on the child — verifying it matches the parent exactly.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core.instance import ModelInstance
from repro.net import Network
from repro.fork import ForkPolicy
from repro.models import lm
from repro.platform.node import NodeRuntime
from repro.serving.engine import ServingEngine


def main():
    cfg = dataclasses.replace(get_arch("micro-small"), compute_dtype="float32")
    net = Network()
    parent_node = NodeRuntime("parent", net)
    child_node = NodeRuntime("child", net)

    # 1. one seed replica — the only provisioned instance in the cluster
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    seed = ModelInstance.create(parent_node, cfg.name, params)
    handle = parent_node.prepare_fork(seed)
    print(f"seed: {seed.total_bytes()/2**20:.1f} MiB state, descriptor = "
          f"{len(parent_node.seeds[handle.handler_id].blob)} bytes")

    # 2. remote fork: child maps the parent's pages, fetches on demand
    t0 = time.perf_counter()
    child = handle.resume_on(child_node, ForkPolicy(lazy=True, prefetch=1))
    print(f"resume_on: {(time.perf_counter()-t0)*1e3:.1f} ms "
          f"(resident: {child.resident_fraction():.0%})")

    child_params = child.materialize_pytree()
    print(f"materialized on demand: {child.stats['pages_rdma']} pages over "
          f"RDMA, {net.meter['rdma_bytes']/2**20:.1f} MiB")

    # 3. serve from the child; parent and child agree bit-for-bit
    prompt = [11, 42, 7, 300]
    out = {}
    for tag, p in (("parent", params), ("child", child_params)):
        eng = ServingEngine(cfg, p, backend="ref")
        rid = eng.submit(prompt, max_tokens=8)
        out[tag] = eng.run_to_completion()[rid]
        print(f"{tag} generated: {out[tag]}")
    assert out["parent"] == out["child"]
    print("child == parent: OK")


if __name__ == "__main__":
    main()
