"""FINRA workflow (paper Figure 2/19): upstream function pre-materializes
market data; N runAuditRule children remote-fork it and validate trades with
ZERO serialization — compared against the Fn/Redis-style message baseline.

  PYTHONPATH=src python examples/serve_workflow_finra.py --rules 8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.net import Network
from repro.models import lm
from repro.platform.coordinator import Coordinator, FunctionDef
from repro.platform.node import NodeRuntime
from repro.platform.workflow import Workflow, WorkflowFunc, run_workflow


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rules", type=int, default=8)
    ap.add_argument("--market-mb", type=float, default=6.0)
    args = ap.parse_args()

    cfg = get_arch("micro-hello")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    market = np.random.default_rng(0).standard_normal(
        int(args.market_mb * 2**20 / 4)).astype(np.float32)

    def fetch_data(inst, ctx):
        # fused fetchPortfolioData+fetchMarketData (paper §7.6)
        inst.add_tensor("globals/market", jnp.asarray(market))
        return {"rows": market.size}

    def fetch_data_msg(inst, ctx):
        return {"market": market}

    def run_audit(inst, ctx):
        if "msg:fetchData" in ctx:
            data = ctx["msg:fetchData"]["market"]        # deserialized copy
        else:
            data = np.asarray(inst.ensure_tensor("globals/market"))
        return {"violations": int((np.abs(data) > 3.5).sum())}

    for transfer, fetch in (("fork", fetch_data), ("message", fetch_data_msg)):
        net = Network()
        nodes = [NodeRuntime(f"inv{i}", net) for i in range(4)]
        coord = Coordinator(net, nodes)
        coord.register_function(FunctionDef("finra-fetch", cfg.name,
                                            lambda: params, fetch))
        coord.register_function(FunctionDef("finra-audit", cfg.name,
                                            lambda: params, run_audit))
        wf = Workflow("finra")
        wf.add(WorkflowFunc("fetchData", "finra-fetch"))
        wf.add(WorkflowFunc("runAuditRule", "finra-audit",
                            fork_from="fetchData"))
        wf.edge("fetchData", "runAuditRule")

        t0 = time.perf_counter()
        res = run_workflow(coord, wf, {}, transfer=transfer,
                           fan_out={"runAuditRule": args.rules})
        dt = time.perf_counter() - t0
        v = [r["violations"] for r in res["runAuditRule"]]
        assert len(set(v)) == 1, "all rules must see identical data"
        print(f"[{transfer:7s}] {args.rules} audit rules in {dt*1e3:7.1f} ms "
              f"wall | sim {net.sim_time*1e3:6.2f} ms | "
              f"rdma {net.meter.get('rdma_bytes',0)/2**20:7.1f} MiB | "
              f"msg {net.meter.get('msg_bytes',0)/2**20:7.1f} MiB | "
              f"violations={v[0]}")


if __name__ == "__main__":
    main()
