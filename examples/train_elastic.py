"""End-to-end training driver with fault tolerance and MITOSIS-style elastic
scale-up: train a ~100M-param LM, checkpoint/restart after a simulated crash,
then add a worker that joins by REMOTE-FORKING a healthy peer (descriptor +
on-demand page pull) instead of restoring from the checkpoint — the paper's
"no provisioned concurrency" applied to elastic training.

Runs on 8 forced host devices so the data-parallel resize 2 -> 4 is real.

  PYTHONPATH=src python examples/train_elastic.py [--steps 60] [--full-100m]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, reduce_for_smoke
from repro.core.instance import ModelInstance
from repro.net import Network
from repro.fork import ForkPolicy
from repro.distributed import ctx
from repro.distributed.sharding import make_axis_env, params_shardings
from repro.models import lm
from repro.models.flops import param_counts
from repro.platform.node import NodeRuntime
from repro.training import checkpoint as ckpt
from repro.training.data import TokenStream
from repro.training.optimizer import init_opt_state
from repro.training.train_step import TrainConfig, make_train_step


def make_mesh(dp: int):
    devs = np.asarray(jax.devices()[:dp]).reshape(dp, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


def shard_tree(tree, cfg, env):
    sh = params_shardings(cfg, jax.eval_shape(lambda: tree), env)
    return jax.tree.map(jax.device_put, tree, sh)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full-100m", action="store_true",
                    help="use the full ~100M config (slow on CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch("train-100m")
    if not args.full_100m:
        cfg = dataclasses.replace(
            reduce_for_smoke(cfg), d_model=256, d_ff=1024, vocab_size=4096)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    N, _, _ = param_counts(cfg)
    print(f"[elastic] {cfg.name}: {N/1e6:.1f}M params on "
          f"{len(jax.devices())} devices")

    tcfg = TrainConfig(peak_lr=1e-3, warmup=5, total_steps=args.steps,
                       q_chunk=args.seq, xent_chunk=args.seq)
    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=0)
    losses = []

    # ---- phase 1: dp=2, crash at 1/3 of the run, restart from checkpoint
    mesh2 = make_mesh(2)
    env2 = make_axis_env(mesh2)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    with ctx.use_env(env2):
        step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
        params = shard_tree(params, cfg, env2)
        opt["m"] = shard_tree(opt["m"], cfg, env2)
        opt["v"] = shard_tree(opt["v"], cfg, env2)
        crash_at = args.steps // 3
        for s in range(crash_at):
            tok, lab = stream.batch_at(s)
            params, opt, m = step_fn(params, opt, jnp.asarray(tok),
                                     jnp.asarray(lab))
            losses.append(float(m["loss"]))
        ckpt.save_checkpoint("/tmp/elastic_ckpt", crash_at, params, opt)
        print(f"[elastic] dp=2 trained to step {crash_at}, "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; CRASH (simulated)")

        # restart from checkpoint (classic fault tolerance)
        step0, params, opt, _ = ckpt.load_checkpoint("/tmp/elastic_ckpt")
        params = shard_tree(jax.tree.map(jnp.asarray, params), cfg, env2)
        opt = {"m": shard_tree(jax.tree.map(jnp.asarray, opt["m"]), cfg, env2),
               "v": shard_tree(jax.tree.map(jnp.asarray, opt["v"]), cfg, env2),
               "count": jnp.asarray(opt["count"])}
        for s in range(step0, 2 * args.steps // 3):
            tok, lab = stream.batch_at(s)
            params, opt, m = step_fn(params, opt, jnp.asarray(tok),
                                     jnp.asarray(lab))
            losses.append(float(m["loss"]))
        print(f"[elastic] restarted from step {step0}, continued to "
              f"{2*args.steps//3}, loss {losses[-1]:.4f}")

    # ---- phase 2: elastic scale-up 2 -> 4 via REMOTE FORK (no checkpoint IO)
    net = Network()
    donor = NodeRuntime("donor", net)
    joiner = NodeRuntime("joiner", net)
    state = {"params": jax.tree.map(np.asarray, params),
             "opt_m": jax.tree.map(np.asarray, opt["m"]),
             "opt_v": jax.tree.map(np.asarray, opt["v"])}
    inst = ModelInstance.create(donor, cfg.name, state,
                                registers={"step": 2 * args.steps // 3,
                                           "count": int(opt["count"])})
    handle = donor.prepare_fork(inst)
    t0 = time.perf_counter()
    child = handle.resume_on(joiner, ForkPolicy(lazy=True, prefetch=1))
    got = child.materialize_pytree()
    dt = time.perf_counter() - t0
    print(f"[elastic] worker joined via remote fork in {dt*1e3:.0f} ms "
          f"({child.stats['pages_rdma']} pages, descriptor "
          f"{len(donor.seeds[handle.handler_id].blob)} B — no checkpoint read)")

    mesh4 = make_mesh(4)
    env4 = make_axis_env(mesh4)
    with ctx.use_env(env4):
        step_fn4 = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
        params4 = shard_tree(jax.tree.map(jnp.asarray, got["params"]), cfg, env4)
        opt4 = {"m": shard_tree(jax.tree.map(jnp.asarray, got["opt_m"]), cfg, env4),
                "v": shard_tree(jax.tree.map(jnp.asarray, got["opt_v"]), cfg, env4),
                "count": jnp.asarray(child.registers["count"], jnp.int32)}
        start = child.registers["step"]
        for s in range(start, args.steps):
            tok, lab = stream.batch_at(s)
            params4, opt4, m = step_fn4(params4, opt4, jnp.asarray(tok),
                                        jnp.asarray(lab))
            losses.append(float(m["loss"]))
    print(f"[elastic] dp=4 continued to step {args.steps}, "
          f"final loss {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss must decrease across crash + resize"
    print(f"[elastic] OK: {losses[0]:.4f} -> {losses[-1]:.4f} across "
          f"crash-restart and 2->4 elastic resize")


if __name__ == "__main__":
    main()
